"""Plan-layer benchmarks: cache behavior + cost-ledger cross-checks.

Row families:

  plan/cache — clears the plan cache, then drives the REAL consumer paths
      (eager + jitted `rp.project` with a fresh-jit retrace, `reconstruct`,
      `project_many` over mixed TT/CP traffic, and a serve-style
      `group_signature` resolve) and reads back `rp.plan_cache_stats()`.
      Derived: `plan_builds` (one per distinct (spec, structure-sig,
      backend, pipeline) — gated like a launch count by check_regression:
      builds more than doubling means the signature went jit-unstable and
      every retrace re-plans), `plan_hits`, and `hit_rate`, asserted
      in-bench >= 0.5 so a cache that silently stops hitting fails even
      without a baseline to diff.
  plan/ledger/hbm — the plan's DECLARED one-pass `cost.hbm_bytes` for the
      XLA dense route vs the compiled executable's measured bytes accessed
      (`compiled.cost_analysis()`). The declared number is a lower bound
      (XLA materializes contraction intermediates the one-pass ledger
      excludes), asserted in-bench whenever the backend reports the metric.
  plan/ledger/wire — the plan layer's `collective_wire_bytes` ledger (what
      `SketchCompressor.wire_bytes` reads) vs the MEASURED HLO all-reduce
      bytes of the compiled fp32 sketch-mean collective: exact equality
      asserted — the ledger IS the wire traffic, not an estimate.
"""
import jax
import jax.numpy as jnp

from repro import rp

from ._util import csv_row, time_call


def _cache_row(rows):
    key = jax.random.PRNGKey(47)
    dims, k, rank, b = (8, 16, 16), 128, 2, 8
    op_tt = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=k, dims=dims, rank=rank),
        jax.random.fold_in(key, 0))
    op_cp = rp.make_projector(
        rp.ProjectorSpec(family="cp", k=k, dims=dims, rank=rank),
        jax.random.fold_in(key, 1))
    xb = jax.random.normal(jax.random.fold_in(key, 2), (b,) + dims)
    xs = [jax.random.normal(jax.random.fold_in(key, 3 + i), dims)
          for i in range(4)]

    rp.clear_plan_cache()

    def workload():
        y = rp.project(op_tt, xb)                      # eager dense
        rp.reconstruct(op_tt, y)                       # eager sketch
        jax.jit(lambda a: rp.project(op_tt, a))(xb)    # fresh jit: retrace
        rp.project_many(op_tt, xs)                     # bucketed many-path
        rp.project_many(op_cp, xs)
        rp.plan_execution(op_tt, rp.group_signature(op_tt, xs))  # serve
        return y

    us = time_call(workload, warmup=1, repeat=3)
    stats = rp.plan_cache_stats()
    builds, hits = stats.builds, stats.hits
    rate = stats.hit_rate
    # the acceptance criterion, asserted where the row is made: repeated
    # identical traffic (4 workload passes incl. warmup) must resolve to
    # the SAME cached plans — jit retraces included
    assert rate >= 0.5, (
        f"plan-cache hit rate {rate:.3f} ({hits} hits / {builds} builds): "
        "identical repeated traffic is rebuilding plans")
    rows.append(csv_row(
        "plan/cache", us,
        f"plan_builds={builds};plan_hits={hits};hit_rate={rate:.4f};"
        f"evictions={stats.evictions}"))


def _ledger_hbm_row(rows):
    key = jax.random.PRNGKey(48)
    dims, k, rank, b = (8, 16, 16), 128, 2, 8
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=k, dims=dims, rank=rank),
        jax.random.fold_in(key, 0))
    xb = jax.random.normal(jax.random.fold_in(key, 1), (b,) + dims)
    eplan = rp.plan_execution(op, rp.StructureSig(batch=b), backend="xla")
    declared = eplan.cost.hbm_bytes
    compiled = jax.jit(
        lambda a: rp.project(op, a, backend="xla")).lower(xb).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    measured = int(ca.get("bytes accessed", 0)) if ca else 0
    if measured:
        # declared is the ONE-PASS bound (inputs + operator + output, each
        # touched once); the compiled program can only move more
        assert measured >= declared, (
            f"measured bytes accessed {measured} below the plan's one-pass "
            f"lower bound {declared} — the ledger over-counts")
    rows.append(csv_row(
        "plan/ledger/hbm", 0.0,
        f"plan={eplan.plan_id};route={eplan.route};"
        f"declared_hbm_bytes={declared};measured_bytes={measured};"
        f"flops={eplan.cost.flops}"))


def _ledger_wire_row(rows):
    from repro.core.sketch import PytreeSketcher, SketchConfig
    from repro.launch.roofline import parse_collectives
    from repro.optim.compress import SketchCompressor

    key = jax.random.PRNGKey(49)
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("pod",))
    cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=8 * 16 * 16,
                       dims=(8, 16, 16))
    g = {"w": jax.random.normal(jax.random.fold_in(key, 0), (ndev, 4096)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (ndev, 100))}
    state = {"residual": jax.tree.map(jnp.zeros_like, g)}
    sk = PytreeSketcher(cfg, jax.tree.map(lambda x: x[0], g))
    comp = SketchCompressor(cfg, sync="sketch-mean", pod_axis="pod")

    def run_step(gg, ss, step):
        with rp.force_pallas():
            return comp.compress_collective(gg, ss, step=step, mesh=mesh)[:2]

    f = jax.jit(run_step).lower(g, state, 0).compile()
    ar = parse_collectives(f.as_text())["per_type"].get(
        "all-reduce", {"count": 0, "bytes": 0.0})
    declared = comp.wire_bytes(sk)
    measured = int(ar["bytes"])
    # fp32 sketch-mean: the ledger must equal the HLO all-reduce payload
    # bit for bit (nb * k * 4 bytes) — the one cross-check that catches a
    # ledger formula drifting from the traffic the compiler actually emits
    assert declared == measured, (
        f"wire ledger {declared} != HLO all-reduce bytes {measured} for "
        "fp32 sketch-mean")
    rows.append(csv_row(
        "plan/ledger/wire", 0.0,
        f"npod={ndev};n_buckets={sk.n_buckets};k={cfg.k};"
        f"declared_wire_bytes={declared};hlo_allreduce_bytes={measured};"
        f"hlo_allreduce_count={ar['count']}"))


def run(fast=True):
    del fast
    rows = []
    _cache_row(rows)
    _ledger_hbm_row(rows)
    _ledger_wire_row(rows)
    return rows
