"""Paper Sec. 1/3 memory claims: operator parameter counts across orders,
plus the MXU-aligned gradient-bucket regime used by the compressor."""
from repro.core import theory

from ._util import csv_row


def run(fast=True):
    rows = []
    k = 1024
    for (d, N, label) in [(15, 3, "small"), (3, 12, "medium"),
                          (3, 25, "high")]:
        dims = (d,) * N
        for r in (2, 5, 10):
            rows.append(csv_row(f"memory/{label}/TT({r})", 0.0,
                                f"params={theory.params_tt_rp(k, dims, r)}"))
        for r in (4, 25, 100):
            rows.append(csv_row(f"memory/{label}/CP({r})", 0.0,
                                f"params={theory.params_cp_rp(k, dims, r)}"))
        rows.append(csv_row(f"memory/{label}/Gaussian", 0.0,
                            f"params={theory.params_gaussian_rp(k, dims)}"))
        rows.append(csv_row(f"memory/{label}/VerySparse", 0.0,
                            f"params={theory.params_sparse_rp(k, dims)}"))
    # gradient-bucket regime (1M-elem buckets, k=4096)
    dims = (128, 128, 64)
    rows.append(csv_row("memory/bucket1M/TT(2)", 0.0,
                        f"params={theory.params_tt_rp(4096, dims, 2)};"
                        f"dense={theory.params_gaussian_rp(4096, dims)}"))
    return rows
