"""Paper App. B.1 (Fig. 3): pairwise-distance preservation on image-like
data, tensorized 4x4x4x4x4x3 as in the paper. CIFAR-10 is not available
offline; a seeded synthetic stand-in with image-like spatial correlation is
used (noted in EXPERIMENTS.md)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import rp

from ._util import csv_row

DIMS = (4, 4, 4, 4, 4, 3)  # 3072 = 32*32*3


def synthetic_images(n=20, seed=0):
    """Low-pass-filtered noise ~ image statistics; normalized rows."""
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(n, 32, 32, 3))
    k = np.ones((5, 5)) / 25.0
    for i in range(n):
        for c in range(3):
            from numpy.lib.stride_tricks import sliding_window_view
            pad = np.pad(imgs[i, :, :, c], 2, mode="reflect")
            win = sliding_window_view(pad, (5, 5))
            imgs[i, :, :, c] = (win * k).sum(axis=(2, 3))
    flat = imgs.reshape(n, -1)
    flat /= np.linalg.norm(flat, axis=1, keepdims=True)
    return jnp.asarray(flat)


def run(fast=True):
    n = 12 if fast else 50
    trials = 8 if fast else 100
    ks = (64, 256) if fast else (64, 256, 1024)
    data = synthetic_images(n)
    tens = data.reshape((n,) + DIMS)
    pairs = list(itertools.combinations(range(n), 2))
    rows = []
    def vproj(family, k, rank, inp):
        spec = rp.ProjectorSpec(family=family, k=k, dims=DIMS, rank=rank)

        def f(kk):
            op = rp.make_projector(spec, kk)
            return jax.vmap(lambda t: rp.project(op, t))(inp)
        return f

    for k in ks:
        for name, proj in [
            ("TT(3)", vproj("tt", k, 3, tens)),
            ("CP(5)", vproj("cp", k, 5, tens)),
            ("Gaussian", vproj("gaussian", k, 1, data)),
        ]:
            ratios = []
            for t in range(trials):
                p = proj(jax.random.PRNGKey(5000 + t))
                for i, j in pairs:
                    du = float(jnp.linalg.norm(data[i] - data[j]))
                    dv = float(jnp.linalg.norm(p[i] - p[j]))
                    ratios.append(dv / du)
            rows.append(csv_row(f"pairwise/{name}/k={k}", 0.0,
                                f"mean_ratio={np.mean(ratios):.4f};"
                                f"std={np.std(ratios):.4f}"))
    return rows
