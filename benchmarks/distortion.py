"""Paper Fig. 1: distortion ratio D(f, X) = | ||f(X)||^2 / ||X||^2 - 1 |
vs embedding size k, for small/medium/high-order inputs, TT vs CP vs
Gaussian (small order) vs very-sparse (medium order)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import rp
from repro.core import random_tt

CASES = {
    "small":  dict(d=15, N=3),
    "medium": dict(d=3, N=12),
    "high":   dict(d=3, N=25),
}
TT_RANKS = (2, 5, 10)
CP_RANKS = (4, 25, 100)


def distortion_table(case: str, ks=(16, 64, 256, 1024), trials=20,
                     seed=0) -> list[dict]:
    info = CASES[case]
    dims = (info["d"],) * info["N"]
    x = random_tt(jax.random.PRNGKey(seed), dims, 10, norm="unit")
    xd = x.full() if case == "small" else None
    xflat = xd.reshape(-1) if xd is not None else None
    rows = []

    def mc(project):
        ds = []
        for t in range(trials):
            y = project(jax.random.PRNGKey(1000 + t))
            ds.append(abs(float(jnp.sum(y * y)) - 1.0))
        return float(np.mean(ds)), float(np.std(ds))

    def proj(family, k, r, inp):
        spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=r)
        return lambda kk: rp.project(rp.make_projector(spec, kk), inp)

    for k in ks:
        for r in TT_RANKS:
            m, s = mc(proj("tt", k, r, x))
            rows.append(dict(case=case, map=f"TT({r})", k=k, mean=m, std=s))
        for r in CP_RANKS:
            m, s = mc(proj("cp", k, r, x))
            rows.append(dict(case=case, map=f"CP({r})", k=k, mean=m, std=s))
        if case == "small":
            m, s = mc(proj("gaussian", k, 1, xflat))
            rows.append(dict(case=case, map="Gaussian", k=k, mean=m, std=s))
        if case == "medium" and k <= 256:
            xm = x.full().reshape(-1)
            m, s = mc(proj("sparse", k, 1, xm))
            rows.append(dict(case=case, map="VerySparse", k=k, mean=m, std=s))
    return rows


def run(fast=True):
    from ._util import csv_row
    ks = (16, 64, 256) if fast else (16, 64, 256, 1024)
    trials = 10 if fast else 50
    all_rows = []
    for case in CASES:
        for r in distortion_table(case, ks=ks, trials=trials):
            all_rows.append(
                csv_row(f"distortion/{case}/{r['map']}/k={r['k']}", 0.0,
                        f"mean={r['mean']:.4f};std={r['std']:.4f}"))
    return all_rows
