"""Registry smoke: one tiny config per registered repro.rp family.

Keeps every family constructible and benchable — `run.py --smoke` is wired
into CI so a family that breaks its factory, dense/flat dispatch, or adjoint
fails fast, including externally registered ones.
"""
import jax
import jax.numpy as jnp

from repro import rp

from ._util import csv_row, time_call

DIMS = (4, 8, 8)
K = 64


def run(fast=True):
    del fast
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), DIMS)
    rows = []
    for family in rp.list_families():
        spec = rp.ProjectorSpec(family=family, k=K, dims=DIMS, rank=2)
        op = rp.make_projector(spec, key)
        f = jax.jit(lambda t, op=op: rp.project(op, t))
        us = time_call(f, x)
        y = f(x)
        x_hat = rp.reconstruct(op, y)
        flat_ok = bool(jnp.allclose(rp.project(op, x.reshape(-1)), y,
                                    rtol=1e-4, atol=1e-5))
        rows.append(csv_row(
            f"smoke/{family}", us,
            f"k={K};dims={'x'.join(map(str, DIMS))};"
            f"params={op.num_params()};recon_elems={x_hat.size};"
            f"flat_matches_dense={flat_ok}"))
        assert flat_ok, family
    return rows
