"""Checkpointing benchmarks: verified save/restore and the sketched-state
size story.

Row families (all deterministic in structure):

  ckpt/save     — atomic synchronous save of a training-shaped state tree
      with per-array crc32 + manifest sha256; derived carries the tree's
      MiB and array count so a perf diff can tell layout drift from a
      genuine slowdown.
  ckpt/restore  — VERIFIED restore (full checksum pass) of the same tree;
      derived additionally proves the corruption path: the newest
      checkpoint is byte-flipped and the fallback restore must land on the
      previous verified step (fallback=1 in the row is asserted, not
      reported on faith).
  ckpt/sketched — SketchedTreeCodec encode+decode roundtrip of an
      EF-shaped tree; derived carries bytes_dense / bytes_sketched / the
      compression ratio. Acceptance: ratio >= 4 (the sketched EF record on
      disk is at least 4x smaller than the dense leaves it replaces).
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt import SketchedTreeCodec, checkpointer
from repro.core.sketch import SketchConfig
from repro.runtime.resilience import flip_byte

from ._util import csv_row, time_call


def _state(n_leaf, n_leaves=4):
    ks = jax.random.split(jax.random.PRNGKey(0), n_leaves)
    return {"params": {f"w{i}": jax.random.normal(ks[i], (n_leaf,))
                       for i in range(n_leaves)},
            "step": jnp.int32(7)}


def _save_restore_rows(rows, n_leaf):
    state = _state(n_leaf)
    nbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(state))
    with tempfile.TemporaryDirectory() as d:
        us = time_call(lambda: checkpointer.save(d, 1, state, keep=2),
                       warmup=1, repeat=3)
        rows.append(csv_row(
            f"ckpt/save/n={n_leaf}", us,
            f"arrays={len(jax.tree.leaves(state))};"
            f"mib={nbytes / 2**20:.2f};verified=1"))

        example = jax.eval_shape(lambda: state)
        us = time_call(lambda: checkpointer.restore(d, example),
                       warmup=1, repeat=3)
        # corruption drill: flip one byte in the newest checkpoint, prove
        # the verified restore falls back to the previous step
        checkpointer.save(d, 2, state, keep=4)
        checkpointer.save(d, 3, state, keep=4)
        flip_byte(f"{d}/step_0000000003/arr_0.npy")
        _, step = checkpointer.restore(d, example)
        assert step == 2, f"fallback restore landed on {step}, wanted 2"
        rows.append(csv_row(
            f"ckpt/restore/n={n_leaf}", us,
            f"arrays={len(jax.tree.leaves(state))};"
            f"mib={nbytes / 2**20:.2f};fallback=1"))


def _sketched_row(rows, n_leaf):
    ef = {"w": jax.random.normal(jax.random.PRNGKey(1), (n_leaf,)),
          "b": jax.random.normal(jax.random.PRNGKey(2), (n_leaf,))}
    cfg = SketchConfig(family="tt", k=128, rank=2, dims=(8, 16, 16),
                       bucket_elems=8 * 16 * 16, fresh_per_step=True)
    codec = SketchedTreeCodec(cfg, jax.eval_shape(lambda: ef))

    def roundtrip():
        rec = codec.encode(ef, step=3)
        return jax.block_until_ready(
            jax.tree.leaves(codec.decode(rec))[0])

    us = time_call(roundtrip, warmup=1, repeat=3)
    ratio = codec.compression_ratio()
    # the PR's acceptance criterion, asserted where the row is made
    assert ratio >= 4.0, f"sketched checkpoint ratio {ratio:.2f} < 4"
    rows.append(csv_row(
        f"ckpt/sketched/n={n_leaf}", us,
        f"bytes_dense={codec.dense_bytes()};"
        f"bytes_sketched={codec.sketch_bytes()};"
        f"ratio={ratio:.2f};k={cfg.k};nb={codec._sk.n_buckets}"))


def run(fast=True):
    rows = []
    n = 1 << 16 if fast else 1 << 20
    _save_restore_rows(rows, n)
    _sketched_row(rows, n)
    return rows
