"""Beyond-paper: sketched-gradient compression — convergence parity and
bytes-on-the-wire across compression ratios."""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.compress import SketchCompressor

from ._util import csv_row


def run(fast=True):
    steps_n = 60 if fast else 200
    cfg = reduced(get_config("llama3.2-3b"))
    model = build_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 64, 8, "train")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
    lr = functools.partial(schedule.constant, peak_lr=3e-3)

    def train(compressor):
        with mesh:
            b = steps_lib.build_train_step(model, mesh, shape, lr_fn=lr,
                                           compressor=compressor)
            state = steps_lib.init_train_state(
                model, jax.random.PRNGKey(0), compressor=compressor)
            m = {}
            for i in range(steps_n):
                state, m = b.fn(state, jax.tree.map(jnp.asarray,
                                                    data.batch(i)))
            return m

    rows = []
    base = train(None)
    rows.append(csv_row("gradcomp/baseline", 0.0,
                        f"final_loss={float(base['loss']):.4f}"))
    for k, tag in ((2048, "0.25x"), (512, "1x"), (128, "4x"), (32, "16x")):
        scfg = SketchConfig(family="tt", k=k, rank=8, bucket_elems=512,
                            dims=(4, 8, 16))
        m = train(SketchCompressor(scfg))
        ratio = float(m["dense_bytes"]) / float(m["sketch_bytes"])
        rows.append(csv_row(
            f"gradcomp/tt_k={k}", 0.0,
            f"final_loss={float(m['loss']):.4f};ratio={ratio:.1f};"
            f"alpha={scfg.shrinkage():.4f};"
            f"residual={float(m['residual_norm']):.2f}"))
    return rows
