"""Bench-regression gate: diff a fresh BENCH_rp.json against the committed
baseline.

Usage: python -m benchmarks.check_regression NEW.json BASELINE.json

Fails (exit 1) on SCHEMA DRIFT — schema version string changed, a baseline
section or named row disappeared, a record lost the
{name, us_per_call, derived} shape, or a timing record stopped covering a
gated subsystem entirely (REQUIRED_ROW_PREFIXES: the order-N dense frontier,
the compressed-domain `struct/` carry-sweep rows, the sharded-engine
`shard/` collective rows, the serving-engine `serve/` rows, and the
checkpointing `ckpt/` rows — a refactor
that silently drops a whole row family must not pass because the baseline
diff has nothing to compare) — and on a
LAUNCH-COUNT REGRESSION: any row whose
Pallas dispatch count (launches_batched / launches_project /
launches_reconstruct) grew to more than 2x the baseline, i.e. a batched
path quietly decomposing back into per-bucket or vmap launches — the
`plan/cache` row's `plan_builds` rides the same gate, so an ExecutionPlan
signature going jit-unstable (every retrace re-planning instead of
hitting the cache) fails the diff the same way — and on a
PERF-BAND REGRESSION: the `perf/*` rows' derived ratios (`speedup`,
`wire_ratio`, `hbm_ratio`) drifting past their relative band vs baseline
(see PERF_BANDS) — and on an OBS-OVERHEAD REGRESSION: the `obs/*` rows'
`overhead_frac` (disabled-telemetry cost / reference dispatch) exceeding
the ABSOLUTE `OBS_OVERHEAD_CAP` budget — a ratio of two timings from the
same process, so unlike wall-clock it is machine-independent and an
absolute cap is meaningful. Absolute wall-clock deltas are deliberately
NOT gated — CI machines are too noisy — only structure, launch counts,
and (relative-banded or capped) ratios of timings taken on the SAME
machine in the same run, which cancel the machine out.
"""
from __future__ import annotations

import json
import sys

LAUNCH_KEYS = ("launches_batched", "launches_project", "launches_reconstruct",
               "plan_builds")
RECORD_KEYS = {"name", "us_per_call", "derived"}
# Row families a timing record must keep emitting for the gate to mean
# anything; checked on the NEW record whenever it has a timing section.
# serve/ and ckpt/ ride along: the CI bench invocations that produce a
# timing section always run those sections too
# (--only smoke,timing,serve,ckpt,rooflines).
REQUIRED_ROW_PREFIXES = ("time/order/", "struct/", "shard/", "serve/",
                         "ckpt/", "perf/", "obs/", "plan/")
# Relative bands on the perf/* rows' derived metrics (new vs baseline,
# numeric plain floats — never gated absolutely, CI machines differ):
#   speedup    — wall-clock ratio (serial/pipelined, unfused/fused). The
#                0.5 band is calibrated to CPU-interpret noise: observed
#                run-to-run wobble is < 1.5x, a collapse to serial (or the
#                fused path silently unfusing) halves it or worse.
#   wire_ratio — fp32/int8 HLO all-reduce bytes (~3.9, deterministic).
#   hbm_ratio  — fused/unfused analytic bytes (< 1; HIGHER is worse, so
#                this one gates new > baseline / band).
PERF_BANDS = {"speedup": 0.5, "wire_ratio": 0.8}
PERF_BANDS_UPPER = {"hbm_ratio": 0.8}
# Absolute cap on obs/* rows' overhead_frac: the telemetry layer's disabled
# fast path may cost at most 5% of the reference dispatch it is wired into.
OBS_OVERHEAD_CAP = 0.05


def _rows_by_name(record: dict) -> dict:
    return {r["name"]: r for rows in record.get("sections", {}).values()
            for r in rows if isinstance(r, dict) and "name" in r}


def check(new: dict, base: dict) -> list[str]:
    """All gate violations of `new` vs the `base` baseline (empty = pass)."""
    errors = []
    if new.get("schema") != base.get("schema"):
        errors.append(f"schema drift: {new.get('schema')!r} != baseline "
                      f"{base.get('schema')!r}")
    missing = sorted(set(base.get("sections", {})) - set(new.get("sections", {})))
    if missing:
        errors.append(f"sections missing from new record: {missing}")
    for sec, rows in new.get("sections", {}).items():
        for r in rows:
            if not isinstance(r, dict) or not RECORD_KEYS <= set(r):
                errors.append(f"malformed record in section {sec!r}: "
                              f"{str(r)[:80]}")
    new_rows, base_rows = _rows_by_name(new), _rows_by_name(base)
    if "timing" in new.get("sections", {}):
        for prefix in REQUIRED_ROW_PREFIXES:
            if not any(name.startswith(prefix) for name in new_rows):
                errors.append(f"no rows with required prefix {prefix!r} in "
                              "new record: a gated row family vanished")
    gone = sorted(set(base_rows) - set(new_rows))
    if gone:
        errors.append(f"baseline rows missing from new record: {gone[:8]}")
    for name, brow in base_rows.items():
        nrow = new_rows.get(name)
        if nrow is None:
            continue
        for key in LAUNCH_KEYS:
            b = brow.get("derived", {}).get(key)
            if not isinstance(b, (int, float)):
                continue
            n = nrow.get("derived", {}).get(key)
            if not isinstance(n, (int, float)):
                # the metric vanishing must not evade the gate it feeds
                errors.append(f"{name}: launch metric {key} present in "
                              f"baseline but missing/non-numeric in new "
                              f"record ({n!r})")
            elif b > 0 and n > 2 * b:
                errors.append(f"{name}: {key} regressed {b} -> {n} (>2x)")
        if name.startswith("obs/"):
            frac = nrow.get("derived", {}).get("overhead_frac")
            has_base = isinstance(
                brow.get("derived", {}).get("overhead_frac"), (int, float))
            if has_base and not isinstance(frac, (int, float)):
                errors.append(f"{name}: overhead_frac present in baseline "
                              f"but missing/non-numeric in new record "
                              f"({frac!r})")
            elif isinstance(frac, (int, float)) and frac > OBS_OVERHEAD_CAP:
                errors.append(f"{name}: disabled-telemetry overhead_frac "
                              f"{frac} exceeds the absolute "
                              f"{OBS_OVERHEAD_CAP} budget")
        if not name.startswith("perf/"):
            continue
        for key, band in list(PERF_BANDS.items()) + list(
                PERF_BANDS_UPPER.items()):
            b = brow.get("derived", {}).get(key)
            if not isinstance(b, (int, float)):
                continue
            n = nrow.get("derived", {}).get(key)
            if not isinstance(n, (int, float)):
                errors.append(f"{name}: perf metric {key} present in "
                              f"baseline but missing/non-numeric in new "
                              f"record ({n!r})")
            elif key in PERF_BANDS_UPPER:
                if b > 0 and n > b / band:
                    errors.append(f"{name}: {key} regressed {b} -> {n} "
                                  f"(> baseline/{band})")
            elif b > 0 and n < band * b:
                errors.append(f"{name}: {key} regressed {b} -> {n} "
                              f"(< {band}x baseline)")
    return errors


def main(argv=None) -> None:
    args = sys.argv[1:] if argv is None else list(argv)
    if len(args) != 2:
        raise SystemExit("usage: python -m benchmarks.check_regression "
                         "NEW.json BASELINE.json")
    with open(args[0]) as f:
        new = json.load(f)
    with open(args[1]) as f:
        base = json.load(f)
    errors = check(new, base)
    for e in errors:
        print(f"BENCH-REGRESSION: {e}", file=sys.stderr)
    if errors:
        raise SystemExit(1)
    n_rows = len(_rows_by_name(new))
    print(f"bench-regression: OK ({new.get('schema')}, {n_rows} rows checked "
          f"against {len(_rows_by_name(base))} baseline rows)")


if __name__ == "__main__":
    main()
