"""Serving-engine benchmarks: trace replay, operator cache, retrieval sweep.

Three row families, all seeded and deterministic in structure:

  serve/trace/mixed — a mixed dense+TT+CP trace through the dynamic
      batcher under `rp.force_pallas()` + `rp.dispatch_stats()`; derived
      carries the GATED `launches_project` (one kernel dispatch per batcher
      tick — the engine's core claim), plus occupancy and latency
      percentiles of the flush policy.
  serve/cache       — operator-cache hit vs regeneration cost on a
      repeated-spec trace (hit rate is an acceptance criterion: >= 0.9).
  serve/query       — the brute-force-but-batched top-m similarity sweep
      over a large sketch store (one matmul tile sweep per query batch).
"""
import time

import numpy as np

from repro import rp
from repro.serve import ServeConfig, SketchServer, SketchStore, replay, \
    synth_trace

from ._util import csv_row, time_call

SPEC = rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2)


def _trace_row(rows, n_requests):
    cfg = ServeConfig(max_batch=8, flush_us=1_000.0)
    server = SketchServer(cfg, SketchStore(SPEC))
    trace = synth_trace(n_requests, [(SPEC, 0)], seed=3)
    with rp.dispatch_stats() as st, rp.force_pallas():
        rep = replay(server, trace)
    assert rep["requests_done"] == n_requests, rep
    assert st.kernel_calls == rep["ticks"], (st.kernel_calls, rep["ticks"])
    us = rep["wall_s"] * 1e6 / n_requests
    rows.append(csv_row(
        f"serve/trace/mixed/B={n_requests}", us,
        f"launches_project={st.kernel_calls};ticks={rep['ticks']};"
        f"requests={rep['requests_done']};"
        f"occupancy={rep['occupancy_mean']:.3f};"
        f"p50_us={rep['p50_us']:.0f};p99_us={rep['p99_us']:.0f};"
        f"hit_rate={rep['cache']['hit_rate']:.3f}"))


def _cache_row(rows, n_requests):
    # dense-only repeated-spec trace: every tick after the first is a cache
    # hit, so hit_rate -> 1 as the trace grows (acceptance: >= 0.9)
    cfg = ServeConfig(max_batch=4, flush_us=500.0)
    server = SketchServer(cfg)
    trace = synth_trace(n_requests, [(SPEC, 0)], mix=(1.0, 0.0, 0.0), seed=5)
    rep = replay(server, trace)
    c = rep["cache"]
    t0 = time.perf_counter()
    server.cache.get(SPEC, 0)                       # a pure LRU hit
    hit_us = (time.perf_counter() - t0) * 1e6
    regen_us = c["regen_s"] * 1e6 / max(c["misses"], 1)
    rows.append(csv_row(
        "serve/cache", hit_us,
        f"hits={c['hits']};misses={c['misses']};"
        f"hit_rate={c['hit_rate']:.3f};evictions={c['evictions']};"
        f"regen_us_per_miss={regen_us:.0f}"))
    assert c["hit_rate"] >= 0.9, c


def _query_row(rows, n_store, tile):
    store = SketchStore(SPEC, query_tile=tile)
    rng = np.random.default_rng(0)
    # ingest in slabs (the growable array doubles underneath)
    for start in range(0, n_store, 16384):
        b = min(16384, n_store - start)
        store.add(rng.standard_normal((b, SPEC.k)).astype(np.float32))
    q = rng.standard_normal((8, SPEC.k)).astype(np.float32)
    us = time_call(lambda: store.query(q, top_m=10), warmup=1, repeat=3)
    rows.append(csv_row(
        f"serve/query/n={n_store}", us,
        f"top_m=10;tile={tile};batch=8;"
        f"eps={store.eps_bound():.2f};mib={store.nbytes() / 2**20:.1f}"))


def run(fast=True):
    rows = []
    _trace_row(rows, 64)
    _cache_row(rows, 96 if fast else 512)
    _query_row(rows, 65_536 if fast else 1_048_576, 8_192)
    return rows
