"""Paper Fig. 2 + App. B.2: embedding time for medium-order inputs given in
TT or CP format, across the map family (TT/CP/sparse/dense) — plus the
batched-vs-per-bucket kernel comparison that tracks the sketcher hot path
(launch counts, wall time, analytic bytes moved) into BENCH_rp.json."""
import jax
import jax.numpy as jnp

from repro import rp
from repro.core import random_cp, random_tt

from ._util import csv_row, time_call


def _compiled_with_dispatch_count(fn, arg):
    """(compiled executable, Pallas dispatches traced) for fn(arg)."""
    c0 = rp.kernel_call_count()
    compiled = jax.jit(fn).lower(arg).compile()
    return compiled, rp.kernel_call_count() - c0


def _analytic_hbm_bytes(direction, family, k, b, dims, rank):
    """Grid-accurate analytic HBM traffic of ONE batched launch.

    Follows the BlockSpec index maps in kernels/{tt,cp}_{project,
    reconstruct}.py: a block is re-fetched whenever its index map changes
    between consecutive grid steps and stays resident otherwise.
    """
    from repro.kernels import pick_tiles
    d1, d2, d3 = dims
    tk, tb, ba = pick_tiles(k, b, dims, rank, kind=direction, family=family)
    nk, nb_t, na = -(-k // tk), -(-b // tb), -(-d1 // ba)
    x_total = b * d1 * d2 * d3 * 4
    y_total = b * k * 4
    if family == "tt":
        c1, c2, c3 = k * d1 * rank * 4, k * rank * d2 * rank * 4, \
            k * rank * d3 * 4
    else:
        c1, c2, c3 = k * d1 * rank * 4, k * d2 * rank * 4, k * d3 * rank * 4
    if direction == "project":
        # grid (ik, ib, ia): x re-streamed once per k-tile; the ia-indexed
        # leading core once per batch tile; g2/g3 resident per k-tile.
        return nk * x_total + nb_t * c1 + c2 + c3 + y_total
    # grid (ib, ia, ik): y re-fetched once per d1-tile; leading core once
    # per batch tile; trailing cores re-streamed per (batch, d1) tile.
    return na * y_total + nb_t * c1 + nb_t * na * (c2 + c3) + x_total


def _batched_vs_per_bucket(rows, fast=True):
    """One batched launch per leaf vs the per-bucket formulations.

    A 16-bucket "leaf" runs through three schedules per direction:
      * per_bucket — one `pallas_call` dispatch per bucket (a Python loop of
        16 single-bucket calls): the per-bucket launch count the batch axis
        exists to eliminate;
      * vmap — `jax.vmap` over single-bucket kernels, the pre-batch sketcher
        formulation (one dispatch at trace time; the batch dim is grafted on
        by the vmap batching rule rather than placed by the BlockSpecs);
      * batched — the native batch grid axis: ONE dispatch, cores streamed
        once per k-tile.
    Launch counts come from rp.kernel_call_count() (dispatch-time
    instrumentation); bytes are the grid-accurate analytic HBM traffic of
    the per-bucket vs batched schedules (_analytic_hbm_bytes — the
    per-bucket schedule re-streams the whole operator every bucket, the
    batched grid amortizes core fetches over the batch tile). Wall-clock
    `speedup` is batched vs vmap — meaningful on TPU, noisy in CPU
    interpret mode.
    """
    nb = 16                      # the acceptance-criteria bucket count
    dims = (8, 16, 16) if fast else (32, 64, 32)
    k = 128
    rank = 2
    key = jax.random.PRNGKey(0)
    xb = jax.random.normal(jax.random.fold_in(key, 1), (nb,) + dims)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
            jax.random.fold_in(key, 2))

        def apply(direction, y_or_x, op=op):
            fn = rp.project if direction == "project" else rp.reconstruct
            return fn(op, y_or_x, backend="auto")

        for direction, inp in (("project", xb),
                               ("reconstruct", apply("project", xb))):
            def per_bucket(a, d=direction):
                with rp.force_pallas():
                    return jnp.stack([apply(d, a[i]) for i in range(nb)])

            def vmapped(a, d=direction):
                with rp.force_pallas():
                    return jax.vmap(lambda t: apply(d, t))(a)

            def batched(a, d=direction):
                with rp.force_pallas():
                    return apply(d, a)

            f_pb, launches_pb = _compiled_with_dispatch_count(per_bucket, inp)
            f_vm, launches_vm = _compiled_with_dispatch_count(vmapped, inp)
            f_b, launches_b = _compiled_with_dispatch_count(batched, inp)
            us_pb = time_call(f_pb, inp)
            us_vm = time_call(f_vm, inp)
            us_b = time_call(f_b, inp)
            bytes_pb = nb * _analytic_hbm_bytes(direction, family, k, 1,
                                                dims, rank)
            bytes_b = _analytic_hbm_bytes(direction, family, k, nb,
                                          dims, rank)
            rows.append(csv_row(
                f"time/batched/{family}/{direction}/B={nb}", us_b,
                f"launches_batched={launches_b};"
                f"launches_per_bucket={launches_pb};"
                f"launches_vmap={launches_vm};"
                f"launch_reduction={launches_pb / max(1, launches_b):.1f}x;"
                f"us_per_bucket_path={us_pb:.1f};us_vmap_path={us_vm:.1f};"
                f"speedup={us_vm / us_b:.2f}x;"
                f"bytes_batched={bytes_b};bytes_per_bucket={bytes_pb}"))


def run(fast=True):
    d, N = 3, 12 if fast else 12
    dims = (d,) * N
    D = d ** N
    k = 256
    key = jax.random.PRNGKey(0)
    x_tt = random_tt(key, dims, 10, norm="unit")
    x_cp = random_cp(key, dims, 10, norm="unit")
    x_dense = x_tt.full().reshape(-1)

    def op(family, fold, rank=1):
        spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank)
        return rp.make_projector(spec, jax.random.fold_in(key, fold))

    tt_op = op("tt", 1, 5)
    cp_op = op("cp", 2, 25)
    rows = []

    for name, o, inp, tag in [
        ("TT(5)", tt_op, x_tt, "input=TT"),
        ("CP(25)", cp_op, x_tt, "input=TT"),
        ("TT(5)", tt_op, x_cp, "input=CP"),
        ("CP(25)", cp_op, x_cp, "input=CP"),
        ("VerySparse", op("sparse", 3), x_dense, "input=dense"),
        ("Gaussian", op("gaussian", 4), x_dense, "input=dense"),
    ]:
        f = jax.jit(lambda t, o=o: rp.project(o, t))
        rows.append(csv_row(f"time/medium/{name}/{tag}", time_call(f, inp),
                            f"k={k};D={D}"))

    # App B.2: scaling in N (input dim d^N)
    for n in ((8, 11, 12) if fast else (8, 11, 12, 13)):
        dims_n = (3,) * n
        x_n = random_tt(jax.random.fold_in(key, n), dims_n, 10)
        op_n = rp.make_projector(
            rp.ProjectorSpec(family="tt", k=k, dims=dims_n, rank=5),
            jax.random.fold_in(key, 100 + n))
        f = jax.jit(lambda t: rp.project(op_n, t))
        rows.append(csv_row(f"time/scaling/TT(5)/N={n}", time_call(f, x_n),
                            f"D={3**n}"))

    _batched_vs_per_bucket(rows, fast=fast)
    return rows
