"""Paper Fig. 2 + App. B.2: embedding time for medium-order inputs given in
TT or CP format, across the map family (TT/CP/sparse/dense) — plus the
batched-vs-per-bucket kernel comparison that tracks the sketcher hot path
(launch counts, wall time, analytic bytes moved), the TT-vs-CP-vs-order
frontier (time/order/* rows, N in {2,3,4,5}), the compressed-domain
structured-input rows (struct/{tt,cp}x{tt,cp}/N={3,4}: carry-sweep launch
counts, carry bytes, analytic speedup), and the sharded-engine rows
(shard/*: compress_collective wire bytes per sync mode + measured HLO
all-reduce bytes, project_sharded per-device bucket counts) into
BENCH_rp.json."""
import jax
import jax.numpy as jnp

from repro import rp
from repro.core import (BatchedCPTensor, BatchedTTTensor, random_cp,
                        random_tt, theory)

from ._util import csv_row, time_call


def _compiled_with_dispatch_count(fn, *args):
    """(compiled executable, Pallas dispatches traced) for fn(*args)."""
    c0 = rp.kernel_call_count()
    compiled = jax.jit(fn).lower(*args).compile()
    return compiled, rp.kernel_call_count() - c0


def _analytic_hbm_bytes(direction, family, k, b, dims, rank):
    """Grid-accurate analytic HBM traffic of ONE batched launch, any order.

    Follows the BlockSpec index maps the planner lays out in
    kernels/_sweep.py: a block is re-fetched whenever its index map changes
    between consecutive grid steps and stays resident otherwise.
    """
    from repro.kernels import plan_contraction
    plan = plan_contraction(family, direction, k, b, dims, rank)
    nk, nb_t, na = (-(-k // plan.tk), -(-b // plan.tb),
                    -(-dims[0] // plan.ba))
    x_total = b * 4
    for d in dims:
        x_total *= d
    y_total = b * k * 4
    c1 = k * dims[0] * rank * 4            # leading core, ia-indexed
    if family == "tt":
        c_rest = (sum(k * rank * d * rank * 4 for d in dims[1:-1])
                  + k * rank * dims[-1] * 4)
    else:
        c_rest = sum(k * d * rank * 4 for d in dims[1:])
    if direction == "project":
        # grid (ik, ib, ia): x re-streamed once per k-tile; the ia-indexed
        # leading core once per batch tile; trailing cores resident per
        # k-tile.
        return nk * x_total + nb_t * c1 + c_rest + y_total
    # grid (ib, ia, ik): y re-fetched once per d1-tile; leading core once
    # per batch tile; trailing cores re-streamed per (batch, d1) tile.
    return na * y_total + nb_t * c1 + nb_t * na * c_rest + x_total


def _order_frontier(rows, fast=True):
    """The TT-vs-CP-vs-order frontier the order-N kernel layer unlocks.

    One batched Pallas (interpret off-TPU) launch per (family, N, direction)
    for N in {2,..,5} at fixed k/rank: `params` shows the operator shrinking
    as the same-size bucket is tensorized into more, smaller modes (core
    params scale with the SUM of the modes, not their product), and
    `var_factor` / `var_ratio_cp_tt` chart the Thm-1 cost CP pays for that
    at each order. `launches_*` prove the mode-sweep route (one dispatch per
    batched call at every order). Wall-clock is meaningful on TPU, noisy in
    CPU interpret mode.
    """
    del fast
    k, rank, b = 128, 2, 4
    dims_by_n = {2: (64, 64), 3: (16, 16, 16), 4: (8, 8, 8, 8),
                 5: (8, 8, 8, 8, 8)}
    key = jax.random.PRNGKey(7)
    for n, dims in dims_by_n.items():
        xb = jax.random.normal(jax.random.fold_in(key, n), (b,) + dims)
        for family in ("tt", "cp"):
            op = rp.make_projector(
                rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
                jax.random.fold_in(key, 10 * n))

            def project(a, op=op):
                return rp.project(op, a, backend="pallas")

            def reconstruct(y, op=op):
                return rp.reconstruct(op, y, backend="pallas")

            f_p, launches_p = _compiled_with_dispatch_count(project, xb)
            us_p = time_call(f_p, xb)
            yb = f_p(xb)
            f_r, launches_r = _compiled_with_dispatch_count(reconstruct, yb)
            us_r = time_call(f_r, yb)
            rows.append(csv_row(
                f"time/order/{family}/N={n}", us_p,
                f"dims={'x'.join(map(str, dims))};k={k};rank={rank};B={b};"
                f"launches_project={launches_p};"
                f"launches_reconstruct={launches_r};"
                f"us_reconstruct={us_r:.1f};"
                f"params={theory.params_rp(family, k, dims, rank)};"
                f"var_factor={theory.variance_factor(family, N=n, R=rank):.2f};"
                f"var_ratio_cp_tt={theory.variance_ratio_cp_to_tt(n, rank):.2f}"))


def _struct_frontier(rows, fast=True):
    """Compressed-domain engine rows: struct/{tt,cp}x{tt,cp}/N={3,4}.

    One batched carry-sweep Pallas (interpret off-TPU) launch per
    (operator family, input family, order) — the four structured pairings
    `rp.project` routes through `kernels/struct/`. Each row records the
    dispatch count (`launches_project`, must stay 1 per batched call — the
    bench gate's launch keys cover it), the carried bond-state bytes
    (`carry_bytes` = B·k·R·R~ floats, the memory that replaces the dense
    sweep's (B, k, d2..dN) intermediates), operator `params`, and the
    ANALYTIC dense/structured FLOP ratio (`analytic_speedup`,
    `theory.struct_speedup`) so the record carries the model's prediction
    next to measured wall-clock (meaningful on TPU, noisy in CPU interpret
    mode).
    """
    del fast
    k, r_op, r_in, b = 128, 2, 4, 4
    dims_by_n = {3: (16, 16, 16), 4: (8, 8, 8, 8)}
    key = jax.random.PRNGKey(11)
    for n, dims in dims_by_n.items():
        for in_family in ("tt", "cp"):
            mk = random_tt if in_family == "tt" else random_cp
            items = [mk(jax.random.fold_in(key, 100 * n + i), dims, r_in)
                     for i in range(b)]
            stack = (BatchedTTTensor.stack if in_family == "tt"
                     else BatchedCPTensor.stack)
            xb = stack(items)
            for op_family in ("tt", "cp"):
                op = rp.make_projector(
                    rp.ProjectorSpec(family=op_family, k=k, dims=dims,
                                     rank=r_op),
                    jax.random.fold_in(key, 10 * n))

                def project(x, op=op):
                    return rp.project(op, x, backend="pallas")

                f, launches = _compiled_with_dispatch_count(project, xb)
                us = time_call(f, xb)
                fl = theory.flops_project_struct(op_family, in_family, k,
                                                 dims, r_op, r_in)
                speedup = theory.struct_speedup(op_family, in_family, k,
                                                dims, r_op, r_in)
                rows.append(csv_row(
                    f"struct/{op_family}x{in_family}/N={n}", us,
                    f"dims={'x'.join(map(str, dims))};k={k};B={b};"
                    f"r_op={r_op};r_in={r_in};"
                    f"launches_project={launches};"
                    f"carry_bytes={theory.mem_carry_struct(k, r_op, r_in, batch=b)};"
                    f"params={theory.params_rp(op_family, k, dims, r_op)};"
                    f"flops_struct={fl};"
                    f"analytic_speedup={speedup:.1f}x"))


def _shard_rows(rows, fast=True):
    """Sharded sketching engine rows (shard/*).

    Runs the `compress_collective` cross-pod compressed all-reduce and the
    `project_sharded` bucket-axis path on a pod mesh over EVERY available
    device (1 on the plain CI job, 8 under the multi-device job's
    XLA_FLAGS=--xla_force_host_platform_device_count=8). Row names and the
    gated trace-time launch counts are device-count-independent, so
    `check_regression` can diff records across both jobs; per-device bucket
    counts, npod, the analytic wire bytes of the active sync mode, and the
    MEASURED HLO all-reduce bytes (the pmean's channel all-reduce op is
    retained even on a 1-device mesh, so the bytes match across jobs; only
    the replica-group size differs) land in `derived` for the record.
    """
    del fast
    from repro.core.sketch import PytreeSketcher, SketchConfig
    from repro.launch.roofline import parse_collectives
    from repro.optim.compress import SketchCompressor

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("pod",))
    cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=8 * 16 * 16,
                       dims=(8, 16, 16))
    key = jax.random.PRNGKey(23)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 0), (ndev, 4096)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (ndev, 100))}
    state = {"residual": jax.tree.map(jnp.zeros_like, g)}
    sk = PytreeSketcher(cfg, jax.tree.map(lambda x: x[0], g))
    for sync in ("sketch-mean", "local-mean"):
        comp = SketchCompressor(cfg, sync=sync, pod_axis="pod")

        def run_step(gg, ss, step, comp=comp):
            # metrics dropped so their telemetry reductions DCE away and
            # the HLO collective count is exactly the sync pmean
            with rp.force_pallas():
                return comp.compress_collective(gg, ss, step=step,
                                                mesh=mesh)[:2]

        f, launches = _compiled_with_dispatch_count(run_step, g, state, 0)
        us = time_call(f, g, state, 0)
        ar = parse_collectives(f.as_text())["per_type"].get(
            "all-reduce", {"count": 0, "bytes": 0.0})
        wire = (sk.sketch_bytes() if sync == "sketch-mean"
                else sk.dense_bytes())
        rows.append(csv_row(
            f"shard/collective/sync={sync}", us,
            f"npod={ndev};n_buckets={sk.n_buckets};k={cfg.k};"
            f"launches_project={launches};"
            f"wire_bytes={wire};"
            f"hlo_allreduce_bytes={int(ar['bytes'])};"
            f"hlo_allreduce_count={ar['count']}"))

    nb = 16
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2),
        jax.random.fold_in(key, 2))
    xb = jax.random.normal(jax.random.fold_in(key, 3), (nb, 8, 16, 16))

    def proj(x):
        with rp.force_pallas():
            return rp.project_sharded(op, x, mesh=mesh)

    f_p, launches_p = _compiled_with_dispatch_count(proj, xb)
    us_p = time_call(f_p, xb)
    rows.append(csv_row(
        f"shard/project/B={nb}", us_p,
        f"npod={ndev};buckets_per_device={nb // ndev};"
        f"launches_project={launches_p};k=128"))


def _batched_vs_per_bucket(rows, fast=True):
    """One batched launch per leaf vs the per-bucket formulations.

    A 16-bucket "leaf" runs through three schedules per direction:
      * per_bucket — one `pallas_call` dispatch per bucket (a Python loop of
        16 single-bucket calls): the per-bucket launch count the batch axis
        exists to eliminate;
      * vmap — `jax.vmap` over single-bucket kernels, the pre-batch sketcher
        formulation (one dispatch at trace time; the batch dim is grafted on
        by the vmap batching rule rather than placed by the BlockSpecs);
      * batched — the native batch grid axis: ONE dispatch, cores streamed
        once per k-tile.
    Launch counts come from rp.kernel_call_count() (dispatch-time
    instrumentation); bytes are the grid-accurate analytic HBM traffic of
    the per-bucket vs batched schedules (_analytic_hbm_bytes — the
    per-bucket schedule re-streams the whole operator every bucket, the
    batched grid amortizes core fetches over the batch tile). Wall-clock
    `speedup` is batched vs vmap — meaningful on TPU, noisy in CPU
    interpret mode.
    """
    nb = 16                      # the acceptance-criteria bucket count
    dims = (8, 16, 16) if fast else (32, 64, 32)
    k = 128
    rank = 2
    key = jax.random.PRNGKey(0)
    xb = jax.random.normal(jax.random.fold_in(key, 1), (nb,) + dims)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
            jax.random.fold_in(key, 2))

        def apply(direction, y_or_x, op=op):
            fn = rp.project if direction == "project" else rp.reconstruct
            return fn(op, y_or_x, backend="auto")

        for direction, inp in (("project", xb),
                               ("reconstruct", apply("project", xb))):
            def per_bucket(a, d=direction):
                with rp.force_pallas():
                    return jnp.stack([apply(d, a[i]) for i in range(nb)])

            def vmapped(a, d=direction):
                with rp.force_pallas():
                    return jax.vmap(lambda t: apply(d, t))(a)

            def batched(a, d=direction):
                with rp.force_pallas():
                    return apply(d, a)

            f_pb, launches_pb = _compiled_with_dispatch_count(per_bucket, inp)
            f_vm, launches_vm = _compiled_with_dispatch_count(vmapped, inp)
            f_b, launches_b = _compiled_with_dispatch_count(batched, inp)
            us_pb = time_call(f_pb, inp)
            us_vm = time_call(f_vm, inp)
            us_b = time_call(f_b, inp)
            bytes_pb = nb * _analytic_hbm_bytes(direction, family, k, 1,
                                                dims, rank)
            bytes_b = _analytic_hbm_bytes(direction, family, k, nb,
                                          dims, rank)
            rows.append(csv_row(
                f"time/batched/{family}/{direction}/B={nb}", us_b,
                f"launches_batched={launches_b};"
                f"launches_per_bucket={launches_pb};"
                f"launches_vmap={launches_vm};"
                f"launch_reduction={launches_pb / max(1, launches_b):.1f}x;"
                f"us_per_bucket_path={us_pb:.1f};us_vmap_path={us_vm:.1f};"
                f"speedup={us_vm / us_b:.2f}x;"
                f"bytes_batched={bytes_b};bytes_per_bucket={bytes_pb}"))


def run(fast=True):
    d, N = 3, 12 if fast else 12
    dims = (d,) * N
    D = d ** N
    k = 256
    key = jax.random.PRNGKey(0)
    x_tt = random_tt(key, dims, 10, norm="unit")
    x_cp = random_cp(key, dims, 10, norm="unit")
    x_dense = x_tt.full().reshape(-1)

    def op(family, fold, rank=1):
        spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank)
        return rp.make_projector(spec, jax.random.fold_in(key, fold))

    tt_op = op("tt", 1, 5)
    cp_op = op("cp", 2, 25)
    rows = []

    for name, o, inp, tag in [
        ("TT(5)", tt_op, x_tt, "input=TT"),
        ("CP(25)", cp_op, x_tt, "input=TT"),
        ("TT(5)", tt_op, x_cp, "input=CP"),
        ("CP(25)", cp_op, x_cp, "input=CP"),
        ("VerySparse", op("sparse", 3), x_dense, "input=dense"),
        ("Gaussian", op("gaussian", 4), x_dense, "input=dense"),
    ]:
        f = jax.jit(lambda t, o=o: rp.project(o, t))
        rows.append(csv_row(f"time/medium/{name}/{tag}", time_call(f, inp),
                            f"k={k};D={D}"))

    # App B.2: scaling in N (input dim d^N)
    for n in ((8, 11, 12) if fast else (8, 11, 12, 13)):
        dims_n = (3,) * n
        x_n = random_tt(jax.random.fold_in(key, n), dims_n, 10)
        op_n = rp.make_projector(
            rp.ProjectorSpec(family="tt", k=k, dims=dims_n, rank=5),
            jax.random.fold_in(key, 100 + n))
        f = jax.jit(lambda t: rp.project(op_n, t))
        rows.append(csv_row(f"time/scaling/TT(5)/N={n}", time_call(f, x_n),
                            f"D={3**n}"))

    _batched_vs_per_bucket(rows, fast=fast)
    _order_frontier(rows, fast=fast)
    _struct_frontier(rows, fast=fast)
    _shard_rows(rows, fast=fast)
    return rows
