"""Paper Fig. 2 + App. B.2: embedding time for medium-order inputs given in
TT or CP format, across the map family (TT/CP/sparse/dense) — plus the
batched-vs-per-bucket kernel comparison that tracks the sketcher hot path
(launch counts, wall time, analytic bytes moved), the TT-vs-CP-vs-order
frontier (time/order/* rows, N in {2,3,4,5}), the compressed-domain
structured-input rows (struct/{tt,cp}x{tt,cp}/N={3,4}: carry-sweep launch
counts, carry bytes, analytic speedup), the sharded-engine rows
(shard/*: compress_collective wire bytes per sync mode + measured HLO
all-reduce bytes, project_sharded per-device bucket counts), and the
kernel perf-frontier rows (perf/*: double-buffered pipelining vs serial,
fused unsketch+EF+AdamW vs the unfused chain, int8 vs fp32 wire — see
`_perf_rows`) into BENCH_rp.json."""
import jax
import jax.numpy as jnp

from repro import rp
from repro.core import (BatchedCPTensor, BatchedTTTensor, random_cp,
                        random_tt)

from ._util import csv_row, time_call


def _compiled_with_dispatch_count(fn, *args):
    """(compiled executable, Pallas dispatches traced) for fn(*args)."""
    c0 = rp.kernel_call_count()
    compiled = jax.jit(fn).lower(*args).compile()
    return compiled, rp.kernel_call_count() - c0


def _kernel_plan(direction, family, k, b, dims, rank, *, pipeline="serial"):
    """The pinned-kernel-route `ExecutionPlan` of ONE batched launch.

    All analytic values in these rows (hbm bytes, flops, params, variance
    factors, grid shapes) are read from `plan.cost` / the plan's tiles —
    the SAME resolver every dispatch goes through — so the bench rows, the
    rooflines, and the kernels' own schedules can never disagree on what a
    launch streams.
    """
    sig = rp.StructureSig(
        structure="sketch" if direction == "reconstruct" else "dense",
        batch=b)
    return rp.plan_execution(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank), sig,
        kind=direction, backend="pallas", pipeline=pipeline)


def _analytic_hbm_bytes(direction, family, k, b, dims, rank):
    """Grid-accurate analytic HBM traffic of ONE batched launch, any order
    (the plan ledger of the schedule the launch would actually use)."""
    return _kernel_plan(direction, family, k, b, dims, rank).cost.hbm_bytes


def _order_frontier(rows, fast=True):
    """The TT-vs-CP-vs-order frontier the order-N kernel layer unlocks.

    One batched Pallas (interpret off-TPU) launch per (family, N, direction)
    for N in {2,..,5} at fixed k/rank: `params` shows the operator shrinking
    as the same-size bucket is tensorized into more, smaller modes (core
    params scale with the SUM of the modes, not their product), and
    `var_factor` / `var_ratio_cp_tt` chart the Thm-1 cost CP pays for that
    at each order. `launches_*` prove the mode-sweep route (one dispatch per
    batched call at every order). Wall-clock is meaningful on TPU, noisy in
    CPU interpret mode.
    """
    del fast
    k, rank, b = 128, 2, 4
    dims_by_n = {2: (64, 64), 3: (16, 16, 16), 4: (8, 8, 8, 8),
                 5: (8, 8, 8, 8, 8)}
    key = jax.random.PRNGKey(7)
    for n, dims in dims_by_n.items():
        xb = jax.random.normal(jax.random.fold_in(key, n), (b,) + dims)
        # the Thm-1 CP/TT ratio is the quotient of the two plans' ledgers
        eplans = {fam: _kernel_plan("project", fam, k, b, dims, rank)
                  for fam in ("tt", "cp")}
        var_ratio = (eplans["cp"].cost.var_factor
                     / eplans["tt"].cost.var_factor)
        for family in ("tt", "cp"):
            op = rp.make_projector(
                rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
                jax.random.fold_in(key, 10 * n))

            def project(a, op=op):
                return rp.project(op, a, backend="pallas")

            def reconstruct(y, op=op):
                return rp.reconstruct(op, y, backend="pallas")

            f_p, launches_p = _compiled_with_dispatch_count(project, xb)
            us_p = time_call(f_p, xb)
            yb = f_p(xb)
            f_r, launches_r = _compiled_with_dispatch_count(reconstruct, yb)
            us_r = time_call(f_r, yb)
            cost = eplans[family].cost
            rows.append(csv_row(
                f"time/order/{family}/N={n}", us_p,
                f"dims={'x'.join(map(str, dims))};k={k};rank={rank};B={b};"
                f"launches_project={launches_p};"
                f"launches_reconstruct={launches_r};"
                f"us_reconstruct={us_r:.1f};"
                f"params={cost.params};"
                f"var_factor={cost.var_factor:.2f};"
                f"var_ratio_cp_tt={var_ratio:.2f}"))


def _struct_frontier(rows, fast=True):
    """Compressed-domain engine rows: struct/{tt,cp}x{tt,cp}/N={3,4}.

    One batched carry-sweep Pallas (interpret off-TPU) launch per
    (operator family, input family, order) — the four structured pairings
    `rp.project` routes through `kernels/struct/`. Each row records the
    dispatch count (`launches_project`, must stay 1 per batched call — the
    bench gate's launch keys cover it), the carried bond-state bytes
    (`carry_bytes` = B·k·R·R~ floats, the memory that replaces the dense
    sweep's (B, k, d2..dN) intermediates), operator `params`, and the
    ANALYTIC dense/structured FLOP ratio (`analytic_speedup`,
    `theory.struct_speedup`) so the record carries the model's prediction
    next to measured wall-clock (meaningful on TPU, noisy in CPU interpret
    mode).
    """
    del fast
    k, r_op, r_in, b = 128, 2, 4, 4
    dims_by_n = {3: (16, 16, 16), 4: (8, 8, 8, 8)}
    key = jax.random.PRNGKey(11)
    for n, dims in dims_by_n.items():
        for in_family in ("tt", "cp"):
            mk = random_tt if in_family == "tt" else random_cp
            items = [mk(jax.random.fold_in(key, 100 * n + i), dims, r_in)
                     for i in range(b)]
            stack = (BatchedTTTensor.stack if in_family == "tt"
                     else BatchedCPTensor.stack)
            xb = stack(items)
            for op_family in ("tt", "cp"):
                op = rp.make_projector(
                    rp.ProjectorSpec(family=op_family, k=k, dims=dims,
                                     rank=r_op),
                    jax.random.fold_in(key, 10 * n))

                def project(x, op=op):
                    return rp.project(op, x, backend="pallas")

                f, launches = _compiled_with_dispatch_count(project, xb)
                us = time_call(f, xb)
                # the plan the dispatch above resolved (a cache hit here);
                # analytic_speedup is its dense counterpart's flops over its
                # own — the same quotient theory.struct_speedup charts
                ep = rp.plan_execution(
                    op, rp.StructureSig(structure=in_family, batch=b,
                                        in_rank=r_in), backend="pallas")
                speedup = (_kernel_plan("project", op_family, k, b, dims,
                                        r_op).cost.flops / ep.cost.flops)
                rows.append(csv_row(
                    f"struct/{op_family}x{in_family}/N={n}", us,
                    f"dims={'x'.join(map(str, dims))};k={k};B={b};"
                    f"r_op={r_op};r_in={r_in};"
                    f"launches_project={launches};"
                    f"carry_bytes={ep.carry_bytes};"
                    f"params={ep.cost.params};"
                    f"flops_struct={ep.cost.flops // b};"
                    f"analytic_speedup={speedup:.1f}x"))


def _shard_rows(rows, fast=True):
    """Sharded sketching engine rows (shard/*).

    Runs the `compress_collective` cross-pod compressed all-reduce and the
    `project_sharded` bucket-axis path on a pod mesh over EVERY available
    device (1 on the plain CI job, 8 under the multi-device job's
    XLA_FLAGS=--xla_force_host_platform_device_count=8). Row names and the
    gated trace-time launch counts are device-count-independent, so
    `check_regression` can diff records across both jobs; per-device bucket
    counts, npod, the analytic wire bytes of the active sync mode, and the
    MEASURED HLO all-reduce bytes (the pmean's channel all-reduce op is
    retained even on a 1-device mesh, so the bytes match across jobs; only
    the replica-group size differs) land in `derived` for the record.
    """
    del fast
    from repro.core.sketch import PytreeSketcher, SketchConfig
    from repro.launch.roofline import parse_collectives
    from repro.optim.compress import SketchCompressor

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("pod",))
    cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=8 * 16 * 16,
                       dims=(8, 16, 16))
    key = jax.random.PRNGKey(23)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 0), (ndev, 4096)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (ndev, 100))}
    state = {"residual": jax.tree.map(jnp.zeros_like, g)}
    sk = PytreeSketcher(cfg, jax.tree.map(lambda x: x[0], g))
    for sync in ("sketch-mean", "local-mean"):
        comp = SketchCompressor(cfg, sync=sync, pod_axis="pod")

        def run_step(gg, ss, step, comp=comp):
            # metrics dropped so their telemetry reductions DCE away and
            # the HLO collective count is exactly the sync pmean
            with rp.force_pallas():
                return comp.compress_collective(gg, ss, step=step,
                                                mesh=mesh)[:2]

        f, launches = _compiled_with_dispatch_count(run_step, g, state, 0)
        us = time_call(f, g, state, 0)
        ar = parse_collectives(f.as_text())["per_type"].get(
            "all-reduce", {"count": 0, "bytes": 0.0})
        wire = comp.wire_bytes(sk)      # the plan layer's wire ledger
        rows.append(csv_row(
            f"shard/collective/sync={sync}", us,
            f"npod={ndev};n_buckets={sk.n_buckets};k={cfg.k};"
            f"launches_project={launches};"
            f"wire_bytes={wire};"
            f"hlo_allreduce_bytes={int(ar['bytes'])};"
            f"hlo_allreduce_count={ar['count']}"))

    nb = 16
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2),
        jax.random.fold_in(key, 2))
    xb = jax.random.normal(jax.random.fold_in(key, 3), (nb, 8, 16, 16))

    def proj(x):
        with rp.force_pallas():
            return rp.project_sharded(op, x, mesh=mesh)

    f_p, launches_p = _compiled_with_dispatch_count(proj, xb)
    us_p = time_call(f_p, xb)
    rows.append(csv_row(
        f"shard/project/B={nb}", us_p,
        f"npod={ndev};buckets_per_device={nb // ndev};"
        f"launches_project={launches_p};k=128"))


def _batched_vs_per_bucket(rows, fast=True):
    """One batched launch per leaf vs the per-bucket formulations.

    A 16-bucket "leaf" runs through three schedules per direction:
      * per_bucket — one `pallas_call` dispatch per bucket (a Python loop of
        16 single-bucket calls): the per-bucket launch count the batch axis
        exists to eliminate;
      * vmap — `jax.vmap` over single-bucket kernels, the pre-batch sketcher
        formulation (one dispatch at trace time; the batch dim is grafted on
        by the vmap batching rule rather than placed by the BlockSpecs);
      * batched — the native batch grid axis: ONE dispatch, cores streamed
        once per k-tile.
    Launch counts come from rp.kernel_call_count() (dispatch-time
    instrumentation); bytes are the grid-accurate analytic HBM traffic of
    the per-bucket vs batched schedules (_analytic_hbm_bytes — the
    per-bucket schedule re-streams the whole operator every bucket, the
    batched grid amortizes core fetches over the batch tile). Wall-clock
    `speedup` is batched vs vmap — meaningful on TPU, noisy in CPU
    interpret mode.
    """
    nb = 16                      # the acceptance-criteria bucket count
    dims = (8, 16, 16) if fast else (32, 64, 32)
    k = 128
    rank = 2
    key = jax.random.PRNGKey(0)
    xb = jax.random.normal(jax.random.fold_in(key, 1), (nb,) + dims)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
            jax.random.fold_in(key, 2))

        def apply(direction, y_or_x, op=op):
            fn = rp.project if direction == "project" else rp.reconstruct
            return fn(op, y_or_x, backend="auto")

        for direction, inp in (("project", xb),
                               ("reconstruct", apply("project", xb))):
            def per_bucket(a, d=direction):
                with rp.force_pallas():
                    return jnp.stack([apply(d, a[i]) for i in range(nb)])

            def vmapped(a, d=direction):
                with rp.force_pallas():
                    return jax.vmap(lambda t: apply(d, t))(a)

            def batched(a, d=direction):
                with rp.force_pallas():
                    return apply(d, a)

            f_pb, launches_pb = _compiled_with_dispatch_count(per_bucket, inp)
            f_vm, launches_vm = _compiled_with_dispatch_count(vmapped, inp)
            f_b, launches_b = _compiled_with_dispatch_count(batched, inp)
            us_pb = time_call(f_pb, inp)
            us_vm = time_call(f_vm, inp)
            us_b = time_call(f_b, inp)
            bytes_pb = nb * _analytic_hbm_bytes(direction, family, k, 1,
                                                dims, rank)
            bytes_b = _analytic_hbm_bytes(direction, family, k, nb,
                                          dims, rank)
            rows.append(csv_row(
                f"time/batched/{family}/{direction}/B={nb}", us_b,
                f"launches_batched={launches_b};"
                f"launches_per_bucket={launches_pb};"
                f"launches_vmap={launches_vm};"
                f"launch_reduction={launches_pb / max(1, launches_b):.1f}x;"
                f"us_per_bucket_path={us_pb:.1f};us_vmap_path={us_vm:.1f};"
                f"speedup={us_vm / us_b:.2f}x;"
                f"bytes_batched={bytes_b};bytes_per_bucket={bytes_pb}"))


def _dense_entry_fusions(hlo_text, shape):
    """Standalone dense elementwise kernels in the ENTRY computation.

    Counts optimized-HLO `fusion` ops in ENTRY whose result is the full
    dense `shape` — the EF/AdamW elementwise passes XLA launches as their
    own kernels in the unfused chain and that disappear entirely into the
    Pallas launch in the fused one (0 vs 4 on the bench shapes; the gate
    pins the fused count staying at 0 via the perf row's derived keys).
    """
    import re
    entry = re.search(r"ENTRY [^{]+\{(.*?)\n\}", hlo_text, re.S)
    if entry is None:
        return -1
    sig = "f32[" + ",".join(map(str, shape)) + "]"
    return sum(1 for line in entry.group(1).splitlines()
               if " fusion(" in line and line.lstrip().split(" = ")[-1]
               .startswith(sig))


def _perf_rows(rows, fast=True):
    """Kernel perf frontier rows (perf/*) — the wall-clock-gated trio.

    * perf/pipeline/sweep/{tt,cp} and perf/pipeline/carry/{tt,cp} — the
      double-buffered DMA schedule vs the serial one on shapes with real
      overlap to win (d1/ba > 1 grid steps for the sweep, b/tb > 1 for the
      carry). `speedup` is a PLAIN float (serial us / pipelined us) so the
      gate can band it; in CPU interpret mode the DMA emulation makes it
      hover near 1.0 — the 0.5x relative band catches collapses, TPU runs
      show the overlap.
    * perf/fused/update/{tt,cp} — ONE fused unsketch+EF+AdamW launch vs
      the unfused reconstruct -> EF -> AdamW chain on the same buckets.
      `speedup` (unfused us / fused us) rides the same band; `hbm_ratio`
      (fused/unfused analytic bytes from the planner ledger, < 1) and the
      standalone dense elementwise kernel counts (`dense_kernels_fused=0`
      vs `dense_kernels_unfused=4` — the EF/AdamW passes XLA launches as
      its own fusions collapse into the Pallas call) are deterministic.
    * perf/wire/sync={sketch-mean,local-mean} — compress_collective with
      wire='fp32' vs wire='int8': measured HLO all-reduce bytes for both,
      `wire_ratio` = fp32/int8 bytes (~3.9x: int8 payload + fp32 scales),
      and the compressor's own analytic `wire_bytes` for the int8 mode so
      the measured and declared ledgers sit side by side.
    """
    del fast
    key = jax.random.PRNGKey(31)

    # --- double-buffered dense sweep vs serial --------------------------
    k, rank, b = 128, 2, 8
    dims = (256, 16, 16)                   # d1/ba > 1: steps to overlap
    xb = jax.random.normal(jax.random.fold_in(key, 0), (b,) + dims)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
            jax.random.fold_in(key, 1))

        def serial(a, op=op):
            return rp.project(op, a, backend="pallas")

        def double(a, op=op):
            return rp.project(op, a, backend="pallas", pipeline="double")

        f_s, _ = _compiled_with_dispatch_count(serial, xb)
        f_d, launches_d = _compiled_with_dispatch_count(double, xb)
        us_s, us_d = time_call(f_s, xb), time_call(f_d, xb)
        ep = _kernel_plan("project", family, k, b, dims, rank,
                          pipeline="double")
        rows.append(csv_row(
            f"perf/pipeline/sweep/{family}", us_d,
            f"dims={'x'.join(map(str, dims))};k={k};B={b};"
            f"launches_project={launches_d};us_serial={us_s:.1f};"
            f"speedup={us_s / us_d:.3f};"
            f"hbm_bytes={ep.cost.hbm_bytes};"
            f"grid_steps={-(-dims[0] // ep.tiles[2])}"))

    # --- double-buffered carry sweep vs serial --------------------------
    bc, r_in, cdims = 64, 4, (16, 16, 16)  # b/tb > 1: steps to overlap
    items = [random_tt(jax.random.fold_in(key, 50 + i), cdims, r_in)
             for i in range(bc)]
    xc = BatchedTTTensor.stack(items)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=cdims, rank=rank),
            jax.random.fold_in(key, 2))

        def serial(a, op=op):
            return rp.project(op, a, backend="pallas")

        def double(a, op=op):
            return rp.project(op, a, backend="pallas", pipeline="double")

        f_s, _ = _compiled_with_dispatch_count(serial, xc)
        f_d, launches_d = _compiled_with_dispatch_count(double, xc)
        us_s, us_d = time_call(f_s, xc), time_call(f_d, xc)
        ep = rp.plan_execution(
            op, rp.StructureSig(structure="tt", batch=bc, in_rank=r_in),
            backend="pallas", pipeline="double")
        rows.append(csv_row(
            f"perf/pipeline/carry/{family}", us_d,
            f"dims={'x'.join(map(str, cdims))};k={k};B={bc};r_in={r_in};"
            f"launches_project={launches_d};us_serial={us_s:.1f};"
            f"speedup={us_s / us_d:.3f};"
            f"hbm_bytes={ep.cost.hbm_bytes};"
            f"grid_steps={-(-bc // ep.tiles[1])}"))

    # --- fused unsketch+EF+AdamW vs the unfused chain -------------------
    from repro.kernels import fused_update_buckets
    nb, fdims = 8, (64, 16, 16)
    yb = jax.random.normal(jax.random.fold_in(key, 3), (nb, k))
    dense = [jax.random.normal(jax.random.fold_in(key, 60 + i),
                               (nb,) + fdims) for i in range(4)]
    lr = jnp.float32(1e-3)
    c1 = c2 = jnp.float32(0.5)
    hp = dict(alpha=0.9, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    for family in ("tt", "cp"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=k, dims=fdims, rank=rank),
            jax.random.fold_in(key, 4))

        def fused(y, p, w, m, v, lr, c1, c2, op=op):
            rp.count_kernel_dispatch()
            with rp.force_pallas():
                return fused_update_buckets(op, y, p, w, m, v, lr, c1, c2,
                                            **hp)

        def unfused(y, p, w, m, v, lr, c1, c2, op=op):
            with rp.force_pallas():
                g = hp["alpha"] * rp.reconstruct(op, y)
            resid = p - g
            m32 = hp["b1"] * m + (1 - hp["b1"]) * g
            v32 = hp["b2"] * v + (1 - hp["b2"]) * g * g
            step = (m32 / c1) / (jnp.sqrt(v32 / c2) + hp["eps"])
            return resid, w - lr * (step + hp["weight_decay"] * w), m32, v32

        argv = (yb, *dense, lr, c1, c2)
        f_f, launches_f = _compiled_with_dispatch_count(fused, *argv)
        f_u, launches_u = _compiled_with_dispatch_count(unfused, *argv)
        us_f, us_u = time_call(f_f, *argv), time_call(f_u, *argv)
        fus_f = _dense_entry_fusions(f_f.as_text(), (nb,) + fdims)
        fus_u = _dense_entry_fusions(f_u.as_text(), (nb,) + fdims)
        hbm_f = rp.plan_update(op, nb, fused=True).cost.hbm_bytes
        hbm_u = rp.plan_update(op, nb, fused=False).cost.hbm_bytes
        rows.append(csv_row(
            f"perf/fused/update/{family}", us_f,
            f"dims={'x'.join(map(str, fdims))};k={k};B={nb};"
            f"launches_project={launches_f};launches_unfused={launches_u};"
            f"us_unfused={us_u:.1f};speedup={us_u / us_f:.3f};"
            f"hbm_ratio={hbm_f / hbm_u:.3f};"
            f"hbm_bytes_fused={hbm_f};"
            f"hbm_bytes_unfused={hbm_u};"
            f"dense_kernels_fused={fus_f};dense_kernels_unfused={fus_u}"))

    # --- int8 sketches on the wire --------------------------------------
    from repro.core.sketch import PytreeSketcher, SketchConfig
    from repro.launch.roofline import parse_collectives
    from repro.optim.compress import SketchCompressor
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("pod",))
    cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=8 * 16 * 16,
                       dims=(8, 16, 16))
    g = {"w": jax.random.normal(jax.random.fold_in(key, 5), (ndev, 4096)),
         "b": jax.random.normal(jax.random.fold_in(key, 6), (ndev, 100))}
    state = {"residual": jax.tree.map(jnp.zeros_like, g)}
    sk = PytreeSketcher(cfg, jax.tree.map(lambda x: x[0], g))
    for sync in ("sketch-mean", "local-mean"):
        hlo_bytes = {}
        for wire in ("fp32", "int8"):
            comp = SketchCompressor(cfg, sync=sync, pod_axis="pod",
                                    wire=wire)

            def run_step(gg, ss, step, comp=comp):
                with rp.force_pallas():
                    return comp.compress_collective(gg, ss, step=step,
                                                    mesh=mesh)[:2]

            f, launches = _compiled_with_dispatch_count(run_step, g, state, 0)
            us = time_call(f, g, state, 0)
            ar = parse_collectives(f.as_text())["per_type"].get(
                "all-reduce", {"count": 0, "bytes": 0.0})
            hlo_bytes[wire] = int(ar["bytes"])
        comp_i8 = SketchCompressor(cfg, sync=sync, pod_axis="pod",
                                   wire="int8")
        rows.append(csv_row(
            f"perf/wire/sync={sync}", us,
            f"npod={ndev};n_buckets={sk.n_buckets};k={cfg.k};"
            f"launches_project={launches};"
            f"hlo_bytes_fp32={hlo_bytes['fp32']};"
            f"hlo_bytes_int8={hlo_bytes['int8']};"
            f"wire_ratio={hlo_bytes['fp32'] / max(1, hlo_bytes['int8']):.3f};"
            f"wire_bytes_int8={comp_i8.wire_bytes(sk)}"))


def run(fast=True):
    d, N = 3, 12 if fast else 12
    dims = (d,) * N
    D = d ** N
    k = 256
    key = jax.random.PRNGKey(0)
    x_tt = random_tt(key, dims, 10, norm="unit")
    x_cp = random_cp(key, dims, 10, norm="unit")
    x_dense = x_tt.full().reshape(-1)

    def op(family, fold, rank=1):
        spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank)
        return rp.make_projector(spec, jax.random.fold_in(key, fold))

    tt_op = op("tt", 1, 5)
    cp_op = op("cp", 2, 25)
    rows = []

    for name, o, inp, tag in [
        ("TT(5)", tt_op, x_tt, "input=TT"),
        ("CP(25)", cp_op, x_tt, "input=TT"),
        ("TT(5)", tt_op, x_cp, "input=CP"),
        ("CP(25)", cp_op, x_cp, "input=CP"),
        ("VerySparse", op("sparse", 3), x_dense, "input=dense"),
        ("Gaussian", op("gaussian", 4), x_dense, "input=dense"),
    ]:
        f = jax.jit(lambda t, o=o: rp.project(o, t))
        rows.append(csv_row(f"time/medium/{name}/{tag}", time_call(f, inp),
                            f"k={k};D={D}"))

    # App B.2: scaling in N (input dim d^N)
    for n in ((8, 11, 12) if fast else (8, 11, 12, 13)):
        dims_n = (3,) * n
        x_n = random_tt(jax.random.fold_in(key, n), dims_n, 10)
        op_n = rp.make_projector(
            rp.ProjectorSpec(family="tt", k=k, dims=dims_n, rank=5),
            jax.random.fold_in(key, 100 + n))
        f = jax.jit(lambda t: rp.project(op_n, t))
        rows.append(csv_row(f"time/scaling/TT(5)/N={n}", time_call(f, x_n),
                            f"D={3**n}"))

    _batched_vs_per_bucket(rows, fast=fast)
    _order_frontier(rows, fast=fast)
    _struct_frontier(rows, fast=fast)
    _shard_rows(rows, fast=fast)
    _perf_rows(rows, fast=fast)
    return rows
