"""Paper Fig. 2 + App. B.2: embedding time for medium-order inputs given in
TT or CP format, across the map family (TT/CP/sparse/dense)."""
import jax

from repro import rp
from repro.core import random_cp, random_tt

from ._util import csv_row, time_call


def run(fast=True):
    d, N = 3, 12 if fast else 12
    dims = (d,) * N
    D = d ** N
    k = 256
    key = jax.random.PRNGKey(0)
    x_tt = random_tt(key, dims, 10, norm="unit")
    x_cp = random_cp(key, dims, 10, norm="unit")
    x_dense = x_tt.full().reshape(-1)

    def op(family, fold, rank=1):
        spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank)
        return rp.make_projector(spec, jax.random.fold_in(key, fold))

    tt_op = op("tt", 1, 5)
    cp_op = op("cp", 2, 25)
    rows = []

    for name, o, inp, tag in [
        ("TT(5)", tt_op, x_tt, "input=TT"),
        ("CP(25)", cp_op, x_tt, "input=TT"),
        ("TT(5)", tt_op, x_cp, "input=CP"),
        ("CP(25)", cp_op, x_cp, "input=CP"),
        ("VerySparse", op("sparse", 3), x_dense, "input=dense"),
        ("Gaussian", op("gaussian", 4), x_dense, "input=dense"),
    ]:
        f = jax.jit(lambda t, o=o: rp.project(o, t))
        rows.append(csv_row(f"time/medium/{name}/{tag}", time_call(f, inp),
                            f"k={k};D={D}"))

    # App B.2: scaling in N (input dim d^N)
    for n in ((8, 11, 12) if fast else (8, 11, 12, 13)):
        dims_n = (3,) * n
        x_n = random_tt(jax.random.fold_in(key, n), dims_n, 10)
        op_n = rp.make_projector(
            rp.ProjectorSpec(family="tt", k=k, dims=dims_n, rank=5),
            jax.random.fold_in(key, 100 + n))
        f = jax.jit(lambda t: rp.project(op_n, t))
        rows.append(csv_row(f"time/scaling/TT(5)/N={n}", time_call(f, x_n),
                            f"D={3**n}"))
    return rows
