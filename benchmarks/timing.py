"""Paper Fig. 2 + App. B.2: embedding time for medium-order inputs given in
TT or CP format, across the map family (TT/CP/sparse/dense)."""
import jax

from repro.core import (GaussianRP, VerySparseRP, random_cp, random_tt,
                        sample_cp_rp, sample_tt_rp)

from ._util import csv_row, time_call


def run(fast=True):
    d, N = 3, 12 if fast else 12
    dims = (d,) * N
    D = d ** N
    k = 256
    key = jax.random.PRNGKey(0)
    x_tt = random_tt(key, dims, 10, norm="unit")
    x_cp = random_cp(key, dims, 10, norm="unit")
    x_dense = x_tt.full().reshape(-1)
    tt_op = sample_tt_rp(jax.random.fold_in(key, 1), dims, k, 5)
    cp_op = sample_cp_rp(jax.random.fold_in(key, 2), dims, k, 25)
    sparse = VerySparseRP(jax.random.fold_in(key, 3), k, D)
    rows = []

    f = jax.jit(lambda t: tt_op.project_tt(t))
    rows.append(csv_row("time/medium/TT(5)/input=TT", time_call(f, x_tt),
                        f"k={k};D={D}"))
    f = jax.jit(lambda t: cp_op.project_tt(t))
    rows.append(csv_row("time/medium/CP(25)/input=TT", time_call(f, x_tt),
                        f"k={k};D={D}"))
    f = jax.jit(lambda t: tt_op.project_cp(t))
    rows.append(csv_row("time/medium/TT(5)/input=CP", time_call(f, x_cp),
                        f"k={k};D={D}"))
    f = jax.jit(lambda t: cp_op.project_cp(t))
    rows.append(csv_row("time/medium/CP(25)/input=CP", time_call(f, x_cp),
                        f"k={k};D={D}"))
    f = jax.jit(lambda v: sparse.project(v))
    rows.append(csv_row("time/medium/VerySparse/input=dense",
                        time_call(f, x_dense), f"k={k};D={D}"))
    dense = GaussianRP(jax.random.fold_in(key, 4), k, D)
    f = jax.jit(lambda v: dense.project(v))
    rows.append(csv_row("time/medium/Gaussian/input=dense",
                        time_call(f, x_dense), f"k={k};D={D}"))

    # App B.2: scaling in N (input dim d^N)
    for n in ((8, 11, 12) if fast else (8, 11, 12, 13)):
        dims_n = (3,) * n
        x_n = random_tt(jax.random.fold_in(key, n), dims_n, 10)
        op_n = sample_tt_rp(jax.random.fold_in(key, 100 + n), dims_n, k, 5)
        f = jax.jit(lambda t: op_n.project_tt(t))
        rows.append(csv_row(f"time/scaling/TT(5)/N={n}", time_call(f, x_n),
                            f"D={3**n}"))
    return rows
