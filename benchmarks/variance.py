"""Thm 1 empirical check: Monte-Carlo Var(||f(X)||^2) vs the bound, over a
(format, N, R) grid."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import rp
from repro.core import random_tt, theory

from ._util import csv_row


def run(fast=True):
    trials = 150 if fast else 500
    k = 32
    rows = []
    for family in ("tt", "cp"):
        for (d, N) in ((4, 3), (3, 6)):
            for R in (1, 2, 5):
                dims = (d,) * N
                x = random_tt(jax.random.PRNGKey(0), dims, 3, norm="unit")
                xd = x.full()
                spec = rp.ProjectorSpec(family=family, k=k, dims=dims, rank=R)
                keys = jax.random.split(jax.random.PRNGKey(1), trials)
                vals = np.asarray(jax.lax.map(
                    lambda kk: jnp.sum(
                        rp.project(rp.make_projector(spec, kk), xd) ** 2),
                    keys))
                bound = theory.variance_factor(family, N=N, R=R) / k
                rows.append(csv_row(
                    f"variance/{family}/N={N}/R={R}", 0.0,
                    f"mean={vals.mean():.4f};var={vals.var():.5f};"
                    f"bound={bound:.5f};ok={vals.var() <= bound * 1.3}"))
    return rows
