"""Shared benchmark helpers: wall-clock timing of jitted callables + CSV."""
import time

import jax


def time_call(fn, *args, warmup=2, repeat=5, **kw):
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def csv_row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
