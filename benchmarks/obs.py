"""Observability overhead benchmarks: the telemetry layer's cost budget.

Row families:

  obs/overhead — the DISABLED fast path's per-dispatch cost against the
      perf/* reference sweep (tt, dims=(256,16,16), k=128, B=8 — the same
      shape `perf/pipeline/sweep/tt` times). Every wired call site pays at
      most one `obs.span(...)` no-op plus a couple of instrument lookups
      per dispatch; the row measures exactly that bundle per call
      (`disabled_ns`), the reference dispatch (`ref_us`), and their ratio
      `overhead_frac` — a PLAIN float the regression gate caps ABSOLUTELY
      at <= 0.05 (unlike wall-clock, a ratio of two timings from the same
      process cancels the machine out; the bench also asserts it, so a
      bloated fast path fails even without a baseline to diff).
  obs/export — the ENABLED path: per-span recording cost (`enabled_ns`),
      plus one Chrome-trace export + metrics JSONL write of an
      `n_events`-span session (`trace_bytes` / `jsonl_rows` prove the
      artifacts are real, not gated).
"""
import json
import pathlib
import tempfile

import jax

from repro import obs, rp

from ._util import csv_row, time_call

# One "dispatch worth" of disabled-mode obs work is bundled per loop
# iteration below; the loop amortizes timer resolution.
_LOOP = 2000


def _disabled_bundle_ns() -> float:
    """ns per (span + counter + histogram) bundle with telemetry OFF."""
    assert not obs.enabled(), "overhead row must run with obs disabled"

    def loop():
        for _ in range(_LOOP):
            with obs.span("obs/bench", family="tt", structure="dense"):
                pass
            obs.counter("obs/bench_c").inc(0)
            obs.histogram("obs/bench_h").observe(1.0)

    return time_call(loop, warmup=1, repeat=5) * 1e3 / _LOOP


def _enabled_span_ns(tracer) -> float:
    """ns per recorded span with telemetry ON (the opt-in price)."""
    def loop():
        for _ in range(_LOOP):
            with obs.span("obs/bench", family="tt", structure="dense"):
                pass

    ns = time_call(loop, warmup=1, repeat=5) * 1e3 / _LOOP
    tracer.clear()          # drop the timing loop's spans from the session
    return ns


def _overhead_row(rows):
    disabled_ns = _disabled_bundle_ns()
    # the perf/* reference sweep: one eager pallas-routed dispatch — eager
    # on purpose, that is where the per-call span cost lives (under jit the
    # span only runs at trace time)
    key = jax.random.PRNGKey(31)
    dims, k, rank, b = (256, 16, 16), 128, 2, 8
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=k, dims=dims, rank=rank),
        jax.random.fold_in(key, 1))
    xb = jax.random.normal(jax.random.fold_in(key, 0), (b,) + dims)
    ref = jax.jit(lambda a: rp.project(op, a, backend="pallas"))
    ref_us = time_call(ref, xb, warmup=2, repeat=5)
    frac = disabled_ns / 1e3 / ref_us
    # the acceptance criterion, asserted where the row is made: wiring
    # telemetry into every hot path must cost <= 5% when nobody asked
    assert frac <= 0.05, (
        f"disabled obs overhead {frac:.4f} of the reference dispatch "
        f"({disabled_ns:.0f}ns vs {ref_us:.0f}us) exceeds the 5% budget")
    rows.append(csv_row(
        "obs/overhead", disabled_ns / 1e3,
        f"overhead_frac={frac:.6f};disabled_ns={disabled_ns:.0f};"
        f"ref_us={ref_us:.1f};budget=0.05"))


def _export_row(rows, n_events=512):
    ctx = obs.enable()
    try:
        enabled_ns = _enabled_span_ns(ctx.tracer)
        for i in range(n_events):
            with obs.span("obs/bench", i=i):
                pass
            obs.histogram("obs/bench_h").observe(float(i))
        with tempfile.TemporaryDirectory() as d:
            tp = pathlib.Path(d) / "trace.json"
            mp = pathlib.Path(d) / "metrics.jsonl"
            us = time_call(lambda: ctx.tracer.export(tp),
                           warmup=1, repeat=3)
            ctx.metrics.write_jsonl(mp)
            trace_bytes = tp.stat().st_size
            jsonl_rows = len(obs.read_jsonl(mp))
            doc = json.loads(tp.read_text())
            assert len(doc["traceEvents"]) == n_events, "export dropped spans"
    finally:
        obs.disable()
    rows.append(csv_row(
        "obs/export", us,
        f"n_events={n_events};enabled_ns={enabled_ns:.0f};"
        f"trace_bytes={trace_bytes};jsonl_rows={jsonl_rows}"))


def run(fast=True):
    del fast
    rows = []
    _overhead_row(rows)
    _export_row(rows)
    return rows
