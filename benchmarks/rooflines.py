"""Aggregates the dry-run sweep JSONs into the roofline table used by
EXPERIMENTS.md (§Dry-run / §Roofline), plus the plan-driven per-kernel
rooflines (roofline/kernel/*): analytic TPU-time bounds for the batched
sweep and carry-sweep launches whose flops AND HBM bytes are read from the
`ExecutionPlan` cost ledger (`rp.plan_execution(...).cost`) — the SAME
resolver every dispatch and every timing row goes through, so the tables
can never disagree on traffic. Each
kernel row carries both schedules' bounds — `serial_s` (compute + memory,
back-to-back phases) and `pipelined_s` (max(compute, memory): the
double-buffered DMA schedule overlaps the streams) — and the
`pipeline_gain` their ratio predicts on hardware."""
import json
import pathlib

from ._util import csv_row


def _kernel_rows(rows):
    from repro import rp
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS

    def bound(name, cost, extra=""):
        compute_s = cost.flops / PEAK_FLOPS
        memory_s = cost.hbm_bytes / HBM_BW
        serial_s = compute_s + memory_s
        pipelined_s = max(compute_s, memory_s)
        rows.append(csv_row(
            f"roofline/kernel/{name}", 0.0,
            f"flops={cost.flops};hbm_bytes={cost.hbm_bytes};"
            f"compute_s={compute_s:.3e};memory_s={memory_s:.3e};"
            f"serial_s={serial_s:.3e};pipelined_s={pipelined_s:.3e};"
            f"pipeline_gain={serial_s / pipelined_s:.3f};"
            f"bottleneck={'compute' if compute_s > memory_s else 'memory'}"
            f"{extra}"))

    k, rank, b = 128, 2, 8
    dims = (256, 16, 16)             # the perf/pipeline/sweep bench shape
    for family in ("tt", "cp"):
        ep = rp.plan_execution(
            rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
            rp.StructureSig(batch=b), backend="pallas", pipeline="double")
        bound(f"sweep/{family}", ep.cost,
              f";dims={'x'.join(map(str, dims))};B={b}")
    bc, r_in, cdims = 64, 4, (16, 16, 16)
    for family in ("tt", "cp"):
        ep = rp.plan_execution(
            rp.ProjectorSpec(family=family, k=k, dims=cdims, rank=rank),
            rp.StructureSig(structure="tt", batch=bc, in_rank=r_in),
            backend="pallas", pipeline="double")
        bound(f"carry/{family}x tt".replace(" ", ""), ep.cost,
              f";dims={'x'.join(map(str, cdims))};B={bc};r_in={r_in}")


def run(fast=True, out_dir="experiments/dryrun"):
    rows = []
    _kernel_rows(rows)
    p = pathlib.Path(out_dir)
    if not p.exists():
        rows.append(csv_row("roofline/none", 0.0, "run launch/sweep.sh first"))
        return rows
    for f in sorted(p.glob("*.json")):
        cell = json.loads(f.read_text())
        if cell.get("status") == "skip":
            rows.append(csv_row(f"roofline/{f.stem}", 0.0, "SKIP"))
            continue
        r = cell["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        rows.append(csv_row(
            f"roofline/{f.stem}", 0.0,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck']};roofline_frac={frac:.3f};"
            f"useful={r['useful_flops_frac']:.3f}"))
    return rows
