"""Aggregates the dry-run sweep JSONs into the roofline table used by
EXPERIMENTS.md (§Dry-run / §Roofline)."""
import json
import pathlib

from ._util import csv_row


def run(fast=True, out_dir="experiments/dryrun"):
    rows = []
    p = pathlib.Path(out_dir)
    if not p.exists():
        csv_row("roofline/none", 0.0, "run launch/sweep.sh first")
        return rows
    for f in sorted(p.glob("*.json")):
        cell = json.loads(f.read_text())
        if cell.get("status") == "skip":
            rows.append(csv_row(f"roofline/{f.stem}", 0.0, "SKIP"))
            continue
        r = cell["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        rows.append(csv_row(
            f"roofline/{f.stem}", 0.0,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck']};roofline_frac={frac:.3f};"
            f"useful={r['useful_flops_frac']:.3f}"))
    return rows
