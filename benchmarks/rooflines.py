"""Aggregates the dry-run sweep JSONs into the roofline table used by
EXPERIMENTS.md (§Dry-run / §Roofline), plus the planner-driven per-kernel
rooflines (roofline/kernel/*): analytic TPU-time bounds for the batched
sweep and carry-sweep launches whose HBM bytes come from the SAME planner
ledger the timing rows report (`kernels.sweep_hbm_bytes` /
`struct_hbm_bytes`), so the two tables can never disagree on traffic. Each
kernel row carries both schedules' bounds — `serial_s` (compute + memory,
back-to-back phases) and `pipelined_s` (max(compute, memory): the
double-buffered DMA schedule overlaps the streams) — and the
`pipeline_gain` their ratio predicts on hardware."""
import json
import pathlib

from ._util import csv_row


def _kernel_rows(rows):
    from repro.core import theory
    from repro.kernels import (plan_carry_sweep, plan_contraction,
                               struct_hbm_bytes, sweep_hbm_bytes)
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS

    def bound(name, flops, hbm, extra=""):
        compute_s = flops / PEAK_FLOPS
        memory_s = hbm / HBM_BW
        serial_s = compute_s + memory_s
        pipelined_s = max(compute_s, memory_s)
        rows.append(csv_row(
            f"roofline/kernel/{name}", 0.0,
            f"flops={flops};hbm_bytes={hbm};"
            f"compute_s={compute_s:.3e};memory_s={memory_s:.3e};"
            f"serial_s={serial_s:.3e};pipelined_s={pipelined_s:.3e};"
            f"pipeline_gain={serial_s / pipelined_s:.3f};"
            f"bottleneck={'compute' if compute_s > memory_s else 'memory'}"
            f"{extra}"))

    k, rank, b = 128, 2, 8
    dims = (256, 16, 16)             # the perf/pipeline/sweep bench shape
    for family in ("tt", "cp"):
        plan = plan_contraction(family, "project", k, b, dims, rank,
                                pipeline="double")
        fl = b * (theory.flops_project_dense_tt(k, dims, rank)
                  if family == "tt"
                  else theory.flops_project_dense_cp(k, dims, rank))
        bound(f"sweep/{family}", fl, sweep_hbm_bytes(plan),
              f";dims={'x'.join(map(str, dims))};B={b}")
    bc, r_in, cdims = 64, 4, (16, 16, 16)
    for family in ("tt", "cp"):
        cplan = plan_carry_sweep(family, "tt", k, bc, cdims, rank, r_in,
                                 pipeline="double")
        fl = bc * theory.flops_project_struct(family, "tt", k, cdims,
                                              rank, r_in)
        bound(f"carry/{family}x tt".replace(" ", ""), fl,
              struct_hbm_bytes(cplan),
              f";dims={'x'.join(map(str, cdims))};B={bc};r_in={r_in}")


def run(fast=True, out_dir="experiments/dryrun"):
    rows = []
    _kernel_rows(rows)
    p = pathlib.Path(out_dir)
    if not p.exists():
        rows.append(csv_row("roofline/none", 0.0, "run launch/sweep.sh first"))
        return rows
    for f in sorted(p.glob("*.json")):
        cell = json.loads(f.read_text())
        if cell.get("status") == "skip":
            rows.append(csv_row(f"roofline/{f.stem}", 0.0, "SKIP"))
            continue
        r = cell["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        rows.append(csv_row(
            f"roofline/{f.stem}", 0.0,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"bottleneck={r['bottleneck']};roofline_frac={frac:.3f};"
            f"useful={r['useful_flops_frac']:.3f}"))
    return rows
