"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. `--full` uses paper-scale trial
counts (slow on CPU); default is a faithful but reduced sweep.
"""
import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: distortion,timing,pairwise,memory,"
                         "variance,gradcomp,rooflines")
    args = ap.parse_args(argv)
    fast = not args.full
    from . import (distortion, gradcomp, memory, pairwise, rooflines, timing,
                   variance)
    mods = {
        "memory": memory, "variance": variance, "distortion": distortion,
        "timing": timing, "pairwise": pairwise, "gradcomp": gradcomp,
        "rooflines": rooflines,
    }
    wanted = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for name in wanted:
        print(f"# --- {name} ---", flush=True)
        mods[name].run(fast=fast)


if __name__ == "__main__":
    main()
