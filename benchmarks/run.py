"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. `--full` uses paper-scale trial
counts (slow on CPU); default is a faithful but reduced sweep. `--json PATH`
additionally writes a structured ``BENCH_rp.json`` perf record (per-kernel
us/call, parsed derived metrics such as batched-vs-per-bucket launch counts,
bytes moved, and the per-order ``time/order/*`` frontier rows) so CI can
archive the perf trajectory run over run and diff it against the committed
baseline (``benchmarks.check_regression``).
"""
import argparse
import json
import sys
import time


def _parse_derived(derived: str):
    """'a=1;b=2.5x;c=foo' -> {'a': 1, 'b': '2.5x', 'c': 'foo'} (best effort)."""
    out = {}
    for part in derived.split(";"):
        if not part:
            continue
        key, eq, val = part.partition("=")
        if not eq:
            out[part] = True
            continue
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        out[key] = val
    return out


def _rows_to_records(rows):
    records = []
    for row in rows or []:
        if not isinstance(row, str):  # tolerate structured (non-CSV) rows
            records.append({"raw": row})
            continue
        name, _, rest = row.partition(",")
        us, _, derived = rest.partition(",")
        records.append({
            "name": name,
            "us_per_call": float(us),
            "derived": _parse_derived(derived),
        })
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config per registered rp family (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list: distortion,timing,pairwise,memory,"
                         "variance,gradcomp,rooflines,smoke,serve,ckpt,obs,"
                         "plan")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a structured perf record (BENCH_rp.json)")
    args = ap.parse_args(argv)
    fast = not args.full
    from . import (ckpt, distortion, gradcomp, memory, obs, pairwise, plan,
                   rooflines, serve, smoke, timing, variance)
    mods = {
        "memory": memory, "variance": variance, "distortion": distortion,
        "timing": timing, "pairwise": pairwise, "gradcomp": gradcomp,
        "rooflines": rooflines, "smoke": smoke, "serve": serve,
        "ckpt": ckpt, "obs": obs, "plan": plan,
    }
    if args.smoke:
        wanted = ["smoke"]
    elif args.only:
        wanted = args.only.split(",")
        unknown = [w for w in wanted if w not in mods]
        if unknown:
            raise ValueError(
                f"unknown --only section(s) {unknown}: accepted sections "
                f"are {sorted(mods)}")
    else:
        wanted = [m for m in mods if m != "smoke"]
    print("name,us_per_call,derived")
    sections = {}
    for name in wanted:
        print(f"# --- {name} ---", flush=True)
        sections[name] = _rows_to_records(mods[name].run(fast=fast))
    if args.json:
        import jax
        record = {
            # v9: execution plans — the plan/* section (plan-cache builds /
            # hits with `plan_builds` gated like a launch count and the
            # hit rate asserted in the bench, plus the cost-ledger
            # cross-checks: declared one-pass HBM bytes vs the compiled
            # executable's bytes accessed, and the wire ledger vs measured
            # HLO all-reduce bytes — exact for fp32 sketch-mean).
            # v8: observability — the obs/* section (the telemetry layer's
            # disabled-fast-path cost vs the perf reference dispatch as a
            # numeric `overhead_frac`, capped ABSOLUTELY at 0.05 by
            # check_regression, plus the enabled recording/export costs).
            # v7: kernel perf frontier — timing gains the perf/* rows
            # (double-buffered pipelining vs serial with a numeric
            # `speedup`, fused unsketch+EF+AdamW vs the unfused chain with
            # `hbm_ratio` + dense-kernel counts, int8-vs-fp32 wire with
            # measured HLO all-reduce bytes and `wire_ratio`), gated by
            # check_regression's relative bands.
            # v6: fault tolerance — the ckpt/* section (verified save /
            # fallback restore / sketched-state record size, with the >=4x
            # compression ratio asserted in the bench itself). v5: serving
            # engine — the serve/* section (trace replay with
            # the gated one-dispatch-per-tick launches_project, operator
            # cache hit/regen, store retrieval sweep). v4: sharded engine —
            # timing gains the shard/* rows (compress_collective wire bytes
            # per sync mode, measured HLO all-reduce bytes, project_sharded
            # per-device bucket counts; device-count-independent names +
            # launch counts so the 1- and 8-device CI jobs diff against one
            # baseline). v3 added the struct/{tt,cp}x{tt,cp}/N={3,4}
            # carry-sweep rows; v2 the time/order/{tt,cp}/N={2..5} frontier.
            "schema": "bench_rp/v9",
            "unix_time": time.time(),
            "backend": jax.default_backend(),
            "fast": fast,
            "argv": list(argv) if argv is not None else sys.argv[1:],
            "sections": sections,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
