"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. `--full` uses paper-scale trial
counts (slow on CPU); default is a faithful but reduced sweep.
"""
import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config per registered rp family (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list: distortion,timing,pairwise,memory,"
                         "variance,gradcomp,rooflines,smoke")
    args = ap.parse_args(argv)
    fast = not args.full
    from . import (distortion, gradcomp, memory, pairwise, rooflines, smoke,
                   timing, variance)
    mods = {
        "memory": memory, "variance": variance, "distortion": distortion,
        "timing": timing, "pairwise": pairwise, "gradcomp": gradcomp,
        "rooflines": rooflines, "smoke": smoke,
    }
    if args.smoke:
        wanted = ["smoke"]
    elif args.only:
        wanted = args.only.split(",")
    else:
        wanted = [m for m in mods if m != "smoke"]
    print("name,us_per_call,derived")
    for name in wanted:
        print(f"# --- {name} ---", flush=True)
        mods[name].run(fast=fast)


if __name__ == "__main__":
    main()
