"""Logical-axis -> mesh-axis sharding rules.

Parameters carry logical axis names (models/<family>.param_axes); this module
maps them to PartitionSpecs for a concrete mesh, with per-dimension
divisibility fallbacks (e.g. whisper's vocab 51865 is not divisible by 16, so
its vocab dim falls back to replicated — recorded via `notes`).

Strategy (see DESIGN.md §6):
  embed        -> (pod, data)   FSDP: ZeRO-3-style weight sharding
  heads/mlp/vocab -> model      tensor parallel
  experts      -> model         expert parallel (if E divides |model|)
  expert_mlp   -> model         only when experts don't shard (TP fallback)
  layers       -> None          (scan axis)
Activations: batch -> (pod, data); residual stream sequence-sharded over
`model` between blocks (sequence parallelism) via shard_batch_seq.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

from .mesh import data_axes, model_size


def axis_rules(cfg: ArchConfig, mesh, *, fsdp_axes=None) -> dict[str, Any]:
    """fsdp_axes: override the parameter-sharding data axes. The gradient
    compressor sets ('data',) so params replicate across pods (DDP-of-FSDP)
    and the pod axis syncs through the sketched all-reduce only."""
    dp = fsdp_axes if fsdp_axes is not None else data_axes(mesh)
    ms = model_size(mesh)
    experts_shardable = (cfg.moe is not None
                         and cfg.moe.num_experts % ms == 0)
    return {
        "embed": dp,
        # embedding table: vocab rows FSDP-sharded, d_model TP-sharded —
        # keeps the backward scatter-add fully partitioned (a dp-sharded
        # d_model would collide with the token batch axis and XLA falls back
        # to a replicated (V, D) f32 scatter).
        "vocab_fsdp": dp,
        "embed_tp": "model",
        "heads": "model",
        "mlp": "model",
        "mlp2": None,
        "vocab": "model",
        "experts": "model" if experts_shardable else None,
        "expert_mlp": None if experts_shardable else "model",
        "layers": None,
        None: None,
    }


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(shape: tuple[int, ...], axes: tuple, rules: dict, mesh,
             notes: list | None = None) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    entries = []
    for dim, logical in zip(shape, axes):
        mesh_axis = rules.get(logical, None)
        if mesh_axis is None:
            entries.append(None)
            continue
        if dim % _axis_size(mesh, mesh_axis) != 0:
            # try a prefix for tuple axes (e.g. ('pod','data') -> ('pod',))
            chosen = None
            if isinstance(mesh_axis, (tuple, list)):
                for cut in range(len(mesh_axis) - 1, 0, -1):
                    sub = tuple(mesh_axis[:cut])
                    if dim % _axis_size(mesh, sub) == 0:
                        chosen = sub
                        break
            if chosen is None and notes is not None:
                notes.append(f"dim {dim} !% {mesh_axis} -> replicated")
            entries.append(chosen)
        else:
            entries.append(tuple(mesh_axis) if isinstance(mesh_axis, list)
                           else mesh_axis)
    return P(*entries)


def param_specs(cfg: ArchConfig, axes_tree, mesh, shapes_tree,
                notes: list | None = None, *, fsdp_axes=None):
    """Pytree of PartitionSpecs matching the params tree."""
    rules = axis_rules(cfg, mesh, fsdp_axes=fsdp_axes)
    return jax.tree.map(
        lambda sds, ax: spec_for(sds.shape, ax, rules, mesh, notes),
        shapes_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def bucket_specs(mesh, *, exclude: tuple = ()) -> P:
    """PartitionSpec template for `(n_buckets, ...)` sketch-bucket arrays.

    Shards the bucket dim over the mesh's data axes (the same axes
    `axis_rules` uses for FSDP), minus any axes under shard_map manual
    control (`exclude`, e.g. the 'pod' axis inside `compress_collective`).
    The sketcher applies per-leaf divisibility fallbacks, so a template
    whose axes don't divide some leaf's bucket count is safe.
    """
    axes = tuple(a for a in data_axes(mesh) if a not in exclude)
    return P(axes) if axes else P(None)


def batch_spec(shape: tuple[int, ...], mesh) -> P:
    """Shard dim 0 (global batch) over as many data axes as divide it."""
    dp = data_axes(mesh)
    n = shape[0]
    for cut in range(len(dp), -1, -1):
        sub = dp[:cut]
        size = int(np.prod([mesh.shape[a] for a in sub])) if sub else 1
        if n % size == 0:
            return P(sub if sub else None, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def input_batch_specs(batch_tree, mesh):
    """Specs for a batch dict of ShapeDtypeStructs (tokens/labels/frames...).

    positions3 has batch on dim 1; everything else on dim 0.
    """
    def leaf_spec(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "positions3":
            inner = batch_spec(sds.shape[1:], mesh)
            return P(None, *inner)
        return batch_spec(sds.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


# ---------------------------------------------------------------------------
# Decode-cache specs (per family layouts; see models/*.init_cache)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, cache_tree, mesh):
    dp = data_axes(mesh)
    ms = model_size(mesh)

    def spec(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = sds.shape
        b = shape[1] if len(shape) > 1 else 1
        bax = batch_spec((b,), mesh)[0]
        if name in ("k", "v", "xk", "xv") and len(shape) == 5:
            L, B, H, S, hd = shape
            if H % ms == 0:
                return P(None, bax, "model", None, None)
            if S % ms == 0:
                return P(None, bax, None, "model", None)
            return P(None, bax, None, None, None)
        if name == "pos" and len(shape) == 3:
            L, B, S = shape
            if S % ms == 0 and cfg.family == "hybrid":
                return P(None, bax, "model")
            # transformer pos buffer follows the k/v seq sharding only if
            # heads don't shard
            if cfg.n_kv_heads % ms != 0 and S % ms == 0:
                return P(None, bax, "model")
            return P(None, bax, None)
        if name == "ssm":                     # (L, B, H, P, ds)
            return P(None, bax, "model" if shape[2] % ms == 0 else None,
                     None, None)
        if name == "conv":                    # (L, B, W-1, C)
            return P(None, bax, None, "model" if shape[3] % ms == 0 else None)
        if name == "h":                       # (G, B, dr)
            return P(None, bax, "model" if shape[2] % ms == 0 else None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def shard_batch_seq(x, mesh, *, seq_axis: int = 1, exclude: tuple = ()):
    """Sequence-parallel constraint on the residual stream (B, S, D).
    `exclude` drops axes under shard_map manual control (e.g. 'pod')."""
    dp = tuple(a for a in data_axes(mesh) if a not in exclude)
    entries = [None] * x.ndim
    entries[0] = dp if x.shape[0] % _axis_size(mesh, dp) == 0 else None
    if x.shape[seq_axis] % model_size(mesh) == 0:
        entries[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
