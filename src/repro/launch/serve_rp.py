"""Sketch-serving CLI: replay a synthetic trace through the serving engine.

Drives `repro.serve.SketchServer` with the offline load generator and
prints the serving report — p50/p99 queueing latency, batch occupancy,
operator-cache hit rate, one-dispatch-per-tick accounting (asserted
against `rp.dispatch_stats()`) — then demos the JL similarity endpoint on
the freshly ingested sketches, error bars included.

CPU example:
PYTHONPATH=src python -m repro.launch.serve_rp --family tt --k 128 \
    --dims 8 16 16 --rank 2 --requests 64 --max-batch 8 --flush-us 1000

With `--trace-out trace.json --metrics-out metrics.jsonl` the replay runs
under an enabled `repro.obs` session: the trace opens in ui.perfetto.dev
(per-tick serve spans over the rp dispatch spans they contain), the JSONL
carries the queue-delay histogram and request counters, and
`python -m repro.launch.obs_report` renders both as markdown.
"""
from __future__ import annotations

import argparse
import contextlib

from repro import obs, rp
from repro.serve import (ServeConfig, SketchServer, SketchStore, replay,
                         synth_trace)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="tt", choices=("tt", "cp"))
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--dims", type=int, nargs="+", default=[8, 16, 16])
    ap.add_argument("--rank", type=int, default=2)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--pool", type=int, default=1,
                    help="operator pool size (distinct seeds of the spec); "
                         ">1 exercises LRU cache eviction")
    ap.add_argument("--mix", type=float, nargs=3, default=[1.0, 1.0, 1.0],
                    metavar=("DENSE", "TT", "CP"),
                    help="relative payload-structure weights")
    ap.add_argument("--mean-gap-us", type=float, default=200.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--flush-us", type=float, default=1_000.0)
    ap.add_argument("--cache-capacity", type=int, default=8)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"))
    ap.add_argument("--top-m", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prewarm", default=None, metavar="MANIFEST",
                    help="warm the operator cache from a prior run's "
                         "--save-manifest file before replay (operators "
                         "regenerate bitwise from (spec, seed))")
    ap.add_argument("--save-manifest", default=None, metavar="PATH",
                    help="after replay, write the cache registry (spec "
                         "dicts + seeds, no operator bytes) for --prewarm")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="record the replay under repro.obs and export the "
                         "Chrome/Perfetto trace here")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="write the obs metrics snapshot (counters, queue-"
                         "delay histogram, events) here as JSONL")
    ap.add_argument("--distortion", type=float, nargs=2, default=None,
                    metavar=("EPS", "DELTA"),
                    help="stream dense-request distortion through a "
                         "DistortionMonitor at this (eps, delta) target")
    args = ap.parse_args(argv)

    spec = rp.ProjectorSpec(family=args.family, k=args.k,
                            dims=tuple(args.dims), rank=args.rank)
    cfg = ServeConfig(max_batch=args.max_batch, flush_us=args.flush_us,
                      cache_capacity=args.cache_capacity,
                      backend=args.backend)
    store = SketchStore(spec)
    server = SketchServer(cfg, store)
    pool = [(spec, s) for s in range(args.pool)]
    trace = synth_trace(args.requests, pool, mix=tuple(args.mix),
                        mean_gap_us=args.mean_gap_us, seed=args.seed)
    if args.prewarm:
        n = server.prewarm(args.prewarm)
        print(f"[serve_rp] prewarmed {n} operators from {args.prewarm}")

    mon = (obs.DistortionMonitor(eps=args.distortion[0],
                                 delta=args.distortion[1])
           if args.distortion else None)
    cap = (obs.capture(trace_path=args.trace_out,
                       metrics_path=args.metrics_out, distortion=mon)
           if (args.trace_out or args.metrics_out or mon)
           else contextlib.nullcontext())
    with cap, rp.dispatch_stats() as st:
        report = replay(server, trace)
    # kernel_calls counts PALLAS-routed dispatches; on the XLA route (the
    # CPU default under backend=auto) it stays 0 — don't claim otherwise.
    disp = (f"{st.kernel_calls} pallas dispatches — one per tick"
            if st.kernel_calls else "XLA-routed, one dispatch per tick")
    print(f"[serve_rp] {report['requests_done']}/{report['n_trace']} "
          f"requests in {report['ticks']} ticks ({disp})")
    print(f"[serve_rp] latency p50={report['p50_us']:.0f}us "
          f"p99={report['p99_us']:.0f}us  "
          f"occupancy={report['occupancy_mean']:.2f}  "
          f"wall={report['wall_s']:.2f}s")
    c = report["cache"]
    print(f"[serve_rp] operator cache: {c['hits']} hits / {c['misses']} "
          f"misses (hit rate {c['hit_rate']:.1%}), "
          f"{c['evictions']} evictions, regen {c['regen_s']:.2f}s")
    print(f"[serve_rp] store: {report['store_size']} sketches "
          f"({report['store_bytes'] / 1024:.1f} KiB)")
    if args.save_manifest:
        n = server.save_manifest(args.save_manifest)
        print(f"[serve_rp] wrote {n}-entry cache manifest to "
              f"{args.save_manifest}")
    if args.trace_out:
        print(f"[serve_rp] wrote Perfetto trace to {args.trace_out} "
              "(open in ui.perfetto.dev)")
    if args.metrics_out:
        print(f"[serve_rp] wrote obs metrics to {args.metrics_out}")
    if mon is not None:
        for row in mon.summary():
            print(f"[serve_rp] distortion {row['family']}/N={row['order']}"
                  f"/k={row['k']}: mean {row['mean_distortion']:.3f}, "
                  f"out-rate {row['out_rate']:.3f} @ eps={row['eps']} "
                  f"(alerted={row['alerted']})")

    # Similarity demo: nearest stored neighbours of the first sketch (its
    # own id comes back first, distance ~0 — a useful sanity check).
    if len(store) > 1:
        top_m = min(args.top_m, len(store))
        res = server.query(store.get(0), top_m)
        ids = ", ".join(str(int(i)) for i in res.ids)
        print(f"[serve_rp] top-{top_m} of sketch 0: ids [{ids}]  "
              f"d2 {res.dist2.round(2).tolist()}")
        pw = server.pairwise([0], [int(res.ids[-1])])
        print(f"[serve_rp] JL bound: d2={pw.dist2[0]:.2f} in "
              f"[{pw.dist2_lo[0]:.2f}, {pw.dist2_hi[0]:.2f}] "
              f"(eps={pw.eps:.2f} @ delta={pw.delta})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
