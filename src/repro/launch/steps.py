"""pjit-compiled step builders: train_step / prefill_step / serve_step.

Each builder returns (jitted_fn, arg_shapes, arg_shardings) so the dry-run can
.lower(...).compile() against ShapeDtypeStructs and real launches can call
the same function with live arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model, input_specs
from repro.models import settings as model_settings
from repro.models.config import ArchConfig, ShapeSpec
from repro.optim import AdamWConfig, adamw, schedule

from . import sharding as sh
from .mesh import data_axes, model_size


def _policy(cfg: ArchConfig):
    if cfg.policy == "lean":
        return dict(param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
                    compute_dtype=jnp.bfloat16)
    return dict(param_dtype=jnp.float32, moment_dtype=jnp.float32,
                compute_dtype=jnp.bfloat16)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def moe_groups_for(cfg: ArchConfig, mesh, global_batch: int) -> int:
    """Dispatch groups == number of data shards that divide the batch."""
    if cfg.moe is None:
        return 1
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    g = dp
    while g > 1 and global_batch % g:
        g //= 2
    return max(1, g)


@dataclasses.dataclass
class StepBundle:
    fn: Callable            # jitted
    args: tuple             # ShapeDtypeStructs (for .lower)
    shardings: tuple        # matching shardings
    notes: list


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def build_train_step(model: Model, mesh, shape: ShapeSpec, *,
                     opt: AdamWConfig | None = None,
                     lr_fn: Callable | None = None,
                     remat: str = "nothing",
                     seq_parallel: bool = True,
                     compressor=None,
                     fused_update: bool = False) -> StepBundle:
    """`fused_update=True` swaps the compress -> adamw.update chain of the
    single-pod compressed branch for `adamw.update_sketched` — one fused
    unsketch+EF+AdamW kernel launch per leaf, no dense g_hat in HBM.
    Requires a compressor, no pod axis (the collective branch syncs
    sketches across pods before the optimizer and keeps the unfused
    update), and `AdamWConfig(clip_norm=None)`."""
    cfg = model.cfg
    pol = _policy(cfg)
    opt = opt or AdamWConfig(moment_dtype=pol["moment_dtype"])
    lr_fn = lr_fn or functools.partial(
        schedule.cosine_with_warmup, peak_lr=3e-4, warmup_steps=2000,
        total_steps=100_000)
    notes: list = []

    # shapes & shardings -------------------------------------------------
    # With the sketch compressor, params replicate across pods (DDP-of-FSDP):
    # the pod axis is synced exclusively through the compressed all-reduce.
    compressing = compressor is not None
    has_pod = "pod" in mesh.axis_names
    fsdp_axes = ("data",) if (compressing and has_pod) else None
    pod_axis = "pod" if (compressing and has_pod) else None
    if fused_update:
        if not compressing:
            raise ValueError(
                "fused_update=True needs a compressor: the fused kernel IS "
                "the unsketch — without sketch compression there is "
                "nothing to fuse; pass compressor= or drop fused_update")
        if pod_axis is not None:
            raise ValueError(
                "fused_update=True is wired for the single-pod roundtrip "
                "branch; the pod-collective branch syncs sketches across "
                "pods before the optimizer and keeps the unfused update — "
                "run without a 'pod' mesh axis or drop fused_update")
        if opt.clip_norm is not None:
            raise ValueError(
                "fused_update=True fuses AdamW into the unsketch kernel, "
                "which never materializes the dense gradient estimate to "
                "clip; construct AdamWConfig(clip_norm=None)")
    if compressing:
        # explicit bucket-axis layout for the sketcher: data axes minus the
        # manual pod axis (replaces the legacy global _constrain_buckets
        # hint), and the mesh the collective shard_map runs on
        compressor = dataclasses.replace(
            compressor, pod_axis=pod_axis, mesh=mesh,
            bucket_spec=sh.bucket_specs(
                mesh, exclude=(pod_axis,) if pod_axis else ()))
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=pol["param_dtype"]))
    axes = model.param_axes()
    pspecs = sh.param_specs(cfg, axes, mesh, param_shapes, notes,
                            fsdp_axes=fsdp_axes)
    opt_shapes = jax.eval_shape(lambda: adamw.init_state(param_shapes, opt))
    ospecs = {"m": pspecs, "v": pspecs, "count": P()}
    state_shapes = {"params": param_shapes, "opt": opt_shapes}
    state_specs = {"params": pspecs, "opt": ospecs}
    npod = mesh.shape["pod"] if has_pod else 1
    if compressing:
        # per-pod residual: leading pod dim on every leaf
        def _ef_shapes():
            base = jax.eval_shape(compressor.init_state, param_shapes)
            if pod_axis is None:
                return base
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((npod,) + s.shape, s.dtype),
                base)
        ef_shapes = _ef_shapes()
        state_shapes["ef"] = ef_shapes
        # per-pod residuals inherit the param FSDP/TP sharding behind the
        # leading pod dim (a bare P('pod') would replicate 4 bytes/param of
        # residual on every device in the pod)
        state_specs["ef"] = ({"residual": jax.tree.map(
            lambda spec: P(pod_axis, *spec), pspecs,
            is_leaf=lambda x: isinstance(x, P))}
            if pod_axis else jax.tree.map(lambda s: P(), ef_shapes))

    batch_shapes = input_specs(cfg, shape)
    batch_specs = sh.input_batch_specs(batch_shapes, mesh)

    groups = moe_groups_for(cfg, mesh, shape.global_batch)
    if pod_axis is not None:
        groups = max(1, groups // npod)
    constrain = (functools.partial(
        sh.shard_batch_seq, mesh=mesh,
        exclude=(pod_axis,) if pod_axis else ()) if seq_parallel else None)

    def loss_and_grads(params, batch):
        def loss_f(p):
            if model_settings.get().cast_params_once:
                # pre-cast matrices so FSDP all-gathers move bf16, not f32
                # (vectors — norms/biases — stay f32 for stability)
                p = jax.tree.map(
                    lambda a: a.astype(pol["compute_dtype"])
                    if (a.dtype == jnp.float32 and a.ndim >= 2) else a, p)
            return model.loss_fn(p, batch, compute_dtype=pol["compute_dtype"],
                                 remat=remat, moe_groups=groups,
                                 constrain=constrain)
        with model_settings.override(
                mesh=mesh,
                manual_axes=(pod_axis,) if pod_axis else ()):
            return jax.value_and_grad(loss_f)(params)

    interpret = jax.default_backend() != "tpu"

    def train_step(state, batch):
        params = state["params"]
        metrics = {}
        new_state = dict(state)
        if fused_update:
            # single-pod compressed branch, fused: ONE unsketch+EF+AdamW
            # kernel launch per leaf — no dense g_hat in HBM, no separate
            # optimizer pass
            loss, grads = loss_and_grads(params, batch)
            lr = lr_fn(state["opt"]["count"])
            new_p, new_opt, new_state["ef"], cmet = adamw.update_sketched(
                params, grads, state["ef"], state["opt"], lr, opt,
                compressor=compressor, interpret=interpret)
            metrics.update(cmet)
            metrics["loss"] = loss
            metrics["lr"] = lr
            new_state["params"] = new_p
            new_state["opt"] = new_opt
            return new_state, metrics
        if not compressing:
            loss, grads = loss_and_grads(params, batch)
        elif pod_axis is None:
            # single-pod mesh: roundtrip estimator (no comm term), same math
            loss, grads = loss_and_grads(params, batch)
            grads, new_state["ef"], cmet = compressor.compress(
                grads, state["ef"], step=state["opt"]["count"])
            metrics.update(cmet)
        else:
            # per-pod grads via vmap(spmd_axis_name='pod'): the batch gets a
            # leading npod dim sharded over 'pod'; the ONLY cross-pod comm is
            # the mean over that dim of the (buckets, k) sketches.
            def split_pod(x, bdim):
                if bdim == 0:
                    return x.reshape((npod, x.shape[0] // npod) + x.shape[1:])
                assert bdim == 1  # positions3: (3, B, S)
                y = x.reshape((x.shape[0], npod, x.shape[1] // npod)
                              + x.shape[2:])
                return jnp.moveaxis(y, 1, 0)

            batch_pp = {k: split_pod(v, 1 if k == "positions3" else 0)
                        for k, v in batch.items()}
            per_pod = jax.vmap(
                lambda b: loss_and_grads(params, b),
                in_axes=({k: 0 for k in batch_pp},),
                spmd_axis_name=pod_axis)
            loss_pp, grads_pp = per_pod(batch_pp)
            # re-assert FSDP/TP sharding on the per-pod grads: sharding does
            # not reliably survive the spmd vmap, and replicated 67B-param
            # grad trees are fatal at production scale
            grads_pp = jax.tree.map(
                lambda g, spec: jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, P(pod_axis, *spec))),
                grads_pp, pspecs,
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))
            loss = jnp.mean(loss_pp)
            # REAL collective sync: shard_map manual over 'pod' — the only
            # cross-pod traffic is the pmean inside compress_collective
            # ((buckets, k) floats under sync='sketch-mean')
            grads, new_state["ef"], cmet = compressor.compress_collective(
                grads_pp, state["ef"], step=state["opt"]["count"])
            metrics.update(cmet)
        metrics["loss"] = loss
        lr = lr_fn(state["opt"]["count"])
        new_p, new_opt, omet = adamw.update(params, grads, state["opt"], lr, opt)
        metrics.update(omet)
        metrics["lr"] = lr
        new_state["params"] = new_p
        new_state["opt"] = new_opt
        return new_state, metrics

    fn = jax.jit(
        train_step,
        in_shardings=(_named(mesh, state_specs), _named(mesh, batch_specs)),
        out_shardings=(_named(mesh, state_specs), None),
        donate_argnums=(0,),
    )
    return StepBundle(fn, (state_shapes, batch_shapes),
                      (state_specs, batch_specs), notes)


def init_train_state(model: Model, key, *, opt: AdamWConfig | None = None,
                     compressor=None, npod: int = 1) -> dict:
    pol = _policy(model.cfg)
    opt = opt or AdamWConfig(moment_dtype=pol["moment_dtype"])
    params = model.init(key, dtype=pol["param_dtype"])
    state = {"params": params, "opt": adamw.init_state(params, opt)}
    if compressor is not None:
        ef = compressor.init_state(params)
        if npod > 1:  # per-pod residuals: leading pod dim
            ef = jax.tree.map(
                lambda e: jnp.zeros((npod,) + e.shape, e.dtype), ef)
        state["ef"] = ef
    return state


# ---------------------------------------------------------------------------
# Prefill (inference forward over the full prompt)
# ---------------------------------------------------------------------------

def build_prefill_step(model: Model, mesh, shape: ShapeSpec, *,
                       remat: str = "nothing",
                       seq_parallel: bool = True) -> StepBundle:
    cfg = model.cfg
    pol = _policy(cfg)
    notes: list = []
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=pol["param_dtype"]))
    pspecs = sh.param_specs(cfg, model.param_axes(), mesh, param_shapes, notes)
    batch_shapes = input_specs(cfg, shape)
    batch_specs = sh.input_batch_specs(batch_shapes, mesh)
    groups = moe_groups_for(cfg, mesh, shape.global_batch)
    constrain = (functools.partial(sh.shard_batch_seq, mesh=mesh)
                 if seq_parallel else None)

    def prefill_step(params, batch):
        mod = model.mod
        # prefill: per-device batch is small, so the grouped (Hkv, G) flash
        # layout cannot shard its score blocks — expand KV heads here
        # (train keeps the grouped layout; see EXPERIMENTS.md §Perf hc8/hc9)
        ctx = model_settings.override(mesh=mesh, gqa_expand=True,
                                      constrain_attn_heads=True)
        ctx.__enter__()
        if cfg.family == "encdec":
            enc = mod.encode(cfg, params, batch["frames"],
                             compute_dtype=pol["compute_dtype"], remat=remat)
            h = mod.decode_hidden(cfg, params, batch["tokens"], enc,
                                  compute_dtype=pol["compute_dtype"],
                                  remat=remat)
        else:
            h = mod.forward_hidden(cfg, params, batch["tokens"],
                                   positions3=batch.get("positions3"),
                                   patches=batch.get("patches"),
                                   patch_positions=batch.get("patch_positions"),
                                   compute_dtype=pol["compute_dtype"],
                                   remat=remat, moe_groups=groups,
                                   constrain=constrain)
        unembed = (params["embed"].T if cfg.tie_embeddings or
                   "unembed" not in params else params["unembed"])
        logits = h[:, -1, :].astype(jnp.float32) @ unembed.astype(jnp.float32)
        ctx.__exit__(None, None, None)
        return logits

    fn = jax.jit(prefill_step,
                 in_shardings=(_named(mesh, pspecs), _named(mesh, batch_specs)),
                 out_shardings=None)
    return StepBundle(fn, (param_shapes, batch_shapes),
                      (pspecs, batch_specs), notes)


# ---------------------------------------------------------------------------
# Decode (one new token against a seq_len cache)
# ---------------------------------------------------------------------------

def build_serve_step(model: Model, mesh, shape: ShapeSpec) -> StepBundle:
    cfg = model.cfg
    pol = _policy(cfg)
    notes: list = []
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=pol["param_dtype"]))
    pspecs = sh.param_specs(cfg, model.param_axes(), mesh, param_shapes, notes)
    batch_shapes = input_specs(cfg, shape)  # token/pos/cache (+positions3)
    cache_shapes = batch_shapes["cache"]
    cspecs = sh.cache_specs(cfg, cache_shapes, mesh)
    tok_spec = sh.batch_spec((shape.global_batch,), mesh)
    groups = moe_groups_for(cfg, mesh, shape.global_batch)

    def serve_step(params, cache, token, pos, positions3=None):
        kw = {"compute_dtype": pol["compute_dtype"], "moe_groups": groups}
        if positions3 is not None:
            kw["positions3"] = positions3
        with model_settings.override(mesh=mesh):
            logits, new_cache = model.decode_step(params, cache, token, pos,
                                                  **kw)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    in_shardings = [_named(mesh, pspecs), _named(mesh, cspecs),
                    NamedSharding(mesh, tok_spec), NamedSharding(mesh, tok_spec)]
    args = [param_shapes, cache_shapes,
            batch_shapes["token"], batch_shapes["pos"]]
    if "positions3" in batch_shapes:
        in_shardings.append(NamedSharding(mesh, P(None, tok_spec[0], None)))
        args.append(batch_shapes["positions3"])
    fn = jax.jit(serve_step,
                 in_shardings=tuple(in_shardings),
                 out_shardings=(NamedSharding(mesh, tok_spec),
                                _named(mesh, cspecs)),
                 donate_argnums=(1,))
    return StepBundle(fn, tuple(args),
                      (pspecs, cspecs, tok_spec, tok_spec), notes)
