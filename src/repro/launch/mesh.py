"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS for 512 host devices
BEFORE calling it, real launches get the actual TPU topology.

Axes:
  pod   — slow inter-pod (DCN / cross-ICI) data parallelism; the gradient
          sketch compressor targets this axis.
  data  — in-pod data parallel + FSDP parameter sharding.
  model — tensor/expert/sequence parallel.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        # typed error (not an assert): survives `python -O` and names the fix
        raise ValueError(
            f"model={model} must be a positive divisor of the {n} available "
            f"device(s); pick a model-parallel size that divides {n} (or "
            "force more host devices via "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
