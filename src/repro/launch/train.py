"""Training launcher.

Examples
--------
# CPU-runnable reduced config, 200 steps with checkpoints:
PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
    --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ck

# Compressed cross-pod gradient sync (needs a pod axis => >= 2x2x2 devices):
PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
    --mesh 2x2x2 --compress tt:k=1024,rank=8,dims=4x8x16 --steps 50

On a real TPU pod the same flags apply with --mesh 16x16 / 2x16x16 and the
full (non---reduced) configs.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.compress import SketchCompressor, parse_compress_flag
from repro.runtime import train_loop
from repro.runtime.resilience import FaultInjector


def parse_mesh(spec: str | None):
    if spec is None:
        return make_host_mesh()
    dims = tuple(int(x) for x in spec.split("x"))
    names = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, names, devices=jax.devices()[: _prod(dims)])


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized smoke variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--mesh", default=None, help="e.g. 2x2x2 / 16x16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", default=None,
                    help="tt:k=...,rank=...[,dims=AxBxC][,order=N]")
    ap.add_argument("--compress-sync", default="local-mean",
                    choices=["local-mean", "sketch-mean"],
                    help="cross-pod sync of compress_collective: pmean the "
                         "dense reconstructions (one adjoint pass) or the "
                         "(buckets, k) sketches (k-sized wire bytes)")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sketch-ef-ckpt", action="store_true",
                    help="checkpoint the error-feedback tree as a (seed, "
                         "spec, sketch) record instead of its dense bytes "
                         "(requires --compress; the operator is regenerated "
                         "from the saved seed on restore)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault injection (tests): raise at this step once")
    ap.add_argument("--monitor", action="store_true",
                    help="O(k) sketch telemetry: param norm/drift per log")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    mesh = parse_mesh(args.mesh)
    npod = mesh.shape.get("pod", 1) if hasattr(mesh.shape, "get") else (
        mesh.shape["pod"] if "pod" in mesh.axis_names else 1)
    shape = ShapeSpec("cli_train", args.seq, args.batch, "train")

    compressor = None
    if args.compress:
        compressor = SketchCompressor(parse_compress_flag(args.compress),
                                      sync=args.compress_sync)
        print(f"[compress] {args.compress} sync={args.compress_sync} "
              f"shrinkage={compressor.cfg.shrinkage():.4f}")

    lr_fn = functools.partial(schedule.cosine_with_warmup, peak_lr=args.lr,
                              warmup_steps=args.warmup,
                              total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))
    with mesh:
        bundle = steps_lib.build_train_step(
            model, mesh, shape, lr_fn=lr_fn, remat=args.remat,
            compressor=compressor)
        state = steps_lib.init_train_state(
            model, jax.random.PRNGKey(args.seed), compressor=compressor,
            npod=npod if compressor is not None else 1)
        injector = (FaultInjector({args.crash_at})
                    if args.crash_at is not None else None)
        on_metrics = None
        if args.monitor:
            from repro.core import PytreeSketcher, SketchConfig, SketchMonitor
            mon_cfg = SketchConfig(family="tt", k=256, rank=2,
                                   bucket_elems=4 * 8 * 16, dims=(4, 8, 16),
                                   fresh_per_step=False)
            monitor = SketchMonitor(
                PytreeSketcher(mon_cfg, state["params"]),
                jax.random.PRNGKey(17))

            def on_metrics(step, metrics, live_state):
                if step % 10 == 0:
                    m = monitor.update(live_state["params"])
                    print(f"   [monitor] step {step} "
                          f"sketch_norm={float(m['sketch_norm']):.4f} "
                          f"drift={float(m['sketch_drift']):.5f}")
        ef_codec = None
        if args.sketch_ef_ckpt:
            if compressor is None or "ef" not in state:
                raise ValueError(
                    "--sketch-ef-ckpt needs error-feedback state: pass "
                    "--compress so the train state carries an 'ef' tree")
            from repro.ckpt import SketchedTreeCodec
            from repro.launch import sharding as sh
            ef_codec = SketchedTreeCodec(
                compressor.cfg, jax.eval_shape(lambda: state["ef"]),
                mesh=mesh, bucket_spec=sh.bucket_specs(mesh))
            print(f"[ckpt] sketched EF records: "
                  f"{ef_codec.dense_bytes()} -> {ef_codec.sketch_bytes()} "
                  f"bytes ({ef_codec.compression_ratio():.1f}x)")
        loop_cfg = train_loop.LoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, npod=npod)
        state, final = train_loop.run(bundle.fn, state, data, loop_cfg,
                                      injector=injector,
                                      on_metrics=on_metrics,
                                      ef_codec=ef_codec)
    print(f"[train] finished at step {final} "
          f"(params={sum(x.size for x in jax.tree.leaves(state['params']))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
