"""Render a markdown report from an `repro.obs` capture.

Takes the two artifacts a capture writes — the Chrome/Perfetto trace JSON
(`--trace`) and the metrics JSONL (`--metrics`) — and prints the markdown
tables a PR or dashboard wants: span durations aggregated by name, queue
histogram percentiles, counters/gauges, and the event log (stragglers,
resume/fallback, distortion alerts). Either input may be omitted.

`--explain SPEC` additionally (or instead) renders the `ExecutionPlan` the
dispatch layer would resolve for a projection described by SPEC — the
chosen route/kernel/tiles, the unified cost ledger, and every rejected
alternative with its reason (see `repro/rp/plan.py`'s module docstring for
the full dispatch matrix; `rp.explain(op, x)` is the in-process form).
SPEC is comma-separated key=value pairs:

    family=tt,k=256,dims=8x16x16,rank=2,structure=dense,batch=8,\
backend=auto,pipeline=serial,kind=project

`family` (tt/cp/gaussian/sparse), `k` and `dims` (x-separated) are
required; `rank` (default 2), `structure` (dense/tt/cp/sketch),
`batch`, `in_rank`, `chunk`, `backend`, `pipeline`, `kind`
(project/reconstruct) are optional. Span rows in the trace carry the
matching `plan` id attribute, so a hot span can be looked up here.

Usage:
PYTHONPATH=src python -m repro.launch.obs_report \
    --trace trace.json --metrics metrics.jsonl
PYTHONPATH=src python -m repro.launch.obs_report \
    --explain family=tt,k=128,dims=8x16x16,rank=2,batch=8
"""
from __future__ import annotations

import argparse
import json
import pathlib


def load_trace(path) -> list[dict]:
    """The `traceEvents` list of a Chrome trace file, schema-checked."""
    doc = json.loads(pathlib.Path(path).read_text())
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        raise ValueError(
            f"{path} is not a Chrome trace: expected a JSON object with a "
            "'traceEvents' list (did you pass the metrics JSONL here?)")
    for e in events:
        if not isinstance(e, dict) or "ph" not in e or "name" not in e:
            raise ValueError(
                f"{path}: malformed trace event {e!r} (every event needs "
                "'name' and 'ph')")
    return events


def span_table(events: list[dict]) -> str:
    """Durations of complete ("ph": "X") spans aggregated by name."""
    agg: dict[str, list[float]] = {}
    for e in events:
        if e.get("ph") == "X":
            agg.setdefault(e["name"], []).append(float(e.get("dur", 0.0)))
    out = ["| span | count | total ms | mean us | max us |",
           "|---|---|---|---|---|"]
    for name in sorted(agg):
        durs = agg[name]
        out.append(f"| {name} | {len(durs)} | {sum(durs) / 1e3:.2f} "
                   f"| {sum(durs) / len(durs):.0f} | {max(durs):.0f} |")
    return "\n".join(out)


def instant_table(events: list[dict]) -> str:
    """Instant markers ("ph": "i") grouped by name."""
    agg: dict[str, int] = {}
    for e in events:
        if e.get("ph") == "i":
            agg[e["name"]] = agg.get(e["name"], 0) + 1
    out = ["| instant | count |", "|---|---|"]
    for name in sorted(agg):
        out.append(f"| {name} | {agg[name]} |")
    return "\n".join(out)


def metrics_tables(lines: list[dict]) -> str:
    """Counters/gauges, histogram percentiles and events from the JSONL."""
    counters = [l for l in lines if l.get("type") in ("counter", "gauge")]
    hists = [l for l in lines if l.get("type") == "histogram"]
    events = [l for l in lines if l.get("type") == "event"]
    blocks = []
    if counters:
        rows = ["| instrument | kind | value |", "|---|---|---|"]
        for l in sorted(counters, key=lambda l: l["name"]):
            rows.append(f"| {l['name']} | {l['type']} | {l['value']:g} |")
        blocks.append("\n".join(rows))
    if hists:
        rows = ["| histogram | n | mean | p50 | p99 |", "|---|---|---|---|---|"]
        for l in sorted(hists, key=lambda l: l["name"]):
            mean = l["sum"] / l["count"] if l["count"] else 0.0
            rows.append(f"| {l['name']} | {l['count']} | {mean:.0f} "
                        f"| {l['p50']:.0f} | {l['p99']:.0f} |")
        blocks.append("\n".join(rows))
    if events:
        rows = ["| event | details |", "|---|---|"]
        for l in events:
            detail = ", ".join(f"{k}={v}" for k, v in sorted(l.items())
                               if k not in ("type", "name", "time"))
            rows.append(f"| {l['name']} | {detail} |")
        blocks.append("\n".join(rows))
    return "\n\n".join(blocks) if blocks else "(no metrics recorded)"


def explain_plan(spec: str) -> str:
    """Resolve SPEC (see module docstring) to its plan's describe() block."""
    kv = {}
    for part in spec.split(","):
        key, eq, val = part.partition("=")
        if not eq or not key:
            raise ValueError(
                f"--explain spec entry {part!r} is not key=value; expected "
                "e.g. family=tt,k=128,dims=8x16x16,rank=2,batch=8")
        kv[key.strip()] = val.strip()
    missing = [k for k in ("family", "k", "dims") if k not in kv]
    if missing:
        raise ValueError(f"--explain spec is missing required key(s) "
                         f"{missing}; got {sorted(kv)}")
    from repro import rp
    pspec = rp.ProjectorSpec(
        family=kv["family"], k=int(kv["k"]),
        dims=tuple(int(d) for d in kv["dims"].split("x")),
        rank=int(kv.get("rank", 2)))
    sig = rp.StructureSig(
        structure=kv.get("structure",
                         "sketch" if kv.get("kind") == "reconstruct"
                         else "dense"),
        batch=int(kv.get("batch", 1)),
        in_rank=int(kv.get("in_rank", 0)),
        chunk=int(kv["chunk"]) if kv.get("chunk") else None)
    plan = rp.plan_execution(pspec, sig, kind=kv.get("kind", "project"),
                             backend=kv.get("backend", "auto"),
                             pipeline=kv.get("pipeline", "serial"))
    return plan.describe()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None,
                    help="Chrome trace JSON from obs.Tracer.export / "
                         "--trace-out")
    ap.add_argument("--metrics", default=None,
                    help="metrics JSONL from obs.MetricsRegistry.write_jsonl"
                         " / --metrics-out")
    ap.add_argument("--explain", default=None, metavar="SPEC",
                    help="render the ExecutionPlan for a projection spec, "
                         "e.g. family=tt,k=128,dims=8x16x16,rank=2,batch=8,"
                         "backend=auto,pipeline=serial,kind=project")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics and not args.explain:
        ap.error("pass --trace, --metrics and/or --explain")
    if args.explain:
        print(explain_plan(args.explain))
    if args.trace:
        events = load_trace(args.trace)
        print(f"### Spans ({args.trace})\n")
        print(span_table(events))
        if any(e.get("ph") == "i" for e in events):
            print("\n### Trace instants\n")
            print(instant_table(events))
    if args.metrics:
        from repro.obs import read_jsonl
        lines = read_jsonl(args.metrics)
        print(f"\n### Metrics ({args.metrics})\n")
        print(metrics_tables(lines))
        alerts = [l for l in lines if l.get("name") == "distortion.alert"]
        if alerts:
            print(f"\nWARNING: {len(alerts)} distortion alert(s) — sketch "
                  "width k is undersized for the configured (eps, delta).")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
