"""Batched serving driver: slot-based continuous batching over the decode
step (prefill on arrival, per-slot positions, greedy sampling).

CPU example:
PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
    --slots 4 --requests 8 --prompt-len 12 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class SlotServer:
    """Minimal continuous-batching server over Model.decode_step.

    Fixed `slots` concurrent sequences; free slots accept queued requests;
    each decode step advances every active slot by one token. Per-slot
    positions make the shared KV cache ring-buffer correct.
    """

    def __init__(self, model, *, slots: int, max_seq: int, eos: int | None,
                 max_gen: int):
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos
        self.max_gen = max_gen
        self.params = model.init(jax.random.PRNGKey(0))
        self.cache = model.init_cache(slots, max_seq)
        self.pos = np.zeros((slots,), np.int32)
        self.gen_count = np.zeros((slots,), np.int32)
        self.active: list[Request | None] = [None] * slots
        self.cur_tok = np.zeros((slots,), np.int32)
        self._step = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos))

    def _feed_prompt(self, slot: int, req: Request) -> None:
        """Whole-prompt prefill, batched onto the device in one transfer.

        Builds the (S, slots) token/position matrices the token-by-token
        loop would have fed step by step — other slots repeat their current
        token at their current position, an idempotent cache write — ships
        them to the device once, and enqueues S async dispatches of the
        SAME jitted decode step the generation loop runs, syncing the host
        only for the final argmax. Reusing that one compiled executable
        (rather than a separately-jitted scan over the prompt) is what
        makes greedy decode bit-identical to token-by-token stepping: XLA
        gives no cross-program determinism guarantee, and ulp-level logit
        differences between two compilations can flip a near-tie argmax.
        """
        S = len(req.prompt)
        if S == 0:
            raise ValueError(f"request {req.rid} has an empty prompt")
        toks = np.broadcast_to(self.cur_tok, (S, self.slots)).copy()
        toks[:, slot] = np.asarray(req.prompt, np.int32)
        poss = np.broadcast_to(self.pos, (S, self.slots)).copy()
        poss[:, slot] = self.pos[slot] + np.arange(S, dtype=np.int32)
        toks_d, poss_d = jnp.asarray(toks), jnp.asarray(poss)
        logits = None
        for i in range(S):
            logits, self.cache = self._step(
                self.params, self.cache, toks_d[i], poss_d[i])
        self.pos[slot] += S
        self.cur_tok[slot] = int(jnp.argmax(logits[slot]))

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                self.active[s] = req
                self.pos[s] = 0
                self.gen_count[s] = 0
                self._feed_prompt(s, req)
                return True
        return False

    def step(self) -> None:
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.cur_tok),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            self.gen_count[s] += 1
            tok = int(nxt[s])
            req.generated.append(tok)
            if ((self.eos is not None and tok == self.eos)
                    or self.gen_count[s] >= self.max_gen
                    or self.pos[s] >= self.max_seq - 1):
                req.done = True
                self.active[s] = None
            else:
                self.cur_tok[s] = tok

    def run(self, queue: list[Request]) -> list[Request]:
        done: list[Request] = []
        pending = list(queue)
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            if any(r is not None for r in self.active):
                self.step()
            for r in queue:
                if r.done and r not in done:
                    done.append(r)
        return done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.family == "encdec":
        raise SystemExit("serve CLI targets decoder families; whisper decode "
                         "is exercised in tests/test_models_decode.py")
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab, size=(args.prompt_len,)))
            for i in range(args.requests)]
    srv = SlotServer(model, slots=args.slots, max_seq=args.max_seq,
                     eos=None, max_gen=args.gen)
    done = srv.run(reqs)
    for r in done:
        print(f"req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {len(r.generated)} tokens: {r.generated[:8]}...")
    print(f"[serve] completed {len(done)}/{args.requests} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
