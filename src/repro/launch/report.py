"""Render EXPERIMENTS.md tables from the dry-run / hillclimb JSONs.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir experiments]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.1f}"


def load_cells(d: pathlib.Path) -> list[dict]:
    return [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]


def roofline_table(cells: list[dict], mesh: str) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | bottleneck "
           "| roofline frac | useful | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "skip":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | SKIP | — "
                       f"| — | — |")
            continue
        r = c["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        peak = r["memory_per_device"].get("peak_bytes_per_device", 0.0)
        out.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| {r['bottleneck']} | {frac:.3f} "
            f"| {r['useful_flops_frac']:.2f} | {_fmt_bytes(peak)} |")
    return "\n".join(out)


def dryrun_table(cells: list[dict]) -> str:
    """§Dry-run: compile status + memory per cell per mesh."""
    by_key: dict[tuple, dict] = {}
    for c in cells:
        by_key[(c["arch"], c["shape"], c["mesh"])] = c
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    out = ["| arch | shape | 16x16 | GiB/dev | 2x16x16 | GiB/dev |",
           "|---|---|---|---|---|---|"]
    for a in archs:
        for s in shapes:
            row = [a, s]
            for mesh in ("16x16", "2x16x16"):
                c = by_key.get((a, s, mesh))
                if c is None:
                    row += ["(pending)", "—"]
                elif c["status"] == "skip":
                    row += ["SKIP", "—"]
                else:
                    peak = c["roofline"]["memory_per_device"].get(
                        "peak_bytes_per_device", 0.0)
                    row += [f"ok ({c['compile_s']:.0f}s)", _fmt_bytes(peak)]
            out.append("| " + " | ".join(row) + " |")
    return "\n".join(out)


def collective_detail(cells: list[dict], arch: str, shape: str,
                      mesh: str = "16x16", tag_note: str = "") -> str:
    for c in cells:
        if (c["arch"], c["shape"], c["mesh"]) == (arch, shape, mesh):
            r = c["roofline"]
            lines = [f"{arch} {shape} {mesh} {tag_note}"]
            for op, d in sorted(r["collective"]["per_type"].items()):
                lines.append(f"  {op:20s} n={d['count']:7.0f} "
                             f"traffic={d['traffic']/2**30:9.2f} GiB/dev")
            return "\n".join(lines)
    return f"{arch} {shape} {mesh}: missing"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments")
    args = ap.parse_args(argv)
    base = pathlib.Path(args.dir)
    cells = load_cells(base / "dryrun") if (base / "dryrun").exists() else []
    print("### Dry-run matrix (paper-faithful baseline)\n")
    print(dryrun_table(cells))
    print("\n### Roofline, single-pod 16x16 (paper-faithful baseline)\n")
    print(roofline_table(cells, "16x16"))
    opt = (load_cells(base / "dryrun_opt")
           if (base / "dryrun_opt").exists() else [])
    if opt:
        print("\n### Roofline, single-pod 16x16 (beyond-paper optimized: "
              "grouped-GQA flash + batch-pinned constraints + "
              "shard-aware MoE dispatch)\n")
        print(roofline_table(opt, "16x16"))
    hc = (load_cells(base / "hillclimb")
          if (base / "hillclimb").exists() else [])
    if hc:
        print("\n### Hillclimb iteration cells (experiments/hillclimb)\n")
        print(roofline_table(hc, "16x16"))
        print()
        print(roofline_table(hc, "2x16x16"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
