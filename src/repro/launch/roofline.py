"""Three-term roofline analysis from a compiled (dry-run) executable.

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes_accessed / (chips * HBM_bw)
  collective = per-device link bytes / link_bw

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes are
NOT in cost_analysis: we parse the post-partitioning HLO (compiled.as_text())
and apply a ring-algorithm traffic model per op type using the replica-group
size. Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (the `pod` axis crosses DCN; flagged separately).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(?P<outtype>\([^)]*\)|[\w\[\],]+)(?:\{[\d,]*\})?\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Per-op-type totals + ring-model per-device link bytes."""
    per_type: dict[str, dict[str, float]] = {}
    link_bytes = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("outtype"))
        n = _group_size(line)
        if op == "all-reduce":
            traffic = 2.0 * out_bytes * (n - 1) / max(n, 1)
        elif op == "all-gather":
            traffic = out_bytes * (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            traffic = out_bytes * (n - 1)            # input = out * n
        elif op == "all-to-all":
            traffic = out_bytes * (n - 1) / max(n, 1)
        else:  # collective-permute: one hop
            traffic = float(out_bytes)
        d = per_type.setdefault(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        d["count"] += 1
        d["bytes"] += out_bytes
        d["traffic"] += traffic
        link_bytes += traffic
    return {"per_type": per_type, "link_bytes_per_device": link_bytes}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective: dict
    model_flops: float           # 6*N*D (active params) for the global step
    memory_per_device: dict
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_frac: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops_per_device / PEAK_FLOPS
        self.memory_s = self.hlo_bytes_per_device / HBM_BW
        self.collective_s = self.collective["link_bytes_per_device"] / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.hlo_flops_per_device * self.n_devices
        self.useful_flops_frac = (self.model_flops / total_hlo
                                  if total_hlo else 0.0)
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def memory_stats(compiled) -> dict[str, float]:
    mem: dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = float(v)
        mem["peak_bytes_per_device"] = (
            mem.get("argument_size_in_bytes", 0.0)
            + mem.get("output_size_in_bytes", 0.0)
            + mem.get("temp_size_in_bytes", 0.0)
            - mem.get("alias_size_in_bytes", 0.0))
    except Exception:  # pragma: no cover
        pass
    return mem


def _costs(compiled) -> tuple[float, float, dict]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = dict(cost or {})
    coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def analyze_extrapolated(comp1, comp2, n1: float, n2: float, n_full: float,
                         *, arch: str, shape, mesh_name: str, n_devices: int,
                         cfg, memory: dict) -> "Roofline":
    """Linear-in-depth extrapolation from two shallow unrolled probes.

    cost(n) = a + b*n  (n = pattern instances); the full cell evaluates at
    n_full. Exact for flops/bytes; collectives are per-type linear too.
    """
    f1, b1, c1 = _costs(comp1)
    f2, b2, c2 = _costs(comp2)

    def extrap(v1, v2):
        slope = (v2 - v1) / (n2 - n1)
        return max(v1 + slope * (n_full - n1), 0.0)

    per_type: dict[str, dict[str, float]] = {}
    for op in set(c1["per_type"]) | set(c2["per_type"]):
        d1 = c1["per_type"].get(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        d2 = c2["per_type"].get(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        per_type[op] = {k: extrap(d1[k], d2[k]) for k in
                        ("count", "bytes", "traffic")}
    coll = {"per_type": per_type,
            "link_bytes_per_device": extrap(c1["link_bytes_per_device"],
                                            c2["link_bytes_per_device"]),
            "probe_instances": [n1, n2, n_full]}
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops_per_device=extrap(f1, f2),
        hlo_bytes_per_device=extrap(b1, b2),
        collective=coll, model_flops=model_flops_for(cfg, shape),
        memory_per_device=memory,
    ).finalize()


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D_tokens (train) or 2*N_active*D (inference)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n_active * tokens


def analyze(compiled, *, arch: str, shape, mesh_name: str, n_devices: int,
            cfg) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = dict(cost or {})
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    mem: dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = float(v)
    except Exception as e:  # pragma: no cover
        mem["error"] = 0.0
    coll = parse_collectives(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops_per_device=flops, hlo_bytes_per_device=byts,
        collective=coll, model_flops=model_flops_for(cfg, shape),
        memory_per_device=mem,
    ).finalize()
