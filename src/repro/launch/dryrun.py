"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell against
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, and emit
the three-term roofline JSON consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any OTHER import (jax locks the device
# count on first initialization). Only the module docstring precedes them.

import argparse
import dataclasses
import json
import pathlib
import sys
import time

import jax

from repro.configs import ARCHS, get_config
from repro.launch import roofline as rl
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, settings


def build_bundle(cfg, shape, mesh, *, remat: str = "nothing",
                 seq_parallel: bool = True, compressor=None):
    model = build_model(cfg)
    if shape.kind == "train":
        return steps.build_train_step(model, mesh, shape, remat=remat,
                                      seq_parallel=seq_parallel,
                                      compressor=compressor)
    if shape.kind == "prefill":
        return steps.build_prefill_step(model, mesh, shape, remat=remat,
                                        seq_parallel=seq_parallel)
    return steps.build_serve_step(model, mesh, shape)


def probe_pair(cfg):
    """(cfg_n1, cfg_n2, n1, n2, n_full): small-depth unrolled cost probes.

    Costs are linear in depth "instances" (one instance = one repetition of
    the arch's layer pattern): two probes pin slope+intercept, the full cell
    extrapolates. lax.scan bodies are otherwise counted ONCE by XLA's cost
    analysis, which under-reports flops/collectives by ~L.
    """
    if cfg.family == "hybrid":
        p = 3
    elif cfg.family == "encdec":
        p = 1
    else:
        p = len(cfg.window_pattern)
    kw1 = {"n_layers": p}
    kw2 = {"n_layers": 2 * p}
    if cfg.family == "encdec":
        kw1["encoder_layers"] = 1
        kw2["encoder_layers"] = 2
    n_full = cfg.n_layers / p
    return (dataclasses.replace(cfg, **kw1), dataclasses.replace(cfg, **kw2),
            1.0, 2.0, n_full)


def _measure(cfg, shape, mesh, *, remat, seq_parallel, compressor):
    bundle = build_bundle(cfg, shape, mesh, remat=remat,
                          seq_parallel=seq_parallel, compressor=compressor)
    t0 = time.time()
    compiled = bundle.fn.lower(*bundle.args).compile()
    return bundle, compiled, time.time() - t0


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None, remat: str = "nothing",
             seq_parallel: bool = True, verbose: bool = True,
             tag: str = "", compress: str | None = None,
             compress_sync: str = "local-mean",
             cfg_override=None, opts: dict | None = None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = cfg.shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind}
    if shape.skip:
        cell["status"] = "skip"
        cell["reason"] = shape.skip
        return _emit(cell, out_dir, verbose, tag)

    mesh = make_production_mesh(multi_pod=multi_pod)
    compressor = None
    if compress:
        from repro.optim.compress import SketchCompressor, parse_compress_flag
        compressor = SketchCompressor(parse_compress_flag(compress),
                                      pod_axis="pod" if multi_pod else None,
                                      sync=compress_sync)
    n_dev = mesh.devices.size
    opts = opts or {}
    with mesh, settings.override(**opts):
        # 1) full-depth rolled compile: proves sharding coherence + memory fit
        bundle, compiled, t_full = _measure(
            cfg, shape, mesh, remat=remat, seq_parallel=seq_parallel,
            compressor=compressor)
        mem = rl.memory_stats(compiled)
        # 2) two shallow UNROLLED probes: exact per-instance costs
        c1, c2, n1, n2, n_full = probe_pair(cfg)
        probe_chunk = max(2048, min(4096, shape.seq_len))
        with settings.override(unroll_scans=True, attn_chunk_q=probe_chunk,
                               attn_chunk_k=probe_chunk):
            _, comp1, t1 = _measure(c1, shape, mesh, remat=remat,
                                    seq_parallel=seq_parallel,
                                    compressor=compressor)
            _, comp2, t2 = _measure(c2, shape, mesh, remat=remat,
                                    seq_parallel=seq_parallel,
                                    compressor=compressor)
    roof = rl.analyze_extrapolated(
        comp1, comp2, n1, n2, n_full, arch=arch, shape=shape,
        mesh_name=mesh_name, n_devices=n_dev, cfg=cfg, memory=mem)
    cell.update(status="ok", compile_s=round(t_full, 1),
                probe_compile_s=[round(t1, 1), round(t2, 1)],
                notes=bundle.notes, roofline=roof.to_json())
    if verbose:
        print(compiled.memory_analysis())
    return _emit(cell, out_dir, verbose, tag)


def _emit(cell: dict, out_dir: str | None, verbose: bool, tag: str) -> dict:
    if out_dir:
        p = pathlib.Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        name = f"{cell['arch']}_{cell['shape']}_{cell['mesh']}{tag}.json"
        (p / name).write_text(json.dumps(cell, indent=1))
    if verbose:
        if cell["status"] == "skip":
            print(f"SKIP {cell['arch']} {cell['shape']}: {cell['reason']}")
        else:
            r = cell["roofline"]
            print(f"OK {cell['arch']} {cell['shape']} {cell['mesh']}: "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_frac']:.2f} "
                  f"(compile {cell['compile_s']:.0f}s)")
    return cell


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--compress", default=None,
                    help="e.g. tt:k=4096,rank=2 — sketched grad all-reduce")
    ap.add_argument("--compress-sync", default="local-mean",
                    choices=["local-mean", "sketch-mean"],
                    help="compress_collective sync mode on the pod axis")
    ap.add_argument("--cast-once", action="store_true",
                    help="perf: bf16 param cast before the scan")
    ap.add_argument("--flash-bf16", action="store_true",
                    help="perf: bf16 softmax weights in flash PV matmul")
    ap.add_argument("--sp-outputs", action="store_true",
                    help="perf: seq-shard block outputs (reduce-scatter)")
    ap.add_argument("--moe-c-shard", action="store_true",
                    help="perf: capacity-shard expert buffer when E < |model|")
    ap.add_argument("--no-head-constraints", action="store_true",
                    help="perf: let the partitioner pick attention shardings")
    ap.add_argument("--no-gqa-expand", action="store_true",
                    help="perf: keep grouped (Hkv, G) flash layout")
    ap.add_argument("--tag", default="", help="suffix for output json")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for name, cfg in ARCHS.items():
            for s in cfg.shapes:
                flag = f"SKIP({s.skip[:30]}...)" if s.skip else "run"
                print(f"{name:20s} {s.name:12s} {flag}")
        return 0

    assert args.arch and args.shape, "--arch and --shape required (or --list)"
    opts = {}
    if args.cast_once:
        opts["cast_params_once"] = True
    if args.flash_bf16:
        opts["flash_p_bf16"] = True
    if args.sp_outputs:
        opts["sp_block_outputs"] = True
    if args.moe_c_shard:
        opts["moe_c_shard"] = True
    if args.no_head_constraints:
        opts["constrain_attn_heads"] = False
    if args.no_gqa_expand:
        opts["gqa_expand"] = False
    cell = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                    out_dir=args.out, remat=args.remat,
                    seq_parallel=not args.no_seq_parallel, tag=args.tag,
                    compress=args.compress,
                    compress_sync=args.compress_sync, opts=opts)
    return 0 if cell["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
