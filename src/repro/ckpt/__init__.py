"""repro.ckpt — verified, sketch-native, elastic checkpoints.

  * `checkpointer` — atomic saves with per-array crc32 + manifest sha256,
    corruption-detecting restore with fallback to the newest VERIFIED
    checkpoint, retry-with-backoff on transient I/O, async saves with the
    device-to-host transfer off the caller's critical path.
  * `SketchedTreeCodec` — persist EF/optimizer pytrees as (seed, spec,
    (n_buckets, k) sketch) records; the operator is regenerated from the
    saved seed on restore, never stored.
  * `respec_pod_ef` / `resume_elastic` — restore onto a different pod
    count: exact contiguous-group sums where the pod count divides evenly,
    total-preserving redistribution otherwise.
"""
from . import checkpointer
from .checkpointer import (AsyncCheckpointer, CheckpointError,
                           CorruptionError, sweep_tmp, verify)
from .elastic import respec_pod_ef, resume_elastic
from .sketched import CKPT_KEY, SketchedTreeCodec

__all__ = [
    "AsyncCheckpointer", "CKPT_KEY", "CheckpointError", "CorruptionError",
    "SketchedTreeCodec", "checkpointer", "respec_pod_ef", "resume_elastic",
    "sweep_tmp", "verify",
]
