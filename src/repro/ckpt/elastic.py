"""Elastic resume: restore a training checkpoint onto a DIFFERENT pod count.

Params and optimizer moments are pod-REPLICATED under the compressed-sync
topology (DDP-of-FSDP: the pod axis syncs exclusively through the sketched
all-reduce), so they restore onto any mesh via `checkpointer.restore`'s
device_put re-sharding. The one pod-SHAPED state is the error-feedback
residual — one row per pod — and its physical meaning is additive: the pod
MEAN of the residual rows is what the next compressed sync folds back into
the gradient estimate. `respec_pod_ef` re-buckets those rows while
preserving `sum_w e_w` exactly:

  * npod_new divides npod_old — each new row is the SUM of a contiguous
    group of old rows: pure fp32 additions in a fixed order, BIT-EXACT,
    no division anywhere.
  * otherwise (growing the pod count, or a non-dividing shrink) — every new
    row carries total/npod_new: still total-preserving and deterministic,
    but the per-pod attribution is lost; the next sketched sync re-attributes
    it, paying one Thm-1-bounded roundtrip like any other compression step.

`resume_elastic` glues the pieces: read the manifest of the newest VERIFIED
checkpoint (corruption falls back like any restore), rebuild the sketched-EF
codec from the saved meta when the checkpoint is sketch-native — the
operator is regenerated from the SAVED seed on the new host, with bucket
layout respecced to the new mesh via `launch/sharding.py::bucket_specs`;
no operator bytes exist on disk — then respec the pod dim to the new count.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from . import checkpointer
from .checkpointer import CheckpointError
from .sketched import SketchedTreeCodec


def _fold_sum(x, lo: int, hi: int):
    # explicit left-to-right adds, NOT jnp.sum: XLA's reduce picks its own
    # (deterministic but backend-specific) association; a fixed fold makes
    # the bit-exactness claim hold against any reference that adds in order
    acc = x[lo]
    for i in range(lo + 1, hi):
        acc = acc + x[i]
    return acc


def _respec_leaf(x, npod_old: int, npod_new: int):
    x = jnp.asarray(x)
    if npod_old == 1:                       # no pod dim on the saved leaf
        if npod_new == 1:
            return x
        return jnp.stack([x / npod_new] * npod_new)
    if x.shape[:1] != (npod_old,):
        raise CheckpointError(
            f"EF leaf has leading dim {x.shape[0] if x.ndim else None}, "
            f"expected the saved pod count {npod_old}")
    if npod_new == 1:
        return _fold_sum(x, 0, npod_old)    # exact: fixed-order fp32 adds
    if npod_old == npod_new:
        return x
    if npod_old % npod_new == 0:            # exact: contiguous group sums
        g = npod_old // npod_new
        return jnp.stack([_fold_sum(x, b * g, (b + 1) * g)
                          for b in range(npod_new)])
    total = _fold_sum(x, 0, npod_old)       # total-preserving redistribution
    return jnp.stack([total / npod_new] * npod_new)


def respec_pod_ef(ef_tree: Any, npod_old: int, npod_new: int) -> Any:
    """Re-bucket per-pod EF residual rows onto a new pod count.

    Preserves the pod SUM of every leaf; bit-exact (no division) whenever
    `npod_new` divides `npod_old` (including npod_new == 1). See module
    docstring for the non-dividing semantics.
    """
    if npod_old < 1 or npod_new < 1:
        raise CheckpointError(
            f"pod counts must be >= 1, got old={npod_old} new={npod_new}")
    return jax.tree.map(lambda x: _respec_leaf(x, npod_old, npod_new),
                        ef_tree)


def _pod_stripped(shape: tuple, npod: int) -> tuple:
    return tuple(shape[1:]) if npod > 1 else tuple(shape)


def resume_elastic(directory: str | os.PathLike, example_state: Any, *,
                   npod_new: int, mesh=None, step: int | None = None,
                   shardings: Any = None) -> tuple[Any, int]:
    """Restore the newest verified checkpoint onto `npod_new` pods.

    `example_state` describes the NEW job's state tree ({"params", "opt"[,
    "ef"]} with `ef` leaves already shaped for `npod_new`: leading pod dim
    iff npod_new > 1). The saved pod count and sketched-EF codec meta come
    from the checkpoint manifest (written by `runtime/train_loop.py`);
    `mesh` (optional) gives the decoded sketch buckets the new mesh's layout
    via `launch/sharding.py::bucket_specs`. Returns (state, step).
    """
    directory = os.fspath(directory)
    if step is None:
        step = checkpointer.newest_verified_step(directory)
        if step is None:
            raise checkpointer.CorruptionError(
                f"no verifiable checkpoint under {directory}")
    manifest = checkpointer.read_manifest(directory, step)
    extra = manifest.get("extra", {})
    npod_old = int(extra.get("npod", 1))
    sk_meta = extra.get("sketched_ef")

    has_ef = isinstance(example_state, dict) and "ef" in example_state
    if not has_ef:
        return checkpointer.restore(directory, example_state, step,
                                    shardings=shardings)

    # the SAVED tree's ef is shaped for npod_old (and possibly sketched):
    # rebuild that example from the new job's, pod dim swapped
    new_ef = example_state["ef"]
    old_ef_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            ((npod_old,) if npod_old > 1 else ())
            + _pod_stripped(l.shape, npod_new), l.dtype),
        new_ef)
    codec = None
    if sk_meta is not None:
        bucket_spec = None
        if mesh is not None:
            from repro.launch.sharding import bucket_specs  # no import cycle
            bucket_spec = bucket_specs(mesh)
        codec = SketchedTreeCodec.from_meta(sk_meta, old_ef_shapes,
                                            mesh=mesh,
                                            bucket_spec=bucket_spec)
    saved_example = dict(example_state)
    saved_example["ef"] = codec.record_shapes() if codec else old_ef_shapes
    restored, step = checkpointer.restore(directory, saved_example, step,
                                          shardings=shardings)
    ef_old = codec.decode(restored["ef"]) if codec else restored["ef"]
    restored["ef"] = respec_pod_ef(ef_old, npod_old, npod_new)
    return restored, step
