"""Atomic, mesh-agnostic, VERIFIED, async-capable checkpoints.

Layout: <dir>/step_<n>/{manifest.json, arr_<i>.npy ...}. Writes go to a tmp
directory that is atomically renamed, so a crash mid-save never corrupts the
latest checkpoint; orphaned ``.tmp_*`` directories from a crash mid-save are
swept on the next save/restore. Restore re-shards onto whatever mesh/sharding
the restarted job uses (elastic scaling): arrays are saved as full
(addressable-gathered) values and re-placed with jax.device_put against the
new sharding.

Integrity: every array entry in the manifest carries a crc32 of its raw
bytes, and the manifest itself carries a sha256 over its canonical JSON body
(computed with the ``integrity`` field blanked). ``verify`` re-hashes both;
``restore`` verifies by default and, when the newest checkpoint is corrupt
(truncated array, flipped byte, missing file), falls back to the newest
checkpoint that DOES verify instead of resuming from garbage. All restore
misuse (tree-structure drift, shape mismatch, shardings-length mismatch)
raises typed ValueErrors that survive ``python -O`` — never bare asserts.

Fault model: transient I/O errors during save (full/flaky disk, NFS rename
hiccup) are retried with capped exponential backoff
(`runtime.resilience.retry_with_backoff`); an injectable `io` hook object
(`runtime.resilience.IOFaultInjector` in tests) intercepts writes/renames so
the failure paths are deterministically testable.

On a real multi-host pod each host would write only its addressable shards
(same manifest format, `shard_id` field); this single-process implementation
writes full arrays, which is the degenerate single-host case of that layout.
"""
from __future__ import annotations

import atexit
import hashlib
import json
import os
import pathlib
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np

from repro import obs


class CheckpointError(ValueError):
    """Restore-path misuse or an unusable checkpoint: typed (survives
    ``python -O``) so supervisors can distinguish it from transient I/O."""


class CorruptionError(CheckpointError):
    """A checkpoint failed integrity verification (checksum/hash/shape)."""


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def sweep_tmp(directory: str | os.PathLike) -> list[pathlib.Path]:
    """Remove orphaned ``.tmp_*`` directories left by a crash mid-save.

    A save that dies between ``mkdtemp`` and the atomic rename leaves its tmp
    directory behind; without this sweep they accumulate forever under the
    checkpoint dir. Called on every save and on AsyncCheckpointer startup.
    Returns the paths removed.
    """
    directory = pathlib.Path(directory)
    removed = []
    if not directory.is_dir():
        return removed
    for tmp in directory.glob(".tmp_*"):
        if tmp.is_dir():
            shutil.rmtree(tmp, ignore_errors=True)
            removed.append(tmp)
    return removed


def _manifest_digest(manifest: dict) -> str:
    """sha256 over the canonical JSON body with ``integrity`` blanked."""
    body = dict(manifest)
    body.pop("integrity", None)
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _default_io():
    # lazy import: ckpt must stay importable without the runtime package
    from repro.runtime.resilience import CheckpointIO
    return CheckpointIO()


def save(directory: str | os.PathLike, step: int, tree: Any, *,
         keep: int = 3, extra: dict | None = None, io=None,
         retries: int = 3, base_delay: float = 0.05) -> pathlib.Path:
    """Atomic synchronous save with integrity metadata. Returns the path.

    Transient OSErrors from the array writes / final rename are retried up
    to `retries` times with capped exponential backoff; `io` injects the
    write/rename implementation (tests pass an IOFaultInjector).
    """
    from repro.runtime.resilience import retry_with_backoff
    io = io if io is not None else _default_io()
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    sweep_tmp(directory)
    final = directory / f"step_{step:010d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    # the span runs on whatever thread calls save() — for AsyncCheckpointer
    # that is the writer thread, which Perfetto renders as its own track of
    # the shared timeline (the overlap with train.step spans is the point)
    with obs.span("ckpt.save", step=step) as sp:
        try:
            leaves, treedef = _flatten(tree)
            sp.set(n_arrays=len(leaves))
            paths = []
            for i, leaf in enumerate(leaves):
                # NOT ascontiguousarray: it promotes 0-d scalars to (1,);
                # the crc below uses tobytes(), which canonicalizes order
                arr = np.asarray(jax.device_get(leaf))
                retry_with_backoff(
                    lambda a=arr, p=tmp / f"arr_{i}.npy": io.write_array(p, a),
                    retries=retries, base_delay=base_delay)
                paths.append({"file": f"arr_{i}.npy", "dtype": str(arr.dtype),
                              "shape": list(arr.shape),
                              "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF})
            manifest = {
                "step": step,
                "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
                if hasattr(treedef, "serialize_using_proto") else None,
                "n_arrays": len(leaves),
                "arrays": paths,
                "time": time.time(),
                "extra": extra or {},
            }
            manifest["integrity"] = _manifest_digest(manifest)
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            retry_with_backoff(lambda: io.rename(tmp, final),
                               retries=retries, base_delay=base_delay)
            io.post_commit(final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    ckpts = sorted(directory.glob("step_*"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    ckpts = sorted(directory.glob("step_*"))
    if not ckpts:
        return None
    return int(ckpts[-1].name.split("_")[1])


def available_steps(directory: str | os.PathLike) -> list[int]:
    """All checkpoint steps under `directory`, ascending."""
    directory = pathlib.Path(directory)
    return sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*"))


def verify(path: str | os.PathLike) -> dict:
    """Full integrity check of one checkpoint directory.

    Raises `CorruptionError` on: missing/unparseable manifest, manifest
    sha256 mismatch (a flipped byte anywhere in the manifest), a missing
    array file, an array whose bytes fail its crc32 (truncation or bit
    flips), or a shape/dtype that disagrees with the manifest entry.
    Returns the (verified) manifest. Pre-integrity checkpoints (no
    ``integrity`` field) fail verification — they carry no evidence.
    """
    path = pathlib.Path(path)
    mpath = path / "manifest.json"
    with obs.span("ckpt.verify", path=str(path)):
        return _verify_body(path, mpath)


def _verify_body(path: pathlib.Path, mpath: pathlib.Path) -> dict:
    try:
        manifest = json.loads(mpath.read_text())
    except (OSError, ValueError) as e:
        # ValueError covers JSONDecodeError AND UnicodeDecodeError — a
        # flipped byte can break utf-8 before the JSON parser ever runs
        raise CorruptionError(f"unreadable manifest {mpath}: {e}") from e
    digest = manifest.get("integrity")
    if digest is None:
        raise CorruptionError(
            f"{mpath} has no integrity digest (pre-integrity checkpoint or "
            "stripped manifest); cannot be verified")
    if _manifest_digest(manifest) != digest:
        raise CorruptionError(
            f"manifest integrity hash mismatch in {mpath}: the manifest was "
            "modified after it was written")
    for meta in manifest["arrays"]:
        apath = path / meta["file"]
        try:
            arr = np.load(apath)
        except (OSError, ValueError) as e:
            raise CorruptionError(
                f"array {apath} unreadable/truncated: {e}") from e
        if list(arr.shape) != list(meta["shape"]) or str(arr.dtype) != meta["dtype"]:
            raise CorruptionError(
                f"array {apath} header drift: got {arr.dtype}{arr.shape}, "
                f"manifest says {meta['dtype']}{tuple(meta['shape'])}")
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != meta["crc32"]:
            raise CorruptionError(
                f"array {apath} checksum mismatch: crc32 {crc:#010x} != "
                f"manifest {meta['crc32']:#010x} (bit flip or torn write)")
    return manifest


def is_verified(directory: str | os.PathLike, step: int) -> bool:
    try:
        verify(pathlib.Path(directory) / f"step_{step:010d}")
        return True
    except CorruptionError:
        return False


def newest_verified_step(directory: str | os.PathLike) -> int | None:
    """The newest step whose checkpoint passes `verify`, else None."""
    for step in reversed(available_steps(directory)):
        if is_verified(directory, step):
            return step
    return None


def restore(directory: str | os.PathLike, example_tree: Any,
            step: int | None = None, *, shardings: Any = None,
            verify_integrity: bool = True,
            fallback: bool = True) -> tuple[Any, int]:
    """Restore into the structure of `example_tree`; optionally re-shard.

    `shardings`: pytree of jax.sharding.Sharding (elastic restore onto a new
    mesh) — if None, arrays stay as committed host arrays.

    `verify_integrity`: run the full checksum/hash check before loading.
    `fallback`: when the selected checkpoint fails verification, walk back
    to the NEWEST checkpoint that does verify (corruption detection with
    automatic fallback); `CorruptionError` only when none survives. An
    explicit `step=` with `fallback=False` raises on that exact step.
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with obs.span("ckpt.restore", step=step) as sp:
        if verify_integrity:
            candidates = [step] + [s for s in
                                   reversed(available_steps(directory))
                                   if s < step]
            last_err: CorruptionError | None = None
            for cand in candidates:
                try:
                    verify(directory / f"step_{cand:010d}")
                    if cand != step:
                        # the fallback is a span attribute, not an event:
                        # train_loop owns the (exactly-one) ckpt.fallback
                        # metrics event so counts stay unambiguous
                        sp.set(fallback_from=step, step=cand)
                        step = cand
                    break
                except CorruptionError as e:
                    last_err = e
                    if not fallback:
                        raise
            else:
                raise CorruptionError(
                    f"no verifiable checkpoint under {directory} "
                    f"(newest failure: {last_err})")
        return _restore_body(directory, example_tree, step, shardings)


def _restore_body(directory: pathlib.Path, example_tree: Any, step: int,
                  shardings: Any) -> tuple[Any, int]:
    path = directory / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(example_tree)
    if manifest["n_arrays"] != len(leaves):
        raise CheckpointError(
            f"checkpoint {path} holds {manifest['n_arrays']} arrays but the "
            f"example tree has {len(leaves)} leaves: tree structure changed "
            "between save and restore")
    loaded = [np.load(path / meta["file"]) for meta in manifest["arrays"]]
    new_leaves = []
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings,
            is_leaf=lambda s: s is None or hasattr(s, "addressable_devices"))
        if len(shard_leaves) != len(loaded):
            raise CheckpointError(
                f"shardings tree has {len(shard_leaves)} leaves but the "
                f"checkpoint holds {len(loaded)} arrays: pass one sharding "
                "(or None) per restored leaf")
    else:
        shard_leaves = [None] * len(loaded)
    for i, (arr, ref, shd) in enumerate(zip(loaded, leaves, shard_leaves)):
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointError(
                f"array {i} of {path} has shape {tuple(arr.shape)} but the "
                f"example leaf expects {tuple(ref.shape)}: leaf shapes "
                "changed between save and restore")
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def read_manifest(directory: str | os.PathLike, step: int) -> dict:
    """The (unverified) manifest of one checkpoint step."""
    path = pathlib.Path(directory) / f"step_{step:010d}" / "manifest.json"
    return json.loads(path.read_text())


def _snapshot_async(tree: Any) -> Any:
    """Consistent device snapshot with the D2H transfer off the critical path.

    The caller's buffers may be DONATED to the next train step the moment
    `save` returns (donate_argnums), so the snapshot must not alias them:
    each jax leaf is copied device-side (an async dispatch — the copy's
    buffers belong to the checkpointer, not the caller) and its
    device-to-host transfer is started immediately with
    `copy_to_host_async`, so every leaf's D2H is in flight concurrently
    before the writer thread ever blocks on one. The writer thread then
    materializes (`np.asarray` waits on the already-running transfer) and
    the host buffers are donated to it outright — written out and dropped,
    never touched by the caller again.
    """
    def snap(x):
        if isinstance(x, jax.Array):
            x = jax.numpy.copy(x)  # device-side defensive copy (async dispatch)
            try:
                x.copy_to_host_async()
            except (AttributeError, RuntimeError):
                pass  # backend without async D2H: writer thread blocks
        return x
    return jax.tree.map(snap, tree)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training.

    `save` snapshots the tree with device-side copies and enqueues every
    leaf's device-to-host transfer (`_snapshot_async`), then hands the
    snapshot to a background thread that materializes and writes it — the
    caller's critical path holds no blocking transfer. A background failure
    raises on the NEXT `save` (before any new thread launches) and on
    `wait()`; use the instance as a context manager (or rely on the atexit
    hook) so a clean exit drains the in-flight checkpoint instead of
    dropping it.
    """

    def __init__(self, directory: str | os.PathLike, keep: int = 3, *,
                 io=None, retries: int = 3):
        self.directory = directory
        self.keep = keep
        self.io = io
        self.retries = retries
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._error: BaseException | None = None
        sweep_tmp(directory)  # crash-orphaned .tmp_* dirs from a prior run
        self._atexit = atexit.register(self._drain_at_exit)

    def _raise_pending(self) -> None:
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        # a failed background save fails THIS call, before a new thread
        # launches — not just the next wait()
        self._raise_pending()
        self.wait()
        host_tree = _snapshot_async(tree)

        def work():
            try:
                save(self.directory, step, host_tree, keep=self.keep,
                     extra=extra, io=self.io, retries=self.retries)
            except BaseException as e:  # surfaced on next save()/wait()
                with self._lock:
                    self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def close(self) -> None:
        """Drain the in-flight save and unregister the atexit hook."""
        try:
            self.wait()
        finally:
            atexit.unregister(self._drain_at_exit)

    def _drain_at_exit(self) -> None:
        # atexit: never raise, just make sure the bytes land
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> bool:
        if exc and exc[0] is not None:
            self._drain_at_exit()   # crashing: drain but keep the original
            return False
        self.close()
        return False
