"""Atomic, mesh-agnostic, async-capable checkpoints.

Layout: <dir>/step_<n>/{manifest.json, arr_<i>.npy ...}. Writes go to a tmp
directory that is atomically renamed, so a crash mid-save never corrupts the
latest checkpoint. Restore re-shards onto whatever mesh/sharding the restarted
job uses (elastic scaling): arrays are saved as full (addressable-gathered)
values and re-placed with jax.device_put against the new sharding.

On a real multi-host pod each host would write only its addressable shards
(same manifest format, `shard_id` field); this single-process implementation
writes full arrays, which is the degenerate single-host case of that layout.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str | os.PathLike, step: int, tree: Any, *,
         keep: int = 3, extra: dict | None = None) -> pathlib.Path:
    """Atomic synchronous save. Returns the final checkpoint path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    try:
        leaves, treedef = _flatten(tree)
        paths = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(tmp / f"arr_{i}.npy", arr)
            paths.append({"file": f"arr_{i}.npy", "dtype": str(arr.dtype),
                          "shape": list(arr.shape)})
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto") else None,
            "n_arrays": len(leaves),
            "arrays": paths,
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    ckpts = sorted(directory.glob("step_*"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    ckpts = sorted(directory.glob("step_*"))
    if not ckpts:
        return None
    return int(ckpts[-1].name.split("_")[1])


def restore(directory: str | os.PathLike, example_tree: Any,
            step: int | None = None, *, shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `example_tree`; optionally re-shard.

    `shardings`: pytree of jax.sharding.Sharding (elastic restore onto a new
    mesh) — if None, arrays stay as committed host arrays.
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(example_tree)
    assert manifest["n_arrays"] == len(leaves), (
        manifest["n_arrays"], len(leaves), "tree structure changed")
    loaded = [np.load(path / meta["file"]) for meta in manifest["arrays"]]
    new_leaves = []
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings,
            is_leaf=lambda s: s is None or hasattr(s, "addressable_devices"))
        assert len(shard_leaves) == len(loaded), (
            len(shard_leaves), len(loaded), "shardings tree mismatch")
    else:
        shard_leaves = [None] * len(loaded)
    for arr, ref, shd in zip(loaded, leaves, shard_leaves):
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training: device_get happens on the
    caller thread (cheap, consistent snapshot), the numpy writes happen on a
    background thread. `wait()` before the next save or at exit."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, keep=self.keep,
                     extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
