"""Sketched-state checkpoint codec: persist pytrees as (seed, spec, sketch).

The paper's memory argument applied to checkpoints: a tensorized random
projection is fully determined by a PRNG seed plus a declarative spec, so a
checkpointed error-feedback / optimizer tree never needs its dense bytes on
disk — only the `(n_buckets, k)` sketch (nb*k floats) plus the seed that
regenerates the operator. On restore the operator is re-sampled bitwise
identically from the saved seed (`rp.make_projector` is deterministic — the
same mechanism `rp/shard.py` uses to regenerate per host) and the dense
estimate comes back through one adjoint pass. The roundtrip is an unbiased
Thm-1-bounded ESTIMATE, not the exact tensor — which is exactly the error
class error-feedback state tolerates (the residual re-absorbs sketch error
the same way it absorbs compression error every step) — and it is fully
DETERMINISTIC: two restores of the same record are bit-identical, so
crash-restart remains reproducible.

On-disk record (one per encoded tree): {"y": (n_buckets, k) f32 sketch,
"seed": int64 base key, "step": int64 fold_in step}. The JSON-able
`meta()` (family/k/rank/dims/bucket sizes) goes into the checkpoint
manifest's `extra` so a restarted job — possibly on a DIFFERENT mesh —
rebuilds the codec via `from_meta` (bucket respec happens through the
sketcher's mesh/bucket_spec arguments; the sketch values themselves are
layout-independent).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import PytreeSketcher, SketchConfig

from .checkpointer import CheckpointError

#: default PRNG base key for checkpoint sketches — deliberately distinct
#: from SketchCompressor's 0x5EED so the checkpoint operator and the
#: gradient-compression operator of the same step are independent draws.
CKPT_KEY = 0xCC11


class SketchedTreeCodec:
    """Encode/decode a fixed-structure pytree through one shared sketch.

    encode(tree, step) -> {"y", "seed", "step"} record (arrays only, ready
    for the checkpointer); decode(record) -> dense unbiased estimate of the
    tree, operator regenerated from the record's own seed/step. Determinism:
    decode(encode(x, s)) is a pure function of (x, s, cfg, base_key).
    """

    def __init__(self, cfg: SketchConfig, example_tree: Any, *,
                 base_key: int = CKPT_KEY, mesh=None, bucket_spec=None):
        self.cfg = cfg
        self.base_key = int(base_key)
        self._sk = PytreeSketcher(cfg, example_tree, mesh=mesh,
                                  bucket_spec=bucket_spec)

    # -- key derivation (mirrors SketchCompressor._key) -------------------
    def key_for(self, step) -> jax.Array:
        key = jax.random.PRNGKey(self.base_key)
        if self.cfg.fresh_per_step:
            key = jax.random.fold_in(key, step)
        return key

    # -- codec ------------------------------------------------------------
    def encode(self, tree: Any, *, step: int) -> dict:
        """tree -> self-describing record of arrays (never the dense tree)."""
        y = self._sk.sketch(tree, self.key_for(step))
        # seed/step stay HOST scalars (np.int64): encode runs outside jit on
        # the save path, and x64 must not depend on jax_enable_x64
        return {"y": y, "seed": np.int64(self.base_key),
                "step": np.int64(step)}

    def decode(self, record: dict) -> Any:
        """record -> dense unbiased estimate; operator regenerated from the
        record's saved seed (no operator bytes were ever on disk)."""
        seed = int(np.asarray(record["seed"]))
        if seed != self.base_key:
            raise CheckpointError(
                f"sketched record was written with base key {seed:#x} but "
                f"this codec regenerates from {self.base_key:#x}; the "
                "reconstructed operator would not match the sketch")
        y = jnp.asarray(record["y"])
        if y.shape != (self._sk.n_buckets, self.cfg.k):
            raise CheckpointError(
                f"sketched record shape {tuple(y.shape)} != expected "
                f"({self._sk.n_buckets}, {self.cfg.k}); the encoded tree "
                "structure or SketchConfig changed between save and restore")
        return self._sk.unsketch(y, self.key_for(int(np.asarray(record["step"]))))

    # -- checkpoint integration -------------------------------------------
    def record_shapes(self) -> dict:
        """ShapeDtypeStruct record matching encode()'s output — the example
        tree the checkpointer restores a sketched record into."""
        return {"y": jax.ShapeDtypeStruct((self._sk.n_buckets, self.cfg.k),
                                          jnp.float32),
                "seed": jax.ShapeDtypeStruct((), jnp.int64),
                "step": jax.ShapeDtypeStruct((), jnp.int64)}

    def meta(self) -> dict:
        """JSON-able codec description for the checkpoint manifest `extra`."""
        return {"family": self.cfg.family, "k": self.cfg.k,
                "rank": self.cfg.rank, "dims": list(self.cfg.dims),
                "bucket_elems": self.cfg.bucket_elems,
                "fresh_per_step": self.cfg.fresh_per_step,
                "base_key": self.base_key,
                "n_buckets": self._sk.n_buckets}

    @classmethod
    def from_meta(cls, meta: dict, example_tree: Any, *, mesh=None,
                  bucket_spec=None) -> "SketchedTreeCodec":
        """Rebuild the codec a checkpoint was written with (elastic resume:
        pass the NEW mesh/bucket_spec — sketch values are layout-free)."""
        cfg = SketchConfig(family=meta["family"], k=int(meta["k"]),
                           rank=int(meta["rank"]),
                           dims=tuple(int(d) for d in meta["dims"]),
                           bucket_elems=int(meta["bucket_elems"]),
                           fresh_per_step=bool(meta["fresh_per_step"]))
        return cls(cfg, example_tree, base_key=int(meta["base_key"]),
                   mesh=mesh, bucket_spec=bucket_spec)

    # -- accounting (the checkpoint-size story) ---------------------------
    def sketch_bytes(self) -> int:
        return self._sk.sketch_bytes() + 16  # + seed/step scalars

    def dense_bytes(self) -> int:
        return self._sk.dense_bytes()

    def compression_ratio(self) -> float:
        return self.dense_bytes() / max(1, self.sketch_bytes())
