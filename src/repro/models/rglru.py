"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA
attention in a (rec, rec, attn) pattern.

RG-LRU (Real-Gated Linear Recurrent Unit, De et al. 2024):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_i x_t + b_i)          input gate
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan over the sequence (the
recurrence is linear); decode is a single-step update — O(1) state, which is
why this arch runs the long_500k shape.

Layer stack: L = 3*G + T layers; the repeated (rec, rec, attn) triple is
scanned over G groups; the T tail layers (rec) are unrolled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as nn
from . import settings
from .config import ArchConfig

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# Parameter spec
# ---------------------------------------------------------------------------

def _rec_spec(cfg: ArchConfig, lead: tuple[int, ...]):
    D, dr, W = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return {
        "norm1": (lead + (D,), ("layers", None), "norm"),
        "norm2": (lead + (D,), ("layers", None), "norm"),
        "w_x": (lead + (D, dr), ("layers", "embed", "mlp"), "fanin"),
        "w_y": (lead + (D, dr), ("layers", "embed", "mlp"), "fanin"),
        "conv_w": (lead + (W, dr), ("layers", None, "mlp"), "fanin"),
        "conv_b": (lead + (dr,), ("layers", "mlp"), "zeros"),
        "w_a": (lead + (dr, dr), ("layers", "mlp", "mlp2"), "fanin"),
        "b_a": (lead + (dr,), ("layers", "mlp"), "zeros"),
        "w_i": (lead + (dr, dr), ("layers", "mlp", "mlp2"), "fanin"),
        "b_i": (lead + (dr,), ("layers", "mlp"), "zeros"),
        "lam": (lead + (dr,), ("layers", "mlp"), "lambda"),
        "w_out": (lead + (dr, D), ("layers", "mlp", "embed"), "fanin"),
        # MLP half of the residual block
        "w_gate": (lead + (D, cfg.d_ff), ("layers", "embed", "mlp"), "fanin"),
        "w_up": (lead + (D, cfg.d_ff), ("layers", "embed", "mlp"), "fanin"),
        "w_down": (lead + (cfg.d_ff, D), ("layers", "mlp", "embed"), "fanin"),
    }


def _attn_spec(cfg: ArchConfig, lead: tuple[int, ...]):
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    return {
        "norm1": (lead + (D,), ("layers", None), "norm"),
        "norm2": (lead + (D,), ("layers", None), "norm"),
        "wq": (lead + (D, Hq * hd), ("layers", "embed", "heads"), "fanin"),
        "wk": (lead + (D, Hkv * hd), ("layers", "embed", "heads"), "fanin"),
        "wv": (lead + (D, Hkv * hd), ("layers", "embed", "heads"), "fanin"),
        "wo": (lead + (Hq * hd, D), ("layers", "heads", "embed"), "fanin"),
        "w_gate": (lead + (D, cfg.d_ff), ("layers", "embed", "mlp"), "fanin"),
        "w_up": (lead + (D, cfg.d_ff), ("layers", "embed", "mlp"), "fanin"),
        "w_down": (lead + (cfg.d_ff, D), ("layers", "mlp", "embed"), "fanin"),
    }


def _layout(cfg: ArchConfig) -> tuple[int, int]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    assert pat == ("rec", "rec", "attn"), pat
    groups = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * groups
    return groups, tail


def _spec(cfg: ArchConfig) -> dict[str, tuple]:
    G, T = _layout(cfg)
    D, V = cfg.d_model, cfg.vocab
    s: dict[str, Any] = {"embed": ((V, D), ("vocab_fsdp", "embed_tp"), "embed")}
    for name, sub in (("rec_a", _rec_spec(cfg, (G,))),
                      ("rec_b", _rec_spec(cfg, (G,))),
                      ("attn", _attn_spec(cfg, (G,)))):
        for k, v in sub.items():
            s[f"groups/{name}/{k}"] = v
    for t in range(T):
        for k, v in _rec_spec(cfg, ()).items():
            s[f"tail_{t}/{k}"] = (v[0], v[1][1:], v[2])
    s["final_norm"] = ((D,), (None,), "norm")
    return s


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    from .transformer import _assign
    params: dict[str, Any] = {}
    for i, (path, (shape, _, kind)) in enumerate(sorted(_spec(cfg).items())):
        k = jax.random.fold_in(key, i)
        if kind == "norm":
            leaf = jnp.ones(shape, dtype)
        elif kind == "zeros":
            leaf = jnp.zeros(shape, dtype)
        elif kind == "embed":
            leaf = jax.random.normal(k, shape, dtype) * 0.02
        elif kind == "lambda":
            # init so that a = exp(-c*softplus(lam)) in a healthy decay range
            u = jax.random.uniform(k, shape, dtype, 0.9, 0.999)
            leaf = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))
        else:
            leaf = jax.random.normal(k, shape, dtype) / (shape[-2] ** 0.5)
        _assign(params, path, leaf)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    from .transformer import _assign
    axes: dict[str, Any] = {}
    for path, (_, ax, _) in sorted(_spec(cfg).items()):
        _assign(axes, path, ax)
    return axes


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_scan(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
               lam: jnp.ndarray, h0: jnp.ndarray | None = None):
    """x, r, i: (B, S, dr). Returns (y (B,S,dr), h_last (B,dr)); f32 math."""
    x, r, i = (t.astype(jnp.float32) for t in (x, r, i))
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    if h0 is not None:
        # fold the initial state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0.astype(jnp.float32)[:, None, :], gated], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = bv if h0 is None else bv[:, 1:]
    return y, y[:, -1, :]


def rglru_step(x_t, r_t, i_t, lam, h):
    """Single decode step; all (B, dr); h (B, dr) f32."""
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i_t.astype(jnp.float32) * x_t.astype(jnp.float32))
    return h_new, h_new


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _rec_block_seq(cfg, lp_raw, lp, h):
    """Recurrent temporal block + MLP residual, full sequence."""
    B, S, D = h.shape
    hn = nn.rms_norm(h, lp_raw["norm1"])
    gx = hn @ lp["w_x"]                                   # (B, S, dr)
    gy = jax.nn.gelu(hn @ lp["w_y"], approximate=True)
    gx = nn.causal_depthwise_conv1d(gx, lp["conv_w"]) + lp["conv_b"]
    r = jax.nn.sigmoid(gx @ lp["w_a"] + lp["b_a"])
    i = jax.nn.sigmoid(gx @ lp["w_i"] + lp["b_i"])
    y, _ = rglru_scan(gx, r, i, lp_raw["lam"])
    out = (y.astype(h.dtype) * gy) @ lp["w_out"]
    h = h + out
    hn2 = nn.rms_norm(h, lp_raw["norm2"])
    return h + nn.geglu(hn2, lp["w_gate"], lp["w_up"], lp["w_down"])


def _attn_block_seq(cfg, lp_raw, lp, h, positions, window):
    B, S, D = h.shape
    hn = nn.rms_norm(h, lp_raw["norm1"])
    q = (hn @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (hn @ lp["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (hn @ lp["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = nn.apply_rope(q, positions, theta=cfg.rope_theta)
    k = nn.apply_rope(k, positions, theta=cfg.rope_theta)
    attn = nn.attention(q, k, v, positions, positions, causal=True,
                        window=window)
    h = h + attn.reshape(B, S, -1) @ lp["wo"]
    hn2 = nn.rms_norm(h, lp_raw["norm2"])
    return h + nn.geglu(hn2, lp["w_gate"], lp["w_up"], lp["w_down"])


def forward_hidden(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, *,
                   compute_dtype=jnp.bfloat16, remat: str = "nothing",
                   constrain=None, **_unused) -> jnp.ndarray:
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = params["embed"][tokens].astype(compute_dtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    window = cfg.window_for_layer  # local attn windows from pattern
    win = jnp.asarray(cfg.window_pattern[-1] or (1 << 30), jnp.int32)

    def group(h, gp_raw):
        gp = jax.tree.map(lambda a: a.astype(compute_dtype), gp_raw)
        h = _rec_block_seq(cfg, gp_raw["rec_a"], gp["rec_a"], h)
        h = _rec_block_seq(cfg, gp_raw["rec_b"], gp["rec_b"], h)
        h = _attn_block_seq(cfg, gp_raw["attn"], gp["attn"], h, positions, win)
        if constrain is not None:
            h = constrain(h)
        return h, None

    if remat != "none":
        group = jax.checkpoint(group, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(group, h, params["groups"],
                        unroll=settings.scan_unroll())
    G, T = _layout(cfg)
    for t in range(T):
        lp_raw = params[f"tail_{t}"]
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        h = _rec_block_seq(cfg, lp_raw, lp, h)
    return nn.rms_norm(h, params["final_norm"])


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.bfloat16, remat: str = "nothing",
            constrain=None, **_unused) -> jnp.ndarray:
    h = forward_hidden(cfg, params, batch["tokens"],
                       compute_dtype=compute_dtype, remat=remat,
                       constrain=constrain)
    return nn.chunked_ce_loss(h, params["embed"].T, batch["labels"])


# ---------------------------------------------------------------------------
# Decode — O(1) recurrent state + ring-buffer local-attention cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    G, T = _layout(cfg)
    dr, W = cfg.rnn_width, cfg.conv_width
    win = min(max_seq, int(cfg.window_pattern[-1] or max_seq))
    def rec_state(n):
        return {
            "h": jnp.zeros((n, batch, dr), jnp.float32),
            "conv": jnp.zeros((n, batch, W - 1, dr), dtype),
        }
    return {
        "rec_a": rec_state(G), "rec_b": rec_state(G),
        "attn": {
            "k": jnp.zeros((G, batch, cfg.n_kv_heads, win, cfg.hd), dtype),
            "v": jnp.zeros((G, batch, cfg.n_kv_heads, win, cfg.hd), dtype),
            # empty slots get a huge position so the causal mask excludes them
            "pos": jnp.full((G, batch, win), 1 << 30, jnp.int32),
        },
        "tail": rec_state(T),
    }


def _rec_block_step(cfg, lp_raw, lp, h, state):
    """h: (B, D) single token; state: {'h','conv'}."""
    hn = nn.rms_norm(h, lp_raw["norm1"])
    gx = hn @ lp["w_x"]
    gy = jax.nn.gelu(hn @ lp["w_y"], approximate=True)
    gx, conv_new = nn.conv1d_update(gx, state["conv"], lp["conv_w"])
    gx = gx + lp["conv_b"]
    r = jax.nn.sigmoid(gx @ lp["w_a"] + lp["b_a"])
    i = jax.nn.sigmoid(gx @ lp["w_i"] + lp["b_i"])
    y, h_new = rglru_step(gx, r, i, lp_raw["lam"], state["h"])
    out = (y.astype(h.dtype) * gy) @ lp["w_out"]
    h = h + out
    hn2 = nn.rms_norm(h, lp_raw["norm2"])
    h = h + nn.geglu(hn2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return h, {"h": h_new, "conv": conv_new}


def _attn_block_step(cfg, lp_raw, lp, h, state, pos, win_size):
    B = h.shape[0]
    hn = nn.rms_norm(h, lp_raw["norm1"])
    q = (hn @ lp["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
    k = (hn @ lp["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    v = (hn @ lp["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    pos_q = pos[:, None]
    q = nn.apply_rope(q, pos_q, theta=cfg.rope_theta)
    k = nn.apply_rope(k, pos_q, theta=cfg.rope_theta)
    slot = pos % win_size
    kc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0)))(
        state["k"], jnp.swapaxes(k, 1, 2).astype(state["k"].dtype), slot)
    vc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0)))(
        state["v"], jnp.swapaxes(v, 1, 2).astype(state["v"].dtype), slot)
    pos_buf = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p,)))(
        state["pos"], pos[:, None], slot)
    win = jnp.asarray(cfg.window_pattern[-1] or (1 << 30), jnp.int32)
    attn = nn.attention(q, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                        pos_q, pos_buf, causal=True, window=win,
                        dense_below=1 << 62)
    h = h + attn.reshape(B, -1) @ lp["wo"]
    hn2 = nn.rms_norm(h, lp_raw["norm2"])
    h = h + nn.geglu(hn2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return h, {"k": kc, "v": vc, "pos": pos_buf}


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, pos: jnp.ndarray, *,
                compute_dtype=jnp.bfloat16, **_unused):
    B = token.shape[0]
    h = params["embed"][token].astype(compute_dtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    win_size = cache["attn"]["k"].shape[3]

    def group(carry, xs):
        h = carry
        gp_raw, st_a, st_b, st_attn = xs
        gp = jax.tree.map(lambda a: a.astype(compute_dtype), gp_raw)
        h, st_a = _rec_block_step(cfg, gp_raw["rec_a"], gp["rec_a"], h, st_a)
        h, st_b = _rec_block_step(cfg, gp_raw["rec_b"], gp["rec_b"], h, st_b)
        h, st_attn = _attn_block_step(cfg, gp_raw["attn"], gp["attn"], h,
                                      st_attn, pos, win_size)
        return h, (st_a, st_b, st_attn)

    h, (st_a, st_b, st_attn) = jax.lax.scan(
        group, h, (params["groups"], cache["rec_a"], cache["rec_b"],
                   cache["attn"]), unroll=settings.scan_unroll())
    G, T = _layout(cfg)
    tail_state = dict(cache["tail"])
    for t in range(T):
        lp_raw = params[f"tail_{t}"]
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        st = {"h": cache["tail"]["h"][t], "conv": cache["tail"]["conv"][t]}
        h, st_new = _rec_block_step(cfg, lp_raw, lp, h, st)
        tail_state = {
            "h": tail_state["h"].at[t].set(st_new["h"]),
            "conv": tail_state["conv"].at[t].set(st_new["conv"]),
        }
    h = nn.rms_norm(h, params["final_norm"])
    logits = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    new_cache = {"rec_a": st_a, "rec_b": st_b, "attn": st_attn,
                 "tail": tail_state}
    return logits, new_cache
