"""Decoder-only transformer family: llama/deepseek/qwen (dense, GQA,
optional QKV bias), gemma2 (local-global alternation, softcaps, post-norms),
mixtral/arctic (MoE, optional dense-residual hybrid), qwen2-vl (M-RoPE +
patch-embedding stub).

Parameters are a flat dict with per-layer tensors stacked on a leading L axis
so the layer stack runs under lax.scan (+ remat). `param_axes` mirrors the
param tree with logical sharding axes consumed by launch/sharding.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as nn
from . import settings
from .config import ArchConfig, GLOBAL_WINDOW
from .moe import moe_capacity, moe_ffn


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------

def _spec(cfg: ArchConfig) -> dict[str, tuple[tuple[int, ...], tuple, str]]:
    """path -> (shape, logical_axes, init_kind)."""
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv, F, V, L = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab, cfg.n_layers
    s: dict[str, tuple] = {}
    s["embed"] = ((V, D), ("vocab_fsdp", "embed_tp"), "embed")
    lyr = {
        "norm1": ((L, D), ("layers", None), "norm"),
        "norm2": ((L, D), ("layers", None), "norm"),
        "wq": ((L, D, Hq * hd), ("layers", "embed", "heads"), "fanin"),
        "wk": ((L, D, Hkv * hd), ("layers", "embed", "heads"), "fanin"),
        "wv": ((L, D, Hkv * hd), ("layers", "embed", "heads"), "fanin"),
        "wo": ((L, Hq * hd, D), ("layers", "heads", "embed"), "fanin"),
    }
    if cfg.qkv_bias:
        lyr["bq"] = ((L, Hq * hd), ("layers", "heads"), "zeros")
        lyr["bk"] = ((L, Hkv * hd), ("layers", "heads"), "zeros")
        lyr["bv"] = ((L, Hkv * hd), ("layers", "heads"), "zeros")
    if cfg.post_norm:
        lyr["norm1_post"] = ((L, D), ("layers", None), "norm")
        lyr["norm2_post"] = ((L, D), ("layers", None), "norm")
    if cfg.moe is not None:
        e = cfg.moe
        lyr["router"] = ((L, D, e.num_experts), ("layers", "embed", None), "fanin")
        lyr["we_gate"] = ((L, e.num_experts, D, e.d_ff_expert),
                          ("layers", "experts", "embed", "expert_mlp"), "fanin")
        lyr["we_up"] = ((L, e.num_experts, D, e.d_ff_expert),
                        ("layers", "experts", "embed", "expert_mlp"), "fanin")
        lyr["we_down"] = ((L, e.num_experts, e.d_ff_expert, D),
                          ("layers", "experts", "expert_mlp", "embed"), "fanin")
        if e.dense_residual_ff:
            Fd = e.dense_residual_ff
            lyr["w_gate"] = ((L, D, Fd), ("layers", "embed", "mlp"), "fanin")
            lyr["w_up"] = ((L, D, Fd), ("layers", "embed", "mlp"), "fanin")
            lyr["w_down"] = ((L, Fd, D), ("layers", "mlp", "embed"), "fanin")
    else:
        lyr["w_gate"] = ((L, D, F), ("layers", "embed", "mlp"), "fanin")
        lyr["w_up"] = ((L, D, F), ("layers", "embed", "mlp"), "fanin")
        lyr["w_down"] = ((L, F, D), ("layers", "mlp", "embed"), "fanin")
    s.update({f"layers/{k}": v for k, v in lyr.items()})
    s["final_norm"] = ((D,), (None,), "norm")
    if not cfg.tie_embeddings:
        s["unembed"] = ((D, V), ("embed", "vocab"), "fanin")
    return s


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    params: dict[str, Any] = {}
    spec = _spec(cfg)
    for i, (path, (shape, _, kind)) in enumerate(sorted(spec.items())):
        k = jax.random.fold_in(key, i)
        if kind == "norm":
            leaf = jnp.zeros(shape, dtype) if cfg.norm_offset else jnp.ones(shape, dtype)
        elif kind == "zeros":
            leaf = jnp.zeros(shape, dtype)
        elif kind == "embed":
            leaf = jax.random.normal(k, shape, dtype) * 0.02
        else:  # fanin
            std = 1.0 / (shape[-2] ** 0.5)
            leaf = jax.random.normal(k, shape, dtype) * std
        _assign(params, path, leaf)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    axes: dict[str, Any] = {}
    for path, (_, ax, _) in sorted(_spec(cfg).items()):
        _assign(axes, path, ax)
    return axes


def _assign(tree: dict, path: str, leaf) -> None:
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = leaf


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rope(cfg: ArchConfig, x, positions, positions3):
    if cfg.mrope_sections is not None:
        return nn.apply_mrope(x, positions3, sections=cfg.mrope_sections,
                              theta=cfg.rope_theta)
    return nn.apply_rope(x, positions, theta=cfg.rope_theta)


def _ffn(cfg: ArchConfig, lp: dict, h_norm: jnp.ndarray, *,
         moe_groups: int, full_capacity: bool = False) -> jnp.ndarray:
    """FFN (dense / MoE / arctic hybrid) on (B, S, D). `full_capacity`
    disables token dropping (decode: a dropped token would corrupt the
    stream; T is tiny there so the buffer cost is negligible)."""
    B, S, D = h_norm.shape
    if cfg.moe is None:
        if cfg.mlp == "geglu":
            return nn.geglu(h_norm, lp["w_gate"], lp["w_up"], lp["w_down"])
        return nn.swiglu(h_norm, lp["w_gate"], lp["w_up"], lp["w_down"])
    flat = h_norm.reshape(B * S, D)
    cap = (B * S // max(moe_groups, 1)) if full_capacity else None
    out = moe_ffn(flat, lp["router"], lp["we_gate"], lp["we_up"],
                  lp["we_down"], cfg.moe, groups=moe_groups,
                  capacity=cap).reshape(B, S, D)
    if cfg.moe.dense_residual_ff:
        out = out + nn.swiglu(h_norm, lp["w_gate"], lp["w_up"], lp["w_down"])
    return out


def _qkv(cfg: ArchConfig, lp: dict, x: jnp.ndarray):
    B, S, _ = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    return (q.reshape(B, S, Hq, hd), k.reshape(B, S, Hkv, hd),
            v.reshape(B, S, Hkv, hd))


def forward_hidden(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, *,
                   positions: jnp.ndarray | None = None,
                   positions3: jnp.ndarray | None = None,
                   patches: jnp.ndarray | None = None,
                   patch_positions: jnp.ndarray | None = None,
                   compute_dtype=jnp.bfloat16,
                   remat: str = "nothing", moe_groups: int = 1,
                   constrain=None) -> jnp.ndarray:
    """Full-sequence forward to final hidden states (B, S, D).

    `constrain` (optional) re-asserts the residual-stream sharding each layer
    (sequence parallelism under pjit)."""
    B, S = tokens.shape
    D = cfg.d_model
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = params["embed"][tokens].astype(compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(D ** 0.5, compute_dtype)
    if patches is not None:
        # VLM stub: precomputed patch embeddings replace placeholder tokens.
        h = jax.vmap(lambda hh, pp, ii: hh.at[ii].set(pp))(
            h, patches.astype(compute_dtype), patch_positions)

    windows = jnp.asarray(cfg.window_array(), dtype=jnp.int32)

    def layer(h, xs):
        lp_raw, window = xs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.rms_norm(h, lp_raw["norm1"], offset=cfg.norm_offset)
        q, k, v = _qkv(cfg, lp, hn)
        q = _rope(cfg, q, positions, positions3)
        k = _rope(cfg, k, positions, positions3)
        attn = nn.attention(q, k, v, positions, positions,
                            causal=True, window=window,
                            softcap=cfg.attn_softcap)
        attn = attn.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["wo"]
        if settings.get().sp_block_outputs and constrain is not None:
            attn = constrain(attn)  # partial sums lower to reduce-scatter
        if cfg.post_norm:
            attn = nn.rms_norm(attn, lp_raw["norm1_post"], offset=cfg.norm_offset)
        h = h + attn
        hn2 = nn.rms_norm(h, lp_raw["norm2"], offset=cfg.norm_offset)
        ff = _ffn(cfg, lp, hn2, moe_groups=moe_groups)
        if settings.get().sp_block_outputs and constrain is not None:
            ff = constrain(ff)
        if cfg.post_norm:
            ff = nn.rms_norm(ff, lp_raw["norm2_post"], offset=cfg.norm_offset)
        h = h + ff
        if constrain is not None:
            h = constrain(h)
        return h, None

    if remat == "nothing":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    h, _ = jax.lax.scan(layer, h, (params["layers"], windows),
                        unroll=settings.scan_unroll())
    return nn.rms_norm(h, params["final_norm"], offset=cfg.norm_offset)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.bfloat16, remat: str = "nothing",
            moe_groups: int = 1, constrain=None) -> jnp.ndarray:
    h = forward_hidden(cfg, params, batch["tokens"],
                       positions3=batch.get("positions3"),
                       patches=batch.get("patches"),
                       patch_positions=batch.get("patch_positions"),
                       compute_dtype=compute_dtype, remat=remat,
                       moe_groups=moe_groups, constrain=constrain)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return nn.chunked_ce_loss(h, unembed, batch["labels"],
                              softcap=cfg.final_softcap,
                              mask=batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# Decode (single-token serve step with KV cache)
# ---------------------------------------------------------------------------

def cache_len(cfg: ArchConfig, max_seq: int) -> int:
    """Ring-buffer length: bounded by the largest attention window when every
    layer is windowed (e.g. mixtral SWA -> 4096 slots even at 512k context)."""
    widest = max(cfg.window_for_layer(i) for i in range(cfg.n_layers))
    return min(max_seq, widest)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    C = cache_len(cfg, max_seq)
    return {
        "k": jnp.zeros((L, batch, Hkv, C, hd), dtype),
        "v": jnp.zeros((L, batch, Hkv, C, hd), dtype),
        # absolute position per slot; huge sentinel = empty (causally masked)
        "pos": jnp.full((L, batch, C), 1 << 30, jnp.int32),
    }


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, pos: jnp.ndarray, *,
                positions3: jnp.ndarray | None = None,
                compute_dtype=jnp.bfloat16, moe_groups: int = 1):
    """token: (B,) int32; pos: (B,) int32 (cache write index per sequence).

    Returns (logits (B, V) f32, new_cache).
    """
    B = token.shape[0]
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    C = cache["k"].shape[3]
    h = params["embed"][token].astype(compute_dtype)[:, None, :]  # (B, 1, D)
    if cfg.embed_scale:
        h = h * jnp.asarray(D ** 0.5, compute_dtype)
    pos_q = pos[:, None]                                  # (B, 1)
    slot = pos % C                                        # ring-buffer slot
    windows = jnp.asarray(cfg.window_array(), dtype=jnp.int32)

    def layer(h, xs):
        lp_raw, window, kc, vc, pc = xs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.rms_norm(h, lp_raw["norm1"], offset=cfg.norm_offset)
        q, k, v = _qkv(cfg, lp, hn)                       # (B, 1, H*, hd)
        q = _rope(cfg, q, pos_q, positions3)
        k = _rope(cfg, k, pos_q, positions3)
        kc = jax.vmap(
            lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0))
        )(kc, jnp.swapaxes(k, 1, 2).astype(kc.dtype), slot)
        vc = jax.vmap(
            lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0))
        )(vc, jnp.swapaxes(v, 1, 2).astype(vc.dtype), slot)
        pc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p,))
                      )(pc, pos[:, None], slot)
        attn = nn.attention(q, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                            pos_q, pc, causal=True, window=window,
                            softcap=cfg.attn_softcap,
                            dense_below=1 << 62)
        attn = attn.reshape(B, 1, Hq * hd) @ lp["wo"]
        if cfg.post_norm:
            attn = nn.rms_norm(attn, lp_raw["norm1_post"], offset=cfg.norm_offset)
        h = h + attn
        hn2 = nn.rms_norm(h, lp_raw["norm2"], offset=cfg.norm_offset)
        ff = _ffn(cfg, lp, hn2, moe_groups=moe_groups, full_capacity=True)
        if cfg.post_norm:
            ff = nn.rms_norm(ff, lp_raw["norm2_post"], offset=cfg.norm_offset)
        return h + ff, (kc, vc, pc)

    h, (k_new, v_new, p_new) = jax.lax.scan(
        layer, h, (params["layers"], windows, cache["k"], cache["v"],
                   cache["pos"]), unroll=settings.scan_unroll())
    h = nn.rms_norm(h, params["final_norm"], offset=cfg.norm_offset)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h[:, 0, :].astype(jnp.float32) @ unembed.astype(jnp.float32)
    logits = nn.soft_cap(logits, cfg.final_softcap)
    return logits, {"k": k_new, "v": v_new, "pos": p_new}


def prefill(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, max_seq: int,
            *, positions3=None, compute_dtype=jnp.bfloat16,
            moe_groups: int = 1):
    """Run the prompt, return (last-token logits, filled cache).

    Simple full-forward prefill that also returns the per-layer K/V.
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = params["embed"][tokens].astype(compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    windows = jnp.asarray(cfg.window_array(), dtype=jnp.int32)

    def layer(h, xs):
        lp_raw, window = xs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.rms_norm(h, lp_raw["norm1"], offset=cfg.norm_offset)
        q, k, v = _qkv(cfg, lp, hn)
        q = _rope(cfg, q, positions, positions3)
        k = _rope(cfg, k, positions, positions3)
        attn = nn.attention(q, k, v, positions, positions, causal=True,
                            window=window, softcap=cfg.attn_softcap)
        attn = attn.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["wo"]
        if cfg.post_norm:
            attn = nn.rms_norm(attn, lp_raw["norm1_post"], offset=cfg.norm_offset)
        h = h + attn
        hn2 = nn.rms_norm(h, lp_raw["norm2"], offset=cfg.norm_offset)
        ff = _ffn(cfg, lp, hn2, moe_groups=moe_groups)
        if cfg.post_norm:
            ff = nn.rms_norm(ff, lp_raw["norm2_post"], offset=cfg.norm_offset)
        C = cache_len(cfg, max_seq)
        assert S <= C, "prefill prompt longer than cache"
        kpad = jnp.zeros((B, cfg.n_kv_heads, C, cfg.hd), compute_dtype)
        kpad = jax.lax.dynamic_update_slice(
            kpad, jnp.swapaxes(k, 1, 2).astype(compute_dtype), (0, 0, 0, 0))
        vpad = jnp.zeros((B, cfg.n_kv_heads, C, cfg.hd), compute_dtype)
        vpad = jax.lax.dynamic_update_slice(
            vpad, jnp.swapaxes(v, 1, 2).astype(compute_dtype), (0, 0, 0, 0))
        return h + ff, (kpad, vpad)

    h, (kc, vc) = jax.lax.scan(layer, h, (params["layers"], windows),
                               unroll=settings.scan_unroll())
    h = nn.rms_norm(h, params["final_norm"], offset=cfg.norm_offset)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h[:, -1, :].astype(jnp.float32) @ unembed.astype(jnp.float32)
    logits = nn.soft_cap(logits, cfg.final_softcap)
    C = cache_len(cfg, max_seq)
    pos_buf = jnp.broadcast_to(
        jnp.where(jnp.arange(C) < S, jnp.arange(C), 1 << 30),
        (cfg.n_layers, B, C)).astype(jnp.int32)
    return logits, {"k": kc, "v": vc, "pos": pos_buf}
