"""Mamba-2 (SSD — state-space duality, Dao & Gu 2024), attention-free.

Block: in_proj -> (z, xBC, dt); causal depthwise conv on xBC; SSD over heads
with scalar-per-head decay A; D skip; gated RMSNorm; out_proj.

Training/prefill uses the chunked dual form: quadratic attention-like math
inside chunks of length Q, linear recurrence across chunks (lax.scan).
Decode is a single recurrence step on the (B, H, hd, ds) state — O(1) per
token, which is why this arch runs long_500k.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as nn
from . import settings
from .config import ArchConfig


def _dims(cfg: ArchConfig):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = d_in // cfg.ssm_head_dim
    ds = cfg.ssm_state
    G = cfg.ssm_groups
    conv_ch = d_in + 2 * G * ds
    return D, d_in, H, ds, G, conv_ch


def _spec(cfg: ArchConfig) -> dict[str, tuple]:
    D, d_in, H, ds, G, conv_ch = _dims(cfg)
    L, V, W = cfg.n_layers, cfg.vocab, cfg.conv_width
    proj_out = 2 * d_in + 2 * G * ds + H
    s: dict[str, Any] = {"embed": ((V, D), ("vocab_fsdp", "embed_tp"), "embed")}
    lyr = {
        "norm": ((L, D), ("layers", None), "norm"),
        "in_proj": ((L, D, proj_out), ("layers", "embed", "mlp"), "fanin"),
        "conv_w": ((L, W, conv_ch), ("layers", None, "mlp"), "fanin"),
        "conv_b": ((L, conv_ch), ("layers", "mlp"), "zeros"),
        "a_log": ((L, H), ("layers", None), "a_log"),
        "d_skip": ((L, H), ("layers", None), "ones"),
        "dt_bias": ((L, H), ("layers", None), "dt_bias"),
        "norm_gate": ((L, d_in), ("layers", "mlp"), "norm"),
        "out_proj": ((L, d_in, D), ("layers", "mlp", "embed"), "fanin"),
    }
    s.update({f"layers/{k}": v for k, v in lyr.items()})
    s["final_norm"] = ((D,), (None,), "norm")
    return s


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    from .transformer import _assign
    params: dict[str, Any] = {}
    for i, (path, (shape, _, kind)) in enumerate(sorted(_spec(cfg).items())):
        k = jax.random.fold_in(key, i)
        if kind in ("norm", "ones"):
            leaf = jnp.ones(shape, dtype)
        elif kind == "zeros":
            leaf = jnp.zeros(shape, dtype)
        elif kind == "embed":
            leaf = jax.random.normal(k, shape, dtype) * 0.02
        elif kind == "a_log":
            leaf = jnp.log(jax.random.uniform(k, shape, dtype, 1.0, 16.0))
        elif kind == "dt_bias":
            # softplus^-1 of dt in [1e-3, 0.1]
            dt = jnp.exp(jax.random.uniform(k, shape, dtype) *
                         (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
            leaf = dt + jnp.log(-jnp.expm1(-dt))
        else:
            leaf = jax.random.normal(k, shape, dtype) / (shape[-2] ** 0.5)
        _assign(params, path, leaf)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    from .transformer import _assign
    axes: dict[str, Any] = {}
    for path, (_, ax, _) in sorted(_spec(cfg).items()):
        _assign(axes, path, ax)
    return axes


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{j < t <= i} x[t]
    for i >= j, -inf otherwise."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bmat: jnp.ndarray, cmat: jnp.ndarray, *, chunk: int,
                h0: jnp.ndarray | None = None):
    """Chunked SSD.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative decay rates;
    bmat/cmat: (B, S, G, N) with heads split evenly across G groups.
    Returns (y (B, S, H, P) f32, h_last (B, H, P, N) f32).
    """
    Bsz, S, H, P = x.shape
    G, N = bmat.shape[2], bmat.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    bh = jnp.repeat(bmat.astype(jnp.float32), rep, axis=2)   # (B, S, H, N)
    ch = jnp.repeat(cmat.astype(jnp.float32), rep, axis=2)
    da = dt * a.astype(jnp.float32)                          # (B, S, H)

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    dac = da.reshape(Bsz, nc, chunk, H)
    bc = bh.reshape(Bsz, nc, chunk, H, N)
    cc = ch.reshape(Bsz, nc, chunk, H, N)

    cum = jnp.cumsum(dac, axis=2)                            # (B, nc, Q, H)
    # intra-chunk (dual quadratic form)
    seg = _segsum(jnp.moveaxis(dac, 3, 2))                   # (B, nc, H, Q, Q)
    ldecay = jnp.exp(seg)
    scores = jnp.einsum("bcihn,bcjhn->bchij", cc, bc)
    m = scores * ldecay * jnp.moveaxis(dtc, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", m, xc)

    # end-of-chunk states
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)             # (B, nc, Q, H)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay_out * dtc, bc, xc)             # (B, nc, H, P, N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B, nc, H)

    def body(h, xs):
        st, dec = xs                                          # (B,H,P,N),(B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    h_last, prev = jax.lax.scan(
        body, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=settings.scan_unroll())
    prev = jnp.moveaxis(prev, 0, 1)                          # (B, nc, H, P, N)

    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cc, prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_last


def ssd_step(x_t, dt_t, a, b_t, c_t, h):
    """One-token SSD update. x_t: (B,H,P); dt_t: (B,H); b_t/c_t: (B,G,N);
    h: (B,H,P,N). Returns (y (B,H,P), h_new)."""
    H = x_t.shape[1]
    G = b_t.shape[1]
    rep = H // G
    bh = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)    # (B,H,N)
    chh = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    da = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32))  # (B,H)
    h_new = (h * da[:, :, None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dt_t.astype(jnp.float32), bh,
                          x_t.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", chh, h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# Blocks / model
# ---------------------------------------------------------------------------

def _split_proj(cfg, zxbcdt):
    D, d_in, H, ds, G, conv_ch = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)
    return z, xbc, dt


def _block_seq(cfg, lp_raw, lp, h, *, chunk):
    Bsz, S, D = h.shape
    _, d_in, H, ds, G, conv_ch = _dims(cfg)
    P = cfg.ssm_head_dim
    hn = nn.rms_norm(h, lp_raw["norm"])
    z, xbc, dt_raw = _split_proj(cfg, hn @ lp["in_proj"])
    xbc = jax.nn.silu(nn.causal_depthwise_conv1d(xbc, lp["conv_w"]) + lp["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + G * ds], axis=-1)
    xs = xs.reshape(Bsz, S, H, P)
    bmat = bmat.reshape(Bsz, S, G, ds)
    cmat = cmat.reshape(Bsz, S, G, ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp_raw["dt_bias"])
    a = -jnp.exp(lp_raw["a_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs, dt, a, bmat, cmat, chunk=chunk)
    y = y + lp_raw["d_skip"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in)
    y = nn.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype),
                    lp_raw["norm_gate"])
    return h + y @ lp["out_proj"]


def forward_hidden(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, *,
                   compute_dtype=jnp.bfloat16, remat: str = "nothing",
                   constrain=None, **_unused) -> jnp.ndarray:
    Bsz, S = tokens.shape
    h = params["embed"][tokens].astype(compute_dtype)
    chunk = min(cfg.ssm_chunk, S)
    while S % chunk:
        chunk //= 2

    def layer(h, lp_raw):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        h = _block_seq(cfg, lp_raw, lp, h, chunk=chunk)
        if constrain is not None:
            h = constrain(h)
        return h, None

    if remat != "none":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["layers"],
                        unroll=settings.scan_unroll())
    return nn.rms_norm(h, params["final_norm"])


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.bfloat16, remat: str = "nothing",
            constrain=None, **_unused) -> jnp.ndarray:
    h = forward_hidden(cfg, params, batch["tokens"],
                       compute_dtype=compute_dtype, remat=remat,
                       constrain=constrain)
    return nn.chunked_ce_loss(h, params["embed"].T, batch["labels"])


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    D, d_in, H, ds, G, conv_ch = _dims(cfg)
    L, W, P = cfg.n_layers, cfg.conv_width, cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((L, batch, H, P, ds), jnp.float32),
        "conv": jnp.zeros((L, batch, W - 1, conv_ch), dtype),
    }


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, pos: jnp.ndarray, *,
                compute_dtype=jnp.bfloat16, **_unused):
    del pos  # state carries all history; position is implicit
    Bsz = token.shape[0]
    D, d_in, H, ds, G, conv_ch = _dims(cfg)
    P = cfg.ssm_head_dim
    h = params["embed"][token].astype(compute_dtype)  # (B, D)

    def layer(carry, xs):
        h = carry
        lp_raw, ssm_st, conv_st = xs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.rms_norm(h, lp_raw["norm"])
        z, xbc, dt_raw = _split_proj(cfg, hn @ lp["in_proj"])
        xbc, conv_new = nn.conv1d_update(xbc, conv_st, lp["conv_w"])
        xbc = jax.nn.silu(xbc + lp["conv_b"])
        xs_t, b_t, c_t = jnp.split(xbc, [d_in, d_in + G * ds], axis=-1)
        xs_t = xs_t.reshape(Bsz, H, P)
        b_t = b_t.reshape(Bsz, G, ds)
        c_t = c_t.reshape(Bsz, G, ds)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp_raw["dt_bias"])
        a = -jnp.exp(lp_raw["a_log"].astype(jnp.float32))
        y, ssm_new = ssd_step(xs_t, dt, a, b_t, c_t, ssm_st)
        y = y + lp_raw["d_skip"].astype(jnp.float32)[:, None] * xs_t.astype(jnp.float32)
        y = y.reshape(Bsz, d_in)
        y = nn.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype),
                        lp_raw["norm_gate"])
        return h + y @ lp["out_proj"], (ssm_new, conv_new.astype(conv_st.dtype))

    h, (ssm_new, conv_new) = jax.lax.scan(
        layer, h, (params["layers"], cache["ssm"], cache["conv"]),
        unroll=settings.scan_unroll())
    h = nn.rms_norm(h, params["final_norm"])
    logits = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, {"ssm": ssm_new, "conv": conv_new}
