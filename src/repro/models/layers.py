"""Shared model layers: norms, RoPE / M-RoPE, memory-efficient attention,
MLP variants, causal depthwise conv, chunked cross-entropy.

Everything is a pure function over explicit parameter arrays so that models
compose under jit / scan / remat / shard_map without a module framework.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from . import settings

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6,
             offset: float = 0.0) -> jnp.ndarray:
    """RMSNorm; gemma-style uses offset=1.0 (weight stored as w-1)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (weight.astype(jnp.float32) + offset)).astype(dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               *, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def soft_cap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs  # (..., half)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S). Rotate-half (llama) convention."""
    angles = _rope_angles(positions, x.shape[-1], theta)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, *,
                sections: Sequence[int] = (16, 24, 24),
                theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, dh); positions3: (3, B, S) temporal/height/width ids.
    Frequency slots are partitioned into `sections` (sum == dh//2); slot j in
    section c rotates by positions3[c].
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    pos = positions3[sec_ids]                       # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                  # (B, S, half)
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = pos.astype(jnp.float32) * freqs        # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — GQA + causal/window masking + optional logit softcap.
# Dense path for short sequences, scan-flash (online softmax over KV chunks,
# outer scan over Q chunks) for long ones: peak memory O(Cq*Ck) per head.
# ---------------------------------------------------------------------------

def _mask(pq: jnp.ndarray, pk: jnp.ndarray, *, causal: bool,
          window) -> jnp.ndarray:
    """pq: (..., Sq), pk: (..., Sk) -> bool (..., Sq, Sk). window may be a
    traced scalar (per-layer local/global alternation under scan)."""
    diff = pq[..., :, None] - pk[..., None, :]
    m = jnp.ones(diff.shape, dtype=bool)
    if causal:
        m &= diff >= 0
    if window is not None:
        m &= diff < window
    return m


def _constrain_heads(x, head_axis: int):
    """Pin batch->data axes and heads->model on attention operands so the
    expanded-GQA score intermediates shard instead of replicating (the mesh
    comes from trace-time settings; no-op outside pjit)."""
    mesh = settings.get().mesh
    if mesh is None or not settings.get().constrain_attn_heads:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    names = mesh.axis_names
    manual = settings.get().manual_axes
    dp = tuple(a for a in names if a in ("pod", "data") and a not in manual)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    ms = mesh.shape["model"] if "model" in names else 1
    entries = [None] * x.ndim
    if x.shape[0] % dp_size == 0:
        entries[0] = dp
    if x.shape[head_axis] % ms == 0:
        entries[head_axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*entries)))


def _attend_dense(q, k, v, pq, pk, *, causal, window, softcap, scale):
    """q: (B,Sq,Hkv,G,dh); k,v: (B,Sk,Hkv,dh); pq/pk: (B,S*)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = soft_cap(s, softcap)
    m = _mask(pq, pk, causal=causal, window=window)  # (B, Sq, Sk)
    s = jnp.where(m[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out


def _attend_flash(q, k, v, pq, pk, *, causal, window, softcap, scale,
                  chunk_q: int, chunk_k: int):
    """Same contract as _attend_dense; O(chunk_q*chunk_k) score memory.

    KV heads are expanded to the full query-head count first: GQA's grouped
    (Hkv, G) layout leaves both head dims smaller than the tensor-parallel
    degree (e.g. 8 < 16), which forces XLA to replicate every score/softmax
    intermediate. Expanded, the head axis is Hq and shards cleanly; the extra
    KV activation bytes are negligible next to replicated score blocks.
    """
    B, Sq, Hkv, G, dh = q.shape
    if G > 1 and settings.get().gqa_expand:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        q = q.reshape(B, Sq, Hkv * G, 1, dh)
        B, Sq, Hkv, G, dh = q.shape
    k = _constrain_heads(k, 2)
    v = _constrain_heads(v, 2)
    q = _constrain_heads(q, 2)
    Sk = k.shape[1]
    nq, nk = Sq // chunk_q, Sk // chunk_k
    assert Sq % chunk_q == 0 and Sk % chunk_k == 0, (Sq, chunk_q, Sk, chunk_k)
    unroll = settings.scan_unroll()

    qc = jnp.moveaxis(q.reshape(B, nq, chunk_q, Hkv, G, dh), 1, 0)
    pqc = jnp.moveaxis(pq.reshape(B, nq, chunk_q), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, chunk_k, Hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, chunk_k, Hkv, dh), 1, 0)
    pkc = jnp.moveaxis(pk.reshape(B, nk, chunk_k), 1, 0)

    def q_block(qi, pqi):
        def body(carry, xs):
            m_run, l_run, acc = carry
            ki, vi, pki = xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            s = soft_cap(s, softcap)
            msk = _mask(pqi, pki, causal=causal, window=window)
            s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.where(msk[:, None, None, :, :], jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            if settings.get().flash_p_bf16:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd",
                                p.astype(jnp.bfloat16), vi,
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                vi.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        init = (jnp.full((B, Hkv, G, chunk_q), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, chunk_q), jnp.float32),
                jnp.zeros((B, Hkv, G, chunk_q, dh), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(body, init, (kc, vc, pkc),
                                          unroll=unroll)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (B,Hkv,G,Cq,dh)
        return jnp.moveaxis(out, 3, 1)                    # (B,Cq,Hkv,G,dh)

    # remat: the backward pass recomputes each q-block's kv scan instead of
    # storing O(Sq*Sk) score intermediates (flash-attention memory profile).
    q_block = jax.checkpoint(q_block,
                             policy=jax.checkpoint_policies.nothing_saveable)
    _, out_blocks = jax.lax.scan(lambda c, xs: (c, q_block(*xs)), 0,
                                 (qc, pqc), unroll=unroll)
    out = jnp.moveaxis(out_blocks, 0, 1).reshape(B, Sq, Hkv, G, dh)
    return out


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              positions_q: jnp.ndarray, positions_k: jnp.ndarray, *,
              causal: bool = True, window=None, softcap: float | None = None,
              chunk_q: int | None = None, chunk_k: int | None = None,
              dense_below: int | None = None) -> jnp.ndarray:
    """GQA attention. q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh).

    Returns (B, Sq, Hq, dh) in q.dtype. `window` may be a traced scalar.
    Chunking defaults come from models.settings (trace-time config).
    """
    cfg = settings.get()
    chunk_q = chunk_q if chunk_q is not None else cfg.attn_chunk_q
    chunk_k = chunk_k if chunk_k is not None else cfg.attn_chunk_k
    dense_below = dense_below if dense_below is not None else cfg.dense_below
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, dh)
    scale = 1.0 / math.sqrt(dh)
    Sk = k.shape[1]
    if Sq * Sk <= dense_below or Sq % min(chunk_q, Sq) != 0:
        out = _attend_dense(qg, k, v, positions_q, positions_k, causal=causal,
                            window=window, softcap=softcap, scale=scale)
    else:
        cq = min(chunk_q, Sq)
        ck = min(chunk_k, Sk)
        while Sk % ck:
            ck //= 2
        out = _attend_flash(qg, k, v, positions_q, positions_k, causal=causal,
                            window=window, softcap=softcap, scale=scale,
                            chunk_q=cq, chunk_k=ck)
    return out.reshape(B, Sq, Hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def geglu(x, w_gate, w_up, w_down):
    h = jax.nn.gelu(x @ w_gate, approximate=True) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    return jax.nn.gelu(x @ w_in + b_in, approximate=True) @ w_out + b_out


# ---------------------------------------------------------------------------
# Causal depthwise conv (mamba2 / rglru / whisper-frontend building block)
# ---------------------------------------------------------------------------

def causal_depthwise_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (W, C). Left-pads so output[t] sees x[t-W+1..t]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],  # (W, 1, C) -> spatial, in/feature-group, out
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out


def conv1d_update(x_t: jnp.ndarray, conv_state: jnp.ndarray,
                  w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token causal conv. x_t: (B, C); conv_state: (B, W-1, C)."""
    W = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return out, window[:, -(W - 1):, :] if W > 1 else conv_state


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_ce_loss(h: jnp.ndarray, unembed: jnp.ndarray, labels: jnp.ndarray,
                    *, chunk: int | None = None, softcap: float | None = None,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Cross-entropy without materializing (B, S, V) logits.

    h: (B, S, D) final hidden states; unembed: (D, V); labels: (B, S).
    """
    B, S, D = h.shape
    chunk = min(chunk if chunk is not None else settings.get().ce_chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    hc = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(carry, xs):
        tot, cnt = carry
        hi, li, mi = xs
        logits = (hi.astype(jnp.float32) @ unembed.astype(jnp.float32))
        logits = soft_cap(logits, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    # remat: recompute each (chunk, V) logits block in the backward pass.
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc, mc), unroll=settings.scan_unroll())
    return tot / jnp.maximum(cnt, 1.0)
