"""Architecture configuration schema consumed by the model families and the
launch layer. One instance per assigned architecture lives in repro/configs/.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

GLOBAL_WINDOW = 1 << 30  # sentinel: "no window" as a dynamic window value


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual_ff: Optional[int] = None  # arctic dense-MoE hybrid
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str            # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'
    skip: Optional[str] = None  # reason if inapplicable for this arch


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str          # 'decoder' | 'encdec' | 'hybrid' | 'ssm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"                     # 'swiglu' | 'geglu' | 'gelu'
    norm: str = "rms"                       # 'rms' | 'ln'
    norm_offset: float = 0.0                # gemma-style (1 + w) rmsnorm
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_norm: bool = False                 # gemma2 post-block rmsnorms
    embed_scale: bool = False               # gemma-style sqrt(D) embed scaling
    window_pattern: tuple = (None,)         # cycles over layers; None=global
    moe: Optional[MoESpec] = None
    mrope_sections: Optional[tuple] = None  # qwen2-vl (t,h,w) freq sections
    # hybrid (recurrentgemma / griffin)
    rnn_width: Optional[int] = None
    block_pattern: Optional[tuple] = None   # e.g. ('rec','rec','attn')
    # ssm (mamba2)
    ssm_state: Optional[int] = None
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_width: int = 4
    # enc-dec (whisper)
    encoder_layers: Optional[int] = None
    encoder_seq: Optional[int] = None       # e.g. 1500 audio frames
    # modality frontend stub: 'audio' (frames) | 'vision' (patches)
    frontend: Optional[str] = None
    num_patches: int = 256                  # vlm stub: patches per image
    tie_embeddings: bool = False
    policy: str = "mixed"                   # 'mixed' | 'lean'
    shapes: tuple = ()

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def window_for_layer(self, i: int) -> int:
        w = self.window_pattern[i % len(self.window_pattern)]
        return GLOBAL_WINDOW if w is None else int(w)

    def window_array(self):
        return [self.window_for_layer(i) for i in range(self.n_layers)]

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name}: no shape {name}; have "
                       f"{[s.name for s in self.shapes]}")

    # -- parameter counting (for MODEL_FLOPS = 6*N*D roofline term) -------
    def param_count(self) -> int:
        D = self.d_model
        F, V, L = self.d_ff, self.vocab, self.n_layers
        total = V * D + D  # embed + final norm
        if not self.tie_embeddings:
            total += D * V
        if self.family == "ssm":
            d_in = self.ssm_expand * D
            H = d_in // self.ssm_head_dim
            conv_ch = d_in + 2 * self.ssm_groups * self.ssm_state
            per = (D * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + H)
                   + conv_ch * self.conv_width + 3 * H + d_in + d_in * D + D)
            return total + L * per
        hd = self.hd
        Hq, Hkv = self.n_heads, self.n_kv_heads
        attn = D * Hq * hd + 2 * D * Hkv * hd + Hq * hd * D
        if self.qkv_bias:
            attn += (Hq + 2 * Hkv) * hd
        if self.mlp in ("swiglu", "geglu"):
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F + F + D
        if self.family == "hybrid":
            dr = self.rnn_width
            rec = (D * dr * 2 + dr * self.conv_width + 2 * dr * dr // 1
                   + 2 * dr + dr * D + D)  # approx: in x2, conv, gates, out
            att = attn + 2 * D
            m = mlp + D
            pat = self.block_pattern
            n_rec = sum(1 for i in range(L) if pat[i % len(pat)] == "rec")
            n_att = L - n_rec
            return total + n_rec * (rec + m) + n_att * (att + m)
        per_layer = attn + 2 * D
        if self.post_norm:
            per_layer += 2 * D
        if self.moe is not None:
            e = self.moe
            per_layer += D * e.num_experts  # router
            per_layer += e.num_experts * 3 * D * e.d_ff_expert
            if e.dense_residual_ff:
                per_layer += 3 * D * e.dense_residual_ff
        else:
            per_layer += mlp
        total += L * per_layer
        if self.family == "encdec":
            enc_per = attn + mlp + 4 * D + (D * Hq * hd + Hq * hd * D
                                            + 2 * D * Hkv * hd)  # + cross attn
            total += (self.encoder_layers or 0) * enc_per
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        inactive = self.n_layers * (e.num_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return self.param_count() - inactive


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def lm_shapes(long_ok: bool, reason: str = "pure full attention — 512k KV "
              "cache/quadratic prefill infeasible; see DESIGN.md") -> tuple:
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not long_ok:
            out.append(dataclasses.replace(s, skip=reason))
        else:
            out.append(s)
    return tuple(out)
