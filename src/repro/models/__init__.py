"""Model families: decoder-only transformer (dense/MoE/VLM), Whisper-style
enc-dec, RecurrentGemma hybrid, Mamba2 SSM — pure-JAX, scan+remat friendly."""
from .api import Model, build_model, input_specs
from .config import ArchConfig, MoESpec, ShapeSpec, lm_shapes

__all__ = ["ArchConfig", "Model", "MoESpec", "ShapeSpec", "build_model",
           "input_specs", "lm_shapes"]
