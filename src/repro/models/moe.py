"""Mixture-of-Experts FFN with static-shaped, flops-lean, SPMD-explicit
dispatch.

Design: top-k routing -> per-group expert-capacity slots -> one flat scatter
into a (G, E, C, D) buffer -> batched expert SwiGLU -> flat gather weighted
by gates. The dispatch cost is O(T*E) int work plus two O(T*k*D)
scatter/gathers — no (T, E, C) one-hot einsum (GShard-style dispatch would
add ~20% matmul flops and a multi-GB intermediate at arctic scale).

Groups G = number of data shards: slot-rank cumsums stay shard-local and the
buffer's G dim shards over the data axes. XLA's scatter partitioner cannot
propagate sharding through the dispatch (it replicates the buffer, which at
mixtral scale costs terabytes of all-reduce), so the buffer/output shardings
are asserted explicitly via trace-time settings (mesh-aware constraints).

Expert parallelism: buffer E dim and (E, ...) weights shard over `model`
when E divides it; otherwise experts replicate and the expert FFN shards
over d_ff (plain TP). Cross-shard token->expert movement then surfaces as
all-to-all in the collective roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import settings
from .config import MoESpec


def moe_capacity(spec: MoESpec, n_tokens: int) -> int:
    c = int(spec.top_k * n_tokens / spec.num_experts * spec.capacity_factor)
    return max(c, spec.top_k)


def _constrain(x, entries):
    """Mesh-aware sharding constraint; no-op outside pjit. `entries` uses
    'dp' (data axes minus manual), 'model', or None per dim."""
    mesh = settings.get().mesh
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    manual = settings.get().manual_axes
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")
               and a not in manual)
    ms = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    out = []
    for dim, e in zip(x.shape, entries):
        if e == "dp" and dp and dim % dp_size == 0:
            out.append(dp)
        elif e == "model" and dim % ms == 0 and ms > 1:
            out.append("model")
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*out)))


def moe_ffn(x: jnp.ndarray, router_w: jnp.ndarray, w_gate: jnp.ndarray,
            w_up: jnp.ndarray, w_down: jnp.ndarray, spec: MoESpec,
            *, capacity: int | None = None, groups: int = 1) -> jnp.ndarray:
    """x: (T, D) flattened tokens. router_w: (D, E). w_*: (E, D, F)/(E, F, D).

    Returns (T, D). Over-capacity tokens drop per group (the residual stream
    carries them unchanged, standard Switch behaviour).
    """
    T, D = x.shape
    E, K = spec.num_experts, spec.top_k
    G = max(1, groups)
    assert T % G == 0, (T, G)
    Tg = T // G
    C = capacity if capacity is not None else moe_capacity(spec, Tg)

    xg = _constrain(x.reshape(G, Tg, D), ("dp", None, None))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if spec.router_softcap:
        logits = spec.router_softcap * jnp.tanh(logits / spec.router_softcap)
    top_vals, top_ids = jax.lax.top_k(logits, K)          # (G, Tg, K)
    gates = jax.nn.softmax(top_vals, axis=-1)

    eid = top_ids.reshape(G, Tg * K)                      # (G, Tg*K)
    gate = gates.reshape(G, Tg * K)
    tok = jnp.broadcast_to(jnp.repeat(jnp.arange(Tg), K), (G, Tg * K))

    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)      # (G, Tg*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot        # rank within expert
    slot = jnp.sum(pos_in_e * onehot, axis=-1)            # (G, Tg*K)
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)                     # OOB -> dropped

    # batch-structured scatter (vmap over G): the SPMD partitioner recognizes
    # the leading batch dim and keeps it dp-sharded; a flat (G*E*C, D)
    # scatter would replicate the whole buffer on every device.
    ec_idx = eid * C + slot_c                             # (G, Tg*K)
    upd = jnp.where(
        keep[..., None],
        jnp.take_along_axis(xg, tok[..., None], axis=1), 0)  # (G, Tg*K, D)
    buf = jax.vmap(
        lambda i, u: jnp.zeros((E * C, D), x.dtype).at[i].add(u, mode="drop")
    )(ec_idx, upd)
    buf = _constrain(buf.reshape(G, E, C, D), ("dp", "model", None, None))

    # batched expert SwiGLU: (G, E, C, D) x (E, D, F) -> (G, E, C, F)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate))
    h = h * jnp.einsum("gecd,edf->gecf", buf, w_up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, w_down)
    mesh = settings.get().mesh
    ms = (mesh.shape["model"] if mesh is not None
          and "model" in mesh.axis_names else 1)
    if (settings.get().moe_c_shard and E % ms != 0 and C % ms == 0):
        out_buf = _constrain(out_buf, ("dp", None, "model", None))
    else:
        out_buf = _constrain(out_buf, ("dp", "model", None, None))

    pulled = jax.vmap(lambda b, i: b[i])(
        out_buf.reshape(G, E * C, D), ec_idx)             # (G, Tg*K, D)
    pulled = jnp.where(keep[..., None], pulled, 0) * gate[..., None].astype(x.dtype)
    out = jax.vmap(
        lambda u, t: jnp.zeros((Tg, D), x.dtype).at[t].add(u)
    )(pulled, tok)
    out = _constrain(out, ("dp", None, None))
    return out.reshape(T, D)


def moe_aux_loss(x: jnp.ndarray, router_w: jnp.ndarray, spec: MoESpec) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (fraction * prob per expert)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (T, E)
    top1 = jnp.argmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, spec.num_experts), axis=0)
    prob = jnp.mean(probs, axis=0)
    return spec.num_experts * jnp.sum(frac * prob)
