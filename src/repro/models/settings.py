"""Trace-time model settings (attention chunking, scan unrolling).

A contextvar consulted while tracing — NOT a runtime value. The dry-run's
cost probes set unroll_scans=True so XLA's cost analysis sees every loop
iteration (lax.scan bodies are otherwise counted once); real training keeps
rolled scans for fast compiles and small HLO.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelSettings:
    # attention memory-efficiency knobs
    attn_chunk_q: int = 512
    attn_chunk_k: int = 1024
    dense_below: int = 2048 * 2048   # use dense scores for Sq*Sk <= this
    ce_chunk: int = 512
    # cost-probe mode: fully unroll scans so HLO cost analysis is exact
    unroll_scans: bool = False
    # pjit mesh for internal sharding constraints (set by launch/steps.py at
    # trace time; None on single-device paths)
    mesh: object = None
    # mesh axes currently under shard_map manual control — excluded from
    # with_sharding_constraint specs (e.g. 'pod' in the compressed train step)
    manual_axes: tuple = ()
    # §Perf knobs (hypothesis -> change -> measure; see EXPERIMENTS.md):
    # cast f32 params to compute dtype ONCE before the layer scan, so FSDP
    # all-gathers move bf16 instead of f32
    cast_params_once: bool = False
    # cast softmax weights to bf16 for the PV matmul (scores stay f32)
    flash_p_bf16: bool = False
    # constrain attention/FFN block outputs to the sequence-sharded layout
    # BEFORE the residual add, so row-parallel partial sums lower to
    # reduce-scatter instead of all-reduce (Megatron-SP)
    sp_block_outputs: bool = False
    # pin q/k/v to (batch->dp, heads->model) inside flash attention; OFF lets
    # the partitioner pick (cheaper collectives on some dense stacks)
    constrain_attn_heads: bool = True
    # expand KV heads to Hq inside flash so the head axis shards at TP>Hkv;
    # OFF (default after §Perf hc8: -20% memory term, -5% collectives on
    # deepseek train_4k) keeps the grouped (Hkv, G) layout with batch-pinned
    # constraints; flash chunking + remat keeps score blocks bounded anyway
    gqa_expand: bool = False
    # when experts don't divide the model axis (mixtral E=8 < 16): shard the
    # expert-buffer CAPACITY dim over 'model' so the down-proj partial sums
    # lower to reduce-scatter instead of a full all-reduce
    moe_c_shard: bool = False


_settings: contextvars.ContextVar[ModelSettings] = contextvars.ContextVar(
    "repro_model_settings", default=ModelSettings())


def get() -> ModelSettings:
    return _settings.get()


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if _settings.get().unroll_scans else 1


@contextlib.contextmanager
def override(**kw):
    cur = _settings.get()
    token = _settings.set(dataclasses.replace(cur, **kw))
    try:
        yield _settings.get()
    finally:
        _settings.reset(token)
