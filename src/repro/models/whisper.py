"""Whisper-style encoder-decoder (audio backbone only — the conv/mel
frontend is a stub per the assignment: `input_specs()` feeds precomputed
frame embeddings (B, encoder_seq, D)).

LayerNorm + biased projections + GELU MLPs, MHA (n_kv_heads == n_heads),
sinusoidal positions (the assigned decoder shapes exceed Whisper's learned
448-position table, noted in DESIGN.md). Embedding tied with the LM head.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as nn
from . import settings
from .config import ArchConfig


def _attn_spec(D, Hq, hd, lead, prefix=""):
    return {
        f"{prefix}ln_w": (lead + (D,), ("layers", None), "norm"),
        f"{prefix}ln_b": (lead + (D,), ("layers", None), "zeros"),
        f"{prefix}wq": (lead + (D, Hq * hd), ("layers", "embed", "heads"), "fanin"),
        f"{prefix}bq": (lead + (Hq * hd,), ("layers", "heads"), "zeros"),
        f"{prefix}wk": (lead + (D, Hq * hd), ("layers", "embed", "heads"), "fanin"),
        f"{prefix}wv": (lead + (D, Hq * hd), ("layers", "embed", "heads"), "fanin"),
        f"{prefix}bv": (lead + (Hq * hd,), ("layers", "heads"), "zeros"),
        f"{prefix}wo": (lead + (Hq * hd, D), ("layers", "heads", "embed"), "fanin"),
        f"{prefix}bo": (lead + (D,), ("layers", None), "zeros"),
    }


def _mlp_spec(D, F, lead):
    return {
        "ln2_w": (lead + (D,), ("layers", None), "norm"),
        "ln2_b": (lead + (D,), ("layers", None), "zeros"),
        "w_in": (lead + (D, F), ("layers", "embed", "mlp"), "fanin"),
        "b_in": (lead + (F,), ("layers", "mlp"), "zeros"),
        "w_out": (lead + (F, D), ("layers", "mlp", "embed"), "fanin"),
        "b_out": (lead + (D,), ("layers", None), "zeros"),
    }


def _spec(cfg: ArchConfig) -> dict[str, tuple]:
    D, hd, Hq, F, V = cfg.d_model, cfg.hd, cfg.n_heads, cfg.d_ff, cfg.vocab
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    s: dict[str, Any] = {"embed": ((V, D), ("vocab_fsdp", "embed_tp"), "embed")}
    enc = {}
    enc.update(_attn_spec(D, Hq, hd, (Le,)))
    enc.update(_mlp_spec(D, F, (Le,)))
    s.update({f"enc/{k}": v for k, v in enc.items()})
    s["enc_ln_w"] = ((D,), (None,), "norm")
    s["enc_ln_b"] = ((D,), (None,), "zeros")
    dec = {}
    dec.update(_attn_spec(D, Hq, hd, (Ld,)))
    dec.update(_attn_spec(D, Hq, hd, (Ld,), prefix="x_"))
    dec.update(_mlp_spec(D, F, (Ld,)))
    s.update({f"dec/{k}": v for k, v in dec.items()})
    s["dec_ln_w"] = ((D,), (None,), "norm")
    s["dec_ln_b"] = ((D,), (None,), "zeros")
    return s


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    from .transformer import _assign
    params: dict[str, Any] = {}
    for i, (path, (shape, _, kind)) in enumerate(sorted(_spec(cfg).items())):
        k = jax.random.fold_in(key, i)
        if kind == "norm":
            leaf = jnp.ones(shape, dtype)
        elif kind == "zeros":
            leaf = jnp.zeros(shape, dtype)
        elif kind == "embed":
            leaf = jax.random.normal(k, shape, dtype) * 0.02
        else:
            leaf = jax.random.normal(k, shape, dtype) / (shape[-2] ** 0.5)
        _assign(params, path, leaf)
    return params


def param_axes(cfg: ArchConfig) -> dict:
    from .transformer import _assign
    axes: dict[str, Any] = {}
    for path, (_, ax, _) in sorted(_spec(cfg).items()):
        _assign(axes, path, ax)
    return axes


# ---------------------------------------------------------------------------

def sinusoidal(S: int, D: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


def _mha(cfg, lp, x_q, x_kv, pos_q, pos_k, *, causal, prefix=""):
    B, Sq, D = x_q.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x_q @ lp[f"{prefix}wq"] + lp[f"{prefix}bq"]).reshape(B, Sq, H, hd)
    k = (x_kv @ lp[f"{prefix}wk"]).reshape(B, -1, H, hd)
    v = (x_kv @ lp[f"{prefix}wv"] + lp[f"{prefix}bv"]).reshape(B, -1, H, hd)
    out = nn.attention(q, k, v, pos_q, pos_k, causal=causal)
    return out.reshape(B, Sq, H * hd) @ lp[f"{prefix}wo"] + lp[f"{prefix}bo"]


def encode(cfg: ArchConfig, params: dict, frames: jnp.ndarray, *,
           compute_dtype=jnp.bfloat16, remat: str = "nothing") -> jnp.ndarray:
    """frames: (B, Se, D) precomputed frame embeddings (conv frontend stub)."""
    B, Se, D = frames.shape
    h = frames.astype(compute_dtype) + sinusoidal(Se, D, compute_dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(Se), (B, Se))

    def layer(h, lp_raw):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.layer_norm(h, lp_raw["ln_w"], lp_raw["ln_b"])
        h = h + _mha(cfg, lp, hn, hn, pos, pos, causal=False)
        hn2 = nn.layer_norm(h, lp_raw["ln2_w"], lp_raw["ln2_b"])
        h = h + nn.gelu_mlp(hn2, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
        return h, None

    if remat != "none":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["enc"],
                        unroll=settings.scan_unroll())
    return nn.layer_norm(h, params["enc_ln_w"], params["enc_ln_b"])


def decode_hidden(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                  enc_out: jnp.ndarray, *, compute_dtype=jnp.bfloat16,
                  remat: str = "nothing") -> jnp.ndarray:
    B, S = tokens.shape
    D = cfg.d_model
    h = params["embed"][tokens].astype(compute_dtype)
    h = h + sinusoidal(S, D, compute_dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    Se = enc_out.shape[1]
    pos_e = jnp.broadcast_to(jnp.arange(Se), (B, Se))
    enc_out = enc_out.astype(compute_dtype)

    def layer(h, lp_raw):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.layer_norm(h, lp_raw["ln_w"], lp_raw["ln_b"])
        h = h + _mha(cfg, lp, hn, hn, pos, pos, causal=True)
        hx = nn.layer_norm(h, lp_raw["x_ln_w"], lp_raw["x_ln_b"])
        h = h + _mha(cfg, lp, hx, enc_out, pos, pos_e, causal=False, prefix="x_")
        hn2 = nn.layer_norm(h, lp_raw["ln2_w"], lp_raw["ln2_b"])
        h = h + nn.gelu_mlp(hn2, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
        return h, None

    if remat != "none":
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["dec"],
                        unroll=settings.scan_unroll())
    return nn.layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.bfloat16, remat: str = "nothing",
            **_unused) -> jnp.ndarray:
    enc_out = encode(cfg, params, batch["frames"],
                     compute_dtype=compute_dtype, remat=remat)
    h = decode_hidden(cfg, params, batch["tokens"], enc_out,
                      compute_dtype=compute_dtype, remat=remat)
    return nn.chunked_ce_loss(h, params["embed"].T, batch["labels"])


# ---------------------------------------------------------------------------
# Decode: self-attn KV cache + precomputed cross-attn K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    Ld, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    Se = cfg.encoder_seq
    return {
        "k": jnp.zeros((Ld, batch, H, max_seq, hd), dtype),
        "v": jnp.zeros((Ld, batch, H, max_seq, hd), dtype),
        "xk": jnp.zeros((Ld, batch, H, Se, hd), dtype),
        "xv": jnp.zeros((Ld, batch, H, Se, hd), dtype),
    }


def build_cross_cache(cfg: ArchConfig, params: dict, enc_out: jnp.ndarray,
                      cache: dict, *, compute_dtype=jnp.bfloat16) -> dict:
    B, Se, D = enc_out.shape
    H, hd = cfg.n_heads, cfg.hd
    e = enc_out.astype(compute_dtype)

    def per_layer(lp):
        xk = (e @ lp["x_wk"].astype(compute_dtype)).reshape(B, Se, H, hd)
        xv = (e @ lp["x_wv"].astype(compute_dtype)
              + lp["x_bv"].astype(compute_dtype)).reshape(B, Se, H, hd)
        return jnp.swapaxes(xk, 1, 2), jnp.swapaxes(xv, 1, 2)

    xk, xv = jax.lax.map(per_layer, params["dec"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray, pos: jnp.ndarray, *,
                compute_dtype=jnp.bfloat16, **_unused):
    B = token.shape[0]
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    max_seq = cache["k"].shape[3]
    Se = cache["xk"].shape[3]
    h = params["embed"][token].astype(compute_dtype)[:, None, :]
    # per-sequence position embedding
    pe = sinusoidal(max_seq, D, compute_dtype)[pos]           # (B, D)
    h = h + pe[:, None, :]
    pos_q = pos[:, None]
    pos_k = jnp.broadcast_to(jnp.arange(max_seq), (B, max_seq))
    pos_e = jnp.broadcast_to(jnp.arange(Se), (B, Se))

    def layer(h, xs):
        lp_raw, kc, vc, xk, xv = xs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp_raw)
        hn = nn.layer_norm(h, lp_raw["ln_w"], lp_raw["ln_b"])
        q = (hn @ lp["wq"] + lp["bq"]).reshape(B, 1, H, hd)
        k = (hn @ lp["wk"]).reshape(B, 1, H, hd)
        v = (hn @ lp["wv"] + lp["bv"]).reshape(B, 1, H, hd)
        kc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0)))(
            kc, jnp.swapaxes(k, 1, 2).astype(kc.dtype), pos)
        vc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (0, p, 0)))(
            vc, jnp.swapaxes(v, 1, 2).astype(vc.dtype), pos)
        attn = nn.attention(q, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2),
                            pos_q, pos_k, causal=True, dense_below=1 << 62)
        h = h + attn.reshape(B, 1, H * hd) @ lp["wo"] + lp["bo"]
        hx = nn.layer_norm(h, lp_raw["x_ln_w"], lp_raw["x_ln_b"])
        qx = (hx @ lp["x_wq"] + lp["x_bq"]).reshape(B, 1, H, hd)
        attn_x = nn.attention(qx, jnp.swapaxes(xk, 1, 2).astype(compute_dtype),
                              jnp.swapaxes(xv, 1, 2).astype(compute_dtype),
                              pos_q, pos_e, causal=False, dense_below=1 << 62)
        h = h + attn_x.reshape(B, 1, H * hd) @ lp["x_wo"] + lp["x_bo"]
        hn2 = nn.layer_norm(h, lp_raw["ln2_w"], lp_raw["ln2_b"])
        h = h + nn.gelu_mlp(hn2, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
        return h, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        layer, h, (params["dec"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]), unroll=settings.scan_unroll())
    h = nn.layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    logits = h[:, 0].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, dict(cache, k=k_new, v=v_new)
