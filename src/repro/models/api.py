"""Family dispatch: one uniform surface (init / loss / decode / cache /
input specs) over the four model families. This is what launch/ and the
examples consume; `--arch` selects an ArchConfig, `build_model` does the rest.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import mamba2, rglru, transformer, whisper
from .config import ArchConfig, ShapeSpec

_FAMILIES = {
    "decoder": transformer,
    "encdec": whisper,
    "hybrid": rglru,
    "ssm": mamba2,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    mod: Any

    # -- parameters ------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        return self.mod.init_params(self.cfg, key, dtype)

    def param_axes(self):
        return self.mod.param_axes(self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- steps -------------------------------------------------------------
    def loss_fn(self, params, batch, **kw):
        return self.mod.loss_fn(self.cfg, params, batch, **kw)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return self.mod.init_cache(self.cfg, batch, max_seq, dtype)

    def decode_step(self, params, cache, token, pos, **kw):
        return self.mod.decode_step(self.cfg, params, cache, token, pos, **kw)

    def prefill(self, params, tokens, max_seq, **kw):
        if self.cfg.family == "decoder":
            return transformer.prefill(self.cfg, params, tokens, max_seq, **kw)
        raise NotImplementedError(f"prefill helper for {self.cfg.family}")


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg, _FAMILIES[cfg.family])


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs per (arch x shape) — consumed by the dry-run.
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Stand-ins for every model input of this cell (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sd((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = sd((B, S), i32)
        if cfg.mrope_sections is not None:
            batch["positions3"] = sd((3, B, S), i32)
            batch["patches"] = sd((B, cfg.num_patches, cfg.d_model), f32)
            batch["patch_positions"] = sd((B, cfg.num_patches), i32)
        if cfg.family == "encdec":
            batch["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), f32)
        return batch
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    batch = {"token": sd((B,), i32), "pos": sd((B,), i32), "cache": cache}
    if cfg.mrope_sections is not None:
        batch["positions3"] = sd((3, B, 1), i32)
    return batch
