"""Structure-dispatched projection: plan lookup -> record -> execute.

`project(op, x)` is the single entry point replacing the old
`project` / `project_tt` / `project_cp` method zoo: it inspects the input's
structure (dense tensor, flat vector, `TTTensor` / `CPTensor`, or the
batched `BatchedTTTensor` / `BatchedCPTensor` containers), raising a typed
`FormatMismatchError` on incompatible shapes — and then EVERY execution
resolves through a cached `repro.rp.plan.ExecutionPlan`: the dispatch
matrix, backend policy, kernel/tile/pipeline selection and the unified
cost ledger all live in `plan.py` (see its module docstring — or run
`rp.explain(op, x)`, which returns the resolved plan with its rejected
alternatives). This module keeps only input normalization and the
context-local instrumentation.

Instrumentation is CONTEXT-LOCAL: a `DispatchStats` object held in a
`contextvars.ContextVar` carries the kernel-dispatch counter, the
per-(family, structure, route, order) launch `breakdown`, and the
force-pallas depth. `kernel_call_count()` reads the current context's
counter (counted at trace time — cached jit executions don't re-dispatch);
`dispatch_stats()` installs a fresh, isolated object for a dynamic scope so
parallel tests and nested contexts can't corrupt each other's counts, and
`force_pallas()` is depth-counted so nesting composes.

Every dispatch additionally opens a `repro.obs` span (`rp.project` /
`rp.reconstruct`, tagged family/structure/order/backend/pipeline with the
RESOLVED route plus the `plan` id, so traces join to exact routes) — a
shared no-op when telemetry is disabled, so the hot path pays one
module-global read (gated by the obs/overhead bench row).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax.numpy as jnp

from repro import obs
from repro.core.cp_rp import CPRP
from repro.core.formats import (STRUCT_TYPES, BatchedCPTensor,
                                BatchedTTTensor, _prod)
from repro.core.tt_rp import TTRP

from . import plan as _plan
from .protocol import FormatMismatchError, RPOperator


@dataclasses.dataclass
class DispatchStats:
    """Context-local dispatch instrumentation.

    kernel_calls : number of `project`/`reconstruct` dispatches that routed
                   to a Pallas kernel in this context.
    force_depth  : nesting depth of active `force_pallas()` scopes; > 0
                   lets 'auto' pick the interpret-mode kernel off-TPU.
    breakdown    : per-(family, structure, route, order) dispatch counts,
                   covering EVERY dispatch — both routes, so the xla
                   fallbacks are visible too. `route` is the RESOLVED
                   backend ('pallas' | 'xla'), `structure` the input kind
                   ('dense' | 'tt' | 'cp' | 'sketch' | 'extern'). The
                   pre-existing fields stay bit-compatible: kernel_calls
                   always equals the sum of the route=='pallas' entries.
    """

    kernel_calls: int = 0
    force_depth: int = 0
    breakdown: dict = dataclasses.field(default_factory=dict)

    @property
    def force_pallas(self) -> bool:
        return self.force_depth > 0

    def record(self, family: str, structure: str, route: str,
               order: int) -> None:
        """Count one dispatch; pallas routes also bump `kernel_calls`."""
        key = (family, structure, route, order)
        self.breakdown[key] = self.breakdown.get(key, 0) + 1
        if route == "pallas":
            self.kernel_calls += 1

    def breakdown_table(self) -> list[dict]:
        """The breakdown as sorted JSON-able rows (telemetry sinks)."""
        return [{"family": f, "structure": s, "route": r, "order": n,
                 "calls": c}
                for (f, s, r, n), c in sorted(self.breakdown.items())]


# The root stats is the default for code that never opens a dispatch_stats()
# scope; scopes (and anything run under contextvars.copy_context / asyncio
# tasks that set one) get their own isolated object.
_ROOT_STATS = DispatchStats()
_STATS: contextvars.ContextVar[DispatchStats] = contextvars.ContextVar(
    "repro_rp_dispatch_stats", default=_ROOT_STATS)


def current_stats() -> DispatchStats:
    """The `DispatchStats` object active in the current context."""
    return _STATS.get()


def kernel_call_count() -> int:
    """How many dispatches routed to a Pallas kernel in this context.

    Counts at dispatch (trace) time: under `jax.jit` a cached executable
    re-runs without re-dispatching, so this proves *routing*, not
    per-execution kernel launches.
    """
    return _STATS.get().kernel_calls


@contextlib.contextmanager
def dispatch_stats():
    """Install a fresh, isolated `DispatchStats` for the dynamic scope.

    Yields the object; counts and force-pallas state inside the scope never
    leak to (or read from) the enclosing context — use one per test when
    tests may run in parallel.
    """
    stats = DispatchStats()
    token = _STATS.set(stats)
    try:
        yield stats
    finally:
        _STATS.reset(token)


@contextlib.contextmanager
def force_pallas():
    """Let `backend='auto'` select the interpret-mode kernel off-TPU.

    Used by tests to exercise/prove the Pallas route on CPU; on real TPU
    hardware 'auto' selects the kernel by itself. Depth-counted on the
    context-local stats, so nested scopes compose and cannot clobber each
    other.
    """
    stats = _STATS.get()
    stats.force_depth += 1
    try:
        yield
    finally:
        stats.force_depth -= 1


def dispatch_breakdown() -> dict:
    """A copy of the current context's per-(family, structure, route,
    order) dispatch counts (see `DispatchStats.breakdown`)."""
    return dict(_STATS.get().breakdown)


def count_kernel_dispatch(family: str = "extern", structure: str = "extern",
                          order: int = 0) -> None:
    """Record one Pallas kernel dispatch on the context-local stats.

    The public hook for kernel wrappers that live OUTSIDE the
    project/reconstruct dispatch matrix (e.g. the fused unsketch+EF+AdamW
    launch in `optim.adamw.update_sketched`) so `kernel_call_count()`
    stays the single source of truth for routing proofs. The optional tags
    place the launch in the per-(family, structure, route, order)
    `breakdown` (route is 'pallas' by definition here — this hook exists
    for kernel launches); untagged calls land under ('extern', 'extern',
    'pallas', 0), keeping the kernel_calls == sum-of-pallas-rows invariant.
    """
    _STATS.get().record(family, structure, "pallas", int(order))


def _coerce_dense(op: RPOperator, x: jnp.ndarray) -> jnp.ndarray:
    """Reshape/pad a dense array to `(*batch, *op.in_dims)`.

    Accepts: exact `(*batch, *in_dims)` tensors; `(*batch, D)` flat vectors
    with D == prod(in_dims); any unbatched tensorization with the right
    element count; and `(*batch, D)` SHORT flat vectors with
    D < prod(in_dims), whose last axis is zero-padded up to prod(in_dims) —
    harmless under a linear map, and the batched case (e.g. a batch of
    ragged tail buckets) pads exactly like the 1-D case.

    Rejected with a typed error: trailing axes exceeding prod(in_dims)
    without matching `in_dims`, and NEAR-MISS tensors that match `in_dims`
    on every mode but the last — those are overwhelmingly truncated buckets
    (off-by-one slice bugs), not flat-vector batches, and padding them
    would silently project garbage.
    """
    dims = tuple(op.in_dims)
    n = len(dims)
    size = _prod(dims)
    x = jnp.asarray(x)
    if x.ndim >= n and tuple(x.shape[-n:]) == dims:
        return x
    if x.ndim >= 1 and x.shape[-1] == size:
        return x.reshape(x.shape[:-1] + dims)
    if x.ndim >= n and x.size == size:
        # alternate tensorization of a single input (e.g. a gradient bucket
        # shaped for a tensorized family, fed to a flat baseline); checked
        # BEFORE the short-vector branch so the total-size match keeps
        # meaning "one input", not "a batch of padded ones"
        return x.reshape(dims)
    if (x.ndim >= n and n > 1 and tuple(x.shape[-n:-1]) == dims[:-1]
            and x.shape[-1] != dims[-1]):
        # near-miss dense tensor: every mode but the last matches in_dims —
        # far more likely a truncated/over-long bucket (an off-by-one slice
        # bug) than a batch of flat vectors that happens to be stacked in
        # the operator's own mode sizes; refuse rather than pad garbage
        raise FormatMismatchError(
            f"dense input of shape {tuple(x.shape)} matches in_dims={dims} "
            f"on every mode but the last ({x.shape[-1]} != {dims[-1]}) — "
            "refusing to reinterpret a near-miss tensor as flat vectors")
    if x.ndim >= 1 and x.shape[-1] < size:
        # short flat vector(s): zero-pad the trailing axis, batched or not
        widths = [(0, 0)] * (x.ndim - 1) + [(0, size - x.shape[-1])]
        return jnp.pad(x, widths).reshape(x.shape[:-1] + dims)
    raise FormatMismatchError(
        f"dense input of shape {tuple(x.shape)} is incompatible with "
        f"operator in_dims={dims} (flat size {size})")


def _check_struct_dims(op: RPOperator, x) -> None:
    if tuple(x.dims) != tuple(op.in_dims):
        raise FormatMismatchError(
            f"{type(x).__name__} input dims {tuple(x.dims)} != operator "
            f"in_dims {tuple(op.in_dims)}")


def _run_planned(span_name: str, eplan, op, x) -> jnp.ndarray:
    """Record one dispatch on the context stats and execute the plan."""
    _STATS.get().record(eplan.family, eplan.structure, eplan.route,
                        eplan.order)
    with obs.span(span_name, family=eplan.family, structure=eplan.structure,
                  order=eplan.order, backend=eplan.route,
                  pipeline=eplan.pipeline, plan=eplan.plan_id):
        return _plan.execute_plan(eplan, op, x)


def _project_dense(op: RPOperator, x: jnp.ndarray, backend: str,
                   pipeline: str = "serial") -> jnp.ndarray:
    xt = _coerce_dense(op, x)
    eplan = _plan.plan_execution(op, _plan.dense_signature(op, xt),
                                 backend=backend, pipeline=pipeline)
    return _run_planned("rp.project", eplan, op, xt)


def _project_struct(op: RPOperator, x, backend: str,
                    pipeline: str = "serial") -> jnp.ndarray:
    """Structured (TT/CP-format) input(s), single or batched.

    TT/CP operators project in the compressed domain — the carry-sweep
    kernel subsystem (`repro.kernels.struct`) under the kernel policy, its
    batched einsum oracles otherwise; either way a batched container is ONE
    dispatch, never a vmap. Flat-vector families (gaussian/sparse)
    densify first — only viable at small prod(dims), which is exactly the
    regime the paper could run those baselines in.
    """
    if not isinstance(op, (TTRP, CPRP)):
        full = x.full()
        if isinstance(x, (BatchedTTTensor, BatchedCPTensor)):
            return _project_dense(op, full.reshape(full.shape[0], -1),
                                  backend, pipeline)
        return _project_dense(op, full.reshape(-1), backend, pipeline)
    _check_struct_dims(op, x)
    eplan = _plan.plan_execution(op, _plan.struct_signature(op, x),
                                 backend=backend, pipeline=pipeline)
    return _run_planned("rp.project", eplan, op, x)


def project(op: RPOperator, x, *, backend: str = "auto",
            pipeline: str = "serial") -> jnp.ndarray:
    """Project `x` with `op`, dispatching on the input's structure.

    x may be:
      * a dense array `(*batch, *op.in_dims)` — any operator order,
      * a flat vector or a `(*batch, D)` stack of them (auto-tensorized;
        short vectors are zero-padded, batched or not),
      * a `TTTensor` / `CPTensor` (compressed-domain fast path for
        tensorized families — never densified),
      * a `BatchedTTTensor` / `BatchedCPTensor` — a whole batch of
        structured inputs in ONE dispatch (the carry-sweep kernels put the
        batch on a native grid axis; there is no vmap on any route).

    `pipeline='double'` selects the double-buffered DMA schedule on the
    kernel routes (dense mode sweep and structured carry sweep) — same
    results to fp32 tolerance, input/core streams overlapped with the MXU
    contractions. Ignored on the einsum routes (there is nothing to
    pipeline by hand); validated either way so a typo cannot silently run
    serial.

    Returns the `(*batch, k)` sketch ((k,) for single structured inputs,
    (B, k) for batched containers).
    """
    _plan.validate_pipeline(pipeline)
    if isinstance(x, STRUCT_TYPES):
        return _project_struct(op, x, backend, pipeline)
    return _project_dense(op, x, backend, pipeline)


def reconstruct(op: RPOperator, y: jnp.ndarray, *, chunk: int | None = None,
                backend: str = "auto") -> jnp.ndarray:
    """Unbiased adjoint reconstruction, `(*batch, k) -> (*batch, *in_dims)`.

    A `(k,)` sketch returns an `in_dims`-shaped estimate (the original
    contract); batched sketches route to the batched mode-sweep adjoint
    kernels (`tt_sweep_reconstruct` / `cp_sweep_reconstruct`, any order
    N >= 2) under the same backend policy as `project` — ONE launch for the
    whole batch, no vmap — and otherwise fall back to a vmap of the
    operator's einsum adjoint.

    `chunk` is part of the resolved plan, not a warning: the einsum route
    honors it as the bound on the k-sized intermediate
    (`plan.chunk_policy == 'honored'`); the kernel route records
    `'folded'` — the planner's VMEM budget already tiles k, so the
    requested bound is honored by the kernel's own k-tiling and no dense
    (D, k) intermediate ever exists. Pass `backend='xla'` to make a
    specific chunk value authoritative; `rp.explain(op, y,
    kind='reconstruct', chunk=...)` shows the recorded policy.
    """
    y = jnp.asarray(y)
    if y.ndim < 1 or y.shape[-1] != op.k:
        raise FormatMismatchError(
            f"sketch shape {tuple(y.shape)} does not end in k = {op.k}")
    eplan = _plan.plan_execution(op, _plan.sketch_signature(op, y, chunk),
                                 kind="reconstruct", backend=backend)
    return _run_planned("rp.reconstruct", eplan, op, y)
