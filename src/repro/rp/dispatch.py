"""Structure-dispatched projection with backend routing.

`project(op, x)` is the single entry point replacing the old
`project` / `project_tt` / `project_cp` method zoo: it inspects the input's
structure (dense tensor, flat vector, `TTTensor` / `CPTensor`, or the
batched `BatchedTTTensor` / `BatchedCPTensor` containers) and the
operator's family, and routes to the cheapest contraction path, raising a
typed `FormatMismatchError` on incompatible shapes.

Dispatch matrix (input format x operator family -> route):

  dense/flat x tt/cp (2<=N<=MAX_ORDER)  mode-sweep kernel | einsum
  (*batch, k) sketch x tt/cp            mode-sweep adjoint kernel | einsum
  (Batched)TT/CP x tt/cp (2<=N)         carry-sweep kernel
                                        (`kernels.struct.struct_project`,
                                        all four pairings, ONE launch per
                                        batched call) | batched einsum refs
  (Batched)TT/CP x gaussian/sparse      densified (`x.full()`) flat einsum
  order outside [2, MAX_ORDER] x any    einsum, even under 'pallas'

Backend policy (`backend='auto' | 'pallas' | 'xla'`)
---------------------------------------------------
Dense-input projections of the TT/CP families at any kernel-supported
order (2 <= N <= `repro.kernels.MAX_ORDER`) have batched mode-sweep Pallas
kernels (`repro.kernels.tt_project` / `cp_project` — `(*batch, *dims)`
inputs run in ONE launch with a native batch grid axis, never vmap); the
adjoints route the same way through `tt_reconstruct` / `cp_reconstruct`
for `(*batch, k)` sketches; structured (TT/CP-format) inputs — single or
batched, any pairing with a TT/CP operator — route to the carry-sweep
kernels in `repro.kernels.struct` (compressed-domain projection,
O(k N d R R~ (R + R~)), never densifying). Routing:

* 'xla'    — always the einsum path.
* 'pallas' — always the kernel (operators outside the supported order
             range — order-1 classical Gaussian, order > MAX_ORDER — take
             the einsum path); interpret mode off-TPU.
* 'auto'   — the kernel iff the shapes are MXU-aligned (k a multiple of the
             128 lane width, every mode a multiple of the 8 sublanes, order
             >= 2) AND we are on real TPU hardware. Off-TPU the kernels
             only run in interpret mode — a validation device, not a fast
             path — so 'auto' stays on XLA there unless `force_pallas()` is
             active (which tests use to prove the routing).

Instrumentation is CONTEXT-LOCAL: a `DispatchStats` object held in a
`contextvars.ContextVar` carries the kernel-dispatch counter, the
per-(family, structure, route, order) launch `breakdown`, and the
force-pallas depth. `kernel_call_count()` reads the current context's
counter (counted at trace time — cached jit executions don't re-dispatch);
`dispatch_stats()` installs a fresh, isolated object for a dynamic scope so
parallel tests and nested contexts can't corrupt each other's counts, and
`force_pallas()` is depth-counted so nesting composes.

Every dispatch additionally opens a `repro.obs` span (`rp.project` /
`rp.reconstruct`, tagged family/structure/order/backend/pipeline with the
RESOLVED route) — a shared no-op when telemetry is disabled, so the hot
path pays one module-global read (gated by the obs/overhead bench row).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.baselines import GaussianRP, VerySparseRP
from repro.core.cp_rp import CPRP
from repro.core.formats import (STRUCT_TYPES, BatchedCPTensor,
                                BatchedTTTensor, TTTensor, _prod)
from repro.core.tt_rp import TTRP

from .protocol import FormatMismatchError, RPOperator

_BACKENDS = ("auto", "pallas", "xla")


@dataclasses.dataclass
class DispatchStats:
    """Context-local dispatch instrumentation.

    kernel_calls : number of `project`/`reconstruct` dispatches that routed
                   to a Pallas kernel in this context.
    force_depth  : nesting depth of active `force_pallas()` scopes; > 0
                   lets 'auto' pick the interpret-mode kernel off-TPU.
    breakdown    : per-(family, structure, route, order) dispatch counts,
                   covering EVERY dispatch — both routes, so the xla
                   fallbacks are visible too. `route` is the RESOLVED
                   backend ('pallas' | 'xla'), `structure` the input kind
                   ('dense' | 'tt' | 'cp' | 'sketch' | 'extern'). The
                   pre-existing fields stay bit-compatible: kernel_calls
                   always equals the sum of the route=='pallas' entries.
    """

    kernel_calls: int = 0
    force_depth: int = 0
    breakdown: dict = dataclasses.field(default_factory=dict)

    @property
    def force_pallas(self) -> bool:
        return self.force_depth > 0

    def record(self, family: str, structure: str, route: str,
               order: int) -> None:
        """Count one dispatch; pallas routes also bump `kernel_calls`."""
        key = (family, structure, route, order)
        self.breakdown[key] = self.breakdown.get(key, 0) + 1
        if route == "pallas":
            self.kernel_calls += 1

    def breakdown_table(self) -> list[dict]:
        """The breakdown as sorted JSON-able rows (telemetry sinks)."""
        return [{"family": f, "structure": s, "route": r, "order": n,
                 "calls": c}
                for (f, s, r, n), c in sorted(self.breakdown.items())]


# The root stats is the default for code that never opens a dispatch_stats()
# scope; scopes (and anything run under contextvars.copy_context / asyncio
# tasks that set one) get their own isolated object.
_ROOT_STATS = DispatchStats()
_STATS: contextvars.ContextVar[DispatchStats] = contextvars.ContextVar(
    "repro_rp_dispatch_stats", default=_ROOT_STATS)


def current_stats() -> DispatchStats:
    """The `DispatchStats` object active in the current context."""
    return _STATS.get()


def kernel_call_count() -> int:
    """How many dispatches routed to a Pallas kernel in this context.

    Counts at dispatch (trace) time: under `jax.jit` a cached executable
    re-runs without re-dispatching, so this proves *routing*, not
    per-execution kernel launches.
    """
    return _STATS.get().kernel_calls


@contextlib.contextmanager
def dispatch_stats():
    """Install a fresh, isolated `DispatchStats` for the dynamic scope.

    Yields the object; counts and force-pallas state inside the scope never
    leak to (or read from) the enclosing context — use one per test when
    tests may run in parallel.
    """
    stats = DispatchStats()
    token = _STATS.set(stats)
    try:
        yield stats
    finally:
        _STATS.reset(token)


@contextlib.contextmanager
def force_pallas():
    """Let `backend='auto'` select the interpret-mode kernel off-TPU.

    Used by tests to exercise/prove the Pallas route on CPU; on real TPU
    hardware 'auto' selects the kernel by itself. Depth-counted on the
    context-local stats, so nested scopes compose and cannot clobber each
    other.
    """
    stats = _STATS.get()
    stats.force_depth += 1
    try:
        yield
    finally:
        stats.force_depth -= 1


def dispatch_breakdown() -> dict:
    """A copy of the current context's per-(family, structure, route,
    order) dispatch counts (see `DispatchStats.breakdown`)."""
    return dict(_STATS.get().breakdown)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# operator class -> family tag for the breakdown/span instrumentation;
# third-party registered families fall back to their lowercased class name
_FAMILY_BY_TYPE = {TTRP: "tt", CPRP: "cp", GaussianRP: "gaussian",
                   VerySparseRP: "sparse"}


def _family_tag(op) -> str:
    for cls, name in _FAMILY_BY_TYPE.items():
        if isinstance(op, cls):
            return name
    return type(op).__name__.lower()


def _order_tag(op) -> int:
    try:
        return int(op.order)
    except (AttributeError, TypeError):
        return len(tuple(op.in_dims))


def count_kernel_dispatch(family: str = "extern", structure: str = "extern",
                          order: int = 0) -> None:
    """Record one Pallas kernel dispatch on the context-local stats.

    The public hook for kernel wrappers that live OUTSIDE the
    project/reconstruct dispatch matrix (e.g. the fused unsketch+EF+AdamW
    launch in `optim.adamw.update_sketched`) so `kernel_call_count()`
    stays the single source of truth for routing proofs. The optional tags
    place the launch in the per-(family, structure, route, order)
    `breakdown` (route is 'pallas' by definition here — this hook exists
    for kernel launches); untagged calls land under ('extern', 'extern',
    'pallas', 0), keeping the kernel_calls == sum-of-pallas-rows invariant.
    """
    _STATS.get().record(family, structure, "pallas", int(order))


def _mxu_aligned(op) -> bool:
    dims = op.in_dims
    return (op.k % 128 == 0 and len(dims) >= 2
            and all(d % 8 == 0 for d in dims))


def _use_kernel(backend: str, *, supported: bool, aligned: bool) -> bool:
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {_BACKENDS}")
    if not supported:
        # even for backend='pallas': unsupported operators take einsum
        return False
    if backend == "pallas":
        return True
    if backend == "xla":
        return False
    return aligned and (_on_tpu() or _STATS.get().force_pallas)


def _coerce_dense(op: RPOperator, x: jnp.ndarray) -> jnp.ndarray:
    """Reshape/pad a dense array to `(*batch, *op.in_dims)`.

    Accepts: exact `(*batch, *in_dims)` tensors; `(*batch, D)` flat vectors
    with D == prod(in_dims); any unbatched tensorization with the right
    element count; and `(*batch, D)` SHORT flat vectors with
    D < prod(in_dims), whose last axis is zero-padded up to prod(in_dims) —
    harmless under a linear map, and the batched case (e.g. a batch of
    ragged tail buckets) pads exactly like the 1-D case.

    Rejected with a typed error: trailing axes exceeding prod(in_dims)
    without matching `in_dims`, and NEAR-MISS tensors that match `in_dims`
    on every mode but the last — those are overwhelmingly truncated buckets
    (off-by-one slice bugs), not flat-vector batches, and padding them
    would silently project garbage.
    """
    dims = tuple(op.in_dims)
    n = len(dims)
    size = _prod(dims)
    x = jnp.asarray(x)
    if x.ndim >= n and tuple(x.shape[-n:]) == dims:
        return x
    if x.ndim >= 1 and x.shape[-1] == size:
        return x.reshape(x.shape[:-1] + dims)
    if x.ndim >= n and x.size == size:
        # alternate tensorization of a single input (e.g. a gradient bucket
        # shaped for a tensorized family, fed to a flat baseline); checked
        # BEFORE the short-vector branch so the total-size match keeps
        # meaning "one input", not "a batch of padded ones"
        return x.reshape(dims)
    if (x.ndim >= n and n > 1 and tuple(x.shape[-n:-1]) == dims[:-1]
            and x.shape[-1] != dims[-1]):
        # near-miss dense tensor: every mode but the last matches in_dims —
        # far more likely a truncated/over-long bucket (an off-by-one slice
        # bug) than a batch of flat vectors that happens to be stacked in
        # the operator's own mode sizes; refuse rather than pad garbage
        raise FormatMismatchError(
            f"dense input of shape {tuple(x.shape)} matches in_dims={dims} "
            f"on every mode but the last ({x.shape[-1]} != {dims[-1]}) — "
            "refusing to reinterpret a near-miss tensor as flat vectors")
    if x.ndim >= 1 and x.shape[-1] < size:
        # short flat vector(s): zero-pad the trailing axis, batched or not
        widths = [(0, 0)] * (x.ndim - 1) + [(0, size - x.shape[-1])]
        return jnp.pad(x, widths).reshape(x.shape[:-1] + dims)
    raise FormatMismatchError(
        f"dense input of shape {tuple(x.shape)} is incompatible with "
        f"operator in_dims={dims} (flat size {size})")


def _check_struct_dims(op: RPOperator, x) -> None:
    if tuple(x.dims) != tuple(op.in_dims):
        raise FormatMismatchError(
            f"{type(x).__name__} input dims {tuple(x.dims)} != operator "
            f"in_dims {tuple(op.in_dims)}")


def _kernel_order_ok(n: int) -> bool:
    # local import: repro.kernels is deliberately not a module-level dep
    from repro.kernels import kernel_order_supported
    return kernel_order_supported(n)


def _check_pipeline(pipeline: str) -> None:
    # local import: repro.kernels is deliberately not a module-level dep
    from repro.kernels import PIPELINES
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; expected "
                         f"{PIPELINES}")


def _project_dense(op: RPOperator, x: jnp.ndarray, backend: str,
                   pipeline: str = "serial") -> jnp.ndarray:
    xt = _coerce_dense(op, x)
    is_tn = isinstance(op, (TTRP, CPRP))
    n = op.order if is_tn else 0
    supported = is_tn and _kernel_order_ok(n) and xt.ndim >= n
    use = _use_kernel(backend, supported=supported, aligned=_mxu_aligned(op))
    route = "pallas" if use else "xla"
    order = _order_tag(op)
    _STATS.get().record(_family_tag(op), "dense", route, order)
    with obs.span("rp.project", family=_family_tag(op), structure="dense",
                  order=order, backend=route, pipeline=pipeline):
        if use:
            from repro.kernels import ops as kops  # local: avoids cycle
            interpret = not _on_tpu()
            kern = (kops.tt_project if isinstance(op, TTRP)
                    else kops.cp_project)
            if xt.ndim <= n + 1:  # single input/1-D batch: native batch axis
                return kern(op, xt, interpret=interpret, pipeline=pipeline)
            batch = xt.shape[:-n]
            flat = xt.reshape((-1,) + xt.shape[-n:])
            return kern(op, flat, interpret=interpret,
                        pipeline=pipeline).reshape(batch + (op.k,))
        return op.project(xt)


def _project_struct(op: RPOperator, x, backend: str,
                    pipeline: str = "serial") -> jnp.ndarray:
    """Structured (TT/CP-format) input(s), single or batched.

    TT/CP operators project in the compressed domain — the carry-sweep
    kernel subsystem (`repro.kernels.struct`) under the kernel policy, its
    batched einsum oracles otherwise; either way a batched container is ONE
    dispatch, never a vmap. Flat-vector families (gaussian/sparse)
    densify first — only viable at small prod(dims), which is exactly the
    regime the paper could run those baselines in.
    """
    if not isinstance(op, (TTRP, CPRP)):
        full = x.full()
        if isinstance(x, (BatchedTTTensor, BatchedCPTensor)):
            return _project_dense(op, full.reshape(full.shape[0], -1),
                                  backend, pipeline)
        return _project_dense(op, full.reshape(-1), backend, pipeline)
    _check_struct_dims(op, x)
    # local import: repro.kernels is deliberately not a module-level dep
    from repro.kernels import struct as kstruct
    supported = _kernel_order_ok(op.order)
    use = _use_kernel(backend, supported=supported, aligned=_mxu_aligned(op))
    route = "pallas" if use else "xla"
    structure = ("tt" if isinstance(x, (TTTensor, BatchedTTTensor))
                 else "cp")
    _STATS.get().record(_family_tag(op), structure, route, op.order)
    with obs.span("rp.project", family=_family_tag(op), structure=structure,
                  order=op.order, backend=route, pipeline=pipeline):
        if use:
            return kstruct.struct_project(op, x, interpret=not _on_tpu(),
                                          pipeline=pipeline)
        return kstruct.struct_project(op, x, use_kernel=False)


def project(op: RPOperator, x, *, backend: str = "auto",
            pipeline: str = "serial") -> jnp.ndarray:
    """Project `x` with `op`, dispatching on the input's structure.

    x may be:
      * a dense array `(*batch, *op.in_dims)` — any operator order,
      * a flat vector or a `(*batch, D)` stack of them (auto-tensorized;
        short vectors are zero-padded, batched or not),
      * a `TTTensor` / `CPTensor` (compressed-domain fast path for
        tensorized families — never densified),
      * a `BatchedTTTensor` / `BatchedCPTensor` — a whole batch of
        structured inputs in ONE dispatch (the carry-sweep kernels put the
        batch on a native grid axis; there is no vmap on any route).

    `pipeline='double'` selects the double-buffered DMA schedule on the
    kernel routes (dense mode sweep and structured carry sweep) — same
    results to fp32 tolerance, input/core streams overlapped with the MXU
    contractions. Ignored on the einsum routes (there is nothing to
    pipeline by hand); validated either way so a typo cannot silently run
    serial.

    Returns the `(*batch, k)` sketch ((k,) for single structured inputs,
    (B, k) for batched containers).
    """
    _check_pipeline(pipeline)
    if isinstance(x, STRUCT_TYPES):
        return _project_struct(op, x, backend, pipeline)
    return _project_dense(op, x, backend, pipeline)


def reconstruct(op: RPOperator, y: jnp.ndarray, *, chunk: int | None = None,
                backend: str = "auto") -> jnp.ndarray:
    """Unbiased adjoint reconstruction, `(*batch, k) -> (*batch, *in_dims)`.

    A `(k,)` sketch returns an `in_dims`-shaped estimate (the original
    contract); batched sketches route to the batched mode-sweep adjoint
    kernels (`tt_sweep_reconstruct` / `cp_sweep_reconstruct`, any order
    N >= 2) under the same backend policy as `project` — ONE launch for the
    whole batch, no vmap — and otherwise fall back to a vmap of the
    operator's einsum adjoint.

    `chunk` precedence: `chunk` bounds the k-sized intermediate on the
    EINSUM path only. The kernel route tiles k internally (the planner's
    VMEM budget already bounds the intermediate), so when backend policy
    selects a kernel, a user-supplied `chunk` is ignored — with a
    `UserWarning`, since the caller asked for a memory bound the kernel
    honors by different means. Pass `backend='xla'` to make `chunk`
    authoritative.
    """
    y = jnp.asarray(y)
    if y.ndim < 1 or y.shape[-1] != op.k:
        raise FormatMismatchError(
            f"sketch shape {tuple(y.shape)} does not end in k = {op.k}")
    is_tn = isinstance(op, (TTRP, CPRP))
    supported = is_tn and _kernel_order_ok(op.order)
    use = _use_kernel(backend, supported=supported, aligned=_mxu_aligned(op))
    route = "pallas" if use else "xla"
    order = _order_tag(op)
    _STATS.get().record(_family_tag(op), "sketch", route, order)
    with obs.span("rp.reconstruct", family=_family_tag(op),
                  structure="sketch", order=order, backend=route,
                  pipeline="serial"):
        if use:
            from repro.kernels import ops as kops  # local: avoids cycle
            if chunk is not None:
                warnings.warn(
                    f"reconstruct(chunk={chunk}) routed to a Pallas kernel, "
                    "which tiles k internally under its own VMEM budget; the "
                    "chunk argument is ignored on this route. Pass "
                    "backend='xla' to honor it on the einsum path.",
                    UserWarning, stacklevel=2)
            interpret = not _on_tpu()
            kern = (kops.tt_reconstruct if isinstance(op, TTRP)
                    else kops.cp_reconstruct)
            if y.ndim <= 2:
                return kern(op, y, interpret=interpret)
            batch = y.shape[:-1]
            out = kern(op, y.reshape(-1, op.k), interpret=interpret)
            return out.reshape(batch + tuple(op.in_dims))
        if y.ndim == 1:
            return op.reconstruct(y, chunk=chunk)
        batch = y.shape[:-1]
        out = jax.vmap(lambda yy: op.reconstruct(yy, chunk=chunk))(
            y.reshape(-1, op.k))
        return out.reshape(batch + tuple(op.in_dims))
