"""repro.rp — the unified projector API for all random-projection families.

One protocol (`RPOperator`), one declarative spec (`ProjectorSpec`), a
registry (`register_family` / `make_projector`), and a structure-dispatched
functional entry point (`project` / `reconstruct`) with backend routing
('auto' | 'pallas' | 'xla') to the order-N mode-sweep Pallas TPU kernels.
`project_many` fans a heterogeneous list of payloads (dense / TT / CP,
rank-ragged) out to those paths in one dispatch per structure group — the
serving engine's batch entry. Every execution resolves through a
cached, frozen `ExecutionPlan` (`repro.rp.plan`: route + kernel +
tiles/grid + pipeline + the unified flops/hbm/vmem/wire/variance cost
ledger); `rp.explain(op, x)` returns the plan that would run, with its
rejected alternatives and reasons. Dispatch instrumentation is
context-local (`DispatchStats` /
`dispatch_stats()` / `kernel_call_count()`). Mesh-aware sharded entry
points (`project_sharded` / `reconstruct_sharded` / `sketch_tree_sharded`
/ `bucket_pspec`) lay the bucket axis out over a `jax.sharding.Mesh` with
`shard_map` — one kernel dispatch per shard, operator replicated.

Quickstart::

    from repro import rp
    import jax

    spec = rp.ProjectorSpec(family="tt", k=256, dims=(8, 128, 64), rank=2)
    op = rp.make_projector(spec, jax.random.PRNGKey(0))
    y = rp.project(op, x)                      # dense, flat, TT or CP input
                                               # (or a BatchedTTTensor /
                                               # BatchedCPTensor batch: one
                                               # carry-sweep launch)
    x_hat = rp.reconstruct(op, y)              # unbiased adjoint

The four built-in families are 'tt', 'cp', 'gaussian', 'sparse'; new ones
register with::

    @rp.register_family("my-family")
    def _make(spec, key): ...

The `repro.core` operator classes and samplers remain importable; their
per-format method zoo (`project_tt` / `project_cp`) is deprecated in favor
of `rp.project` and kept for one release.
"""
from . import families as _families  # noqa: F401  (registers built-ins)
from .dispatch import (DispatchStats, count_kernel_dispatch, current_stats,
                       dispatch_breakdown, dispatch_stats, force_pallas,
                       kernel_call_count, project, reconstruct)
from .many import project_many
from .plan import (BACKENDS, CostLedger, ExecutionPlan, PlanCacheStats,
                   StructureSig, clear_plan_cache, collective_wire_bytes,
                   execute_plan, explain, group_signature, plan_cache_stats,
                   plan_execution, plan_update, pow2ceil, structure_tag,
                   validate_backend, validate_pipeline)
from .protocol import FormatMismatchError, ProjectorSpec, RPOperator
from .registry import (get_family, list_families, make_projector,
                       register_family)
from .shard import (bucket_pspec, dequantize_psum, project_sharded,
                    quantize_for_psum, reconstruct_sharded,
                    sketch_tree_sharded)

__all__ = [
    "BACKENDS", "CostLedger", "DispatchStats", "ExecutionPlan",
    "FormatMismatchError", "PlanCacheStats", "ProjectorSpec", "RPOperator",
    "StructureSig", "bucket_pspec", "clear_plan_cache",
    "collective_wire_bytes", "count_kernel_dispatch", "current_stats",
    "dispatch_breakdown", "dispatch_stats", "force_pallas",
    "dequantize_psum", "execute_plan", "explain", "get_family",
    "group_signature", "kernel_call_count", "list_families",
    "make_projector", "plan_cache_stats", "plan_execution", "plan_update",
    "pow2ceil", "project", "project_many", "project_sharded",
    "quantize_for_psum", "reconstruct", "reconstruct_sharded",
    "register_family", "sketch_tree_sharded", "structure_tag",
    "validate_backend", "validate_pipeline",
]
