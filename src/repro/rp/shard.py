"""Mesh-aware sharded sketching: shard_map entry points over the bucket axis.

The paper's systems claim — the TT/CP operator is O(kNdR^2) floats, so every
host regenerates it from a PRNG key and only sketches cross the network — is
what makes *distributed* sketching cheap. This module is where that claim
becomes explicit SPMD: `project_sharded` / `sketch_tree_sharded` take a
`jax.sharding.Mesh` plus a bucket `PartitionSpec` and lay the `(n_buckets,
...)` axis out over the mesh with `shard_map`, so every device runs ONE
kernel dispatch on its local bucket slice (the operator is an explicitly
replicated input — P() on every core — never an implicit broadcast the
partitioner might materialize differently per backend).

Layering: this module knows nothing about launch/ axis conventions. The
default `bucket_pspec` shards over every mesh axis that divides the bucket
count; `launch/sharding.py::bucket_specs` narrows that to the data axes of
the production mesh, and `optim/compress.py::compress_collective` builds the
cross-pod compressed all-reduce on top (manual over the pod axis, `auto`
over the rest).

All entry points degrade gracefully: a spec that shards over nothing (or a
bucket count the mesh axes do not divide) falls back to the plain
un-shard_map'd `rp.project` call, so single-device tests and CPU examples
run the same code path end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .dispatch import project, reconstruct


def _axes_tuple(entry) -> tuple[str, ...]:
    """Normalize a PartitionSpec entry to a tuple of axis names."""
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _axes_size(mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def shard_entry(mesh, spec) -> tuple:
    """(dim-0 spec entry, axes tuple, total shard size) for a bucket spec.

    The one place the `(n_buckets, ...)` spec convention is decoded — the
    shard_map entry points and `PytreeSketcher._constrain` all call this, so
    the pjit layout and the shard_map layout can never disagree on what a
    spec entry means.
    """
    entry = spec[0] if len(spec) else None
    axes = _axes_tuple(entry)
    return entry, axes, _axes_size(mesh, axes)


def bucket_pspec(mesh, n_buckets: int, *, axes=None, exclude=()) -> P:
    """PartitionSpec for a `(n_buckets, ...)` bucket array on `mesh`.

    Picks the largest prefix of `axes` (default: every mesh axis not in
    `exclude`) whose total size divides `n_buckets` and shards dim 0 over
    it; `P(None)` when nothing divides. Trailing dims are left unsharded —
    each bucket is one kernel-sized tensorized block.
    """
    cand = tuple(a for a in (axes if axes is not None else mesh.axis_names)
                 if a not in exclude)
    for cut in range(len(cand), 0, -1):
        sub = cand[:cut]
        if n_buckets % _axes_size(mesh, sub) == 0:
            return P(sub)
    return P(None)


def _sharded_apply(fn, op, x, *, mesh, spec, axes):
    """shard_map `fn(op, x_local)` with dim 0 of `x` laid out per `spec`."""
    auto = frozenset(mesh.axis_names) - set(axes)
    op_specs = jax.tree.map(lambda _: P(), op)
    f = shard_map(fn, mesh=mesh, in_specs=(op_specs, P(spec[0])),
                  out_specs=P(spec[0]), check_rep=False, auto=auto)
    return f(op, x)


def project_sharded(op, x, *, mesh, spec: P | None = None,
                    backend: str = "auto") -> jnp.ndarray:
    """`rp.project` with the bucket axis sharded over the mesh.

    x: `(n_buckets, *op.in_dims)` (or `(n_buckets, D)` for flat-contracting
    families). Each shard of the bucket axis runs ONE `rp.project` dispatch
    on its local buckets — the kernel's native batch grid axis does the rest
    — and the operator is an explicitly replicated shard_map input, so
    nothing but `x` is ever laid out over the wire. Returns the
    `(n_buckets, k)` sketch sharded the same way.

    `spec` defaults to `bucket_pspec(mesh, n_buckets)`; a spec (or bucket
    count) that shards over nothing falls back to the plain dispatch.
    """
    x = jnp.asarray(x)
    if spec is None:
        spec = bucket_pspec(mesh, x.shape[0])
    _, axes, size = shard_entry(mesh, spec)
    if size <= 1:
        return project(op, x, backend=backend)
    if x.shape[0] % size:
        raise ValueError(
            f"bucket count {x.shape[0]} is not divisible by mesh axes "
            f"{axes} (size {size}); pass a spec that divides it "
            "(bucket_pspec picks the largest valid prefix)")
    # per-shard plan reuse: every shard body dispatches the SAME local
    # shape, so resolving the plan for one shard here means every traced
    # body (and every re-trace at this shape) is a plan-cache hit
    from .plan import StructureSig, plan_execution
    plan_execution(op, StructureSig(structure="dense",
                                    batch=x.shape[0] // size),
                   backend=backend)

    def body(o, xl):
        return project(o, xl, backend=backend)

    return _sharded_apply(body, op, x, mesh=mesh, spec=spec, axes=axes)


def reconstruct_sharded(op, y, *, mesh, spec: P | None = None,
                        backend: str = "auto") -> jnp.ndarray:
    """Adjoint of `project_sharded`: `(n_buckets, k) -> (n_buckets, *dims)`.

    Same layout contract: one batched `rp.reconstruct` dispatch per shard of
    the bucket axis, operator replicated, output sharded like the input.
    """
    y = jnp.asarray(y)
    if spec is None:
        spec = bucket_pspec(mesh, y.shape[0])
    _, axes, size = shard_entry(mesh, spec)
    if size <= 1:
        return reconstruct(op, y, backend=backend)
    if y.shape[0] % size:
        raise ValueError(
            f"bucket count {y.shape[0]} is not divisible by mesh axes "
            f"{axes} (size {size}); pass a spec that divides it")
    # per-shard plan reuse (see project_sharded): one resolve, N shard hits
    from .plan import StructureSig, plan_execution
    plan_execution(op, StructureSig(structure="sketch",
                                    batch=y.shape[0] // size),
                   kind="reconstruct", backend=backend)

    def body(o, yl):
        return reconstruct(o, yl, backend=backend)

    return _sharded_apply(body, op, y, mesh=mesh, spec=spec, axes=axes)


# ---------------------------------------------------------------------------
# int8 wire quantization for collective sketch syncs
# ---------------------------------------------------------------------------

def quantize_for_psum(y: jnp.ndarray, axis_name: str, npod: int,
                      *, per_row: bool = True):
    """Scaled-int8 quantization safe to `lax.psum` over `axis_name`.

    Emits `(q, s)` with `q` int8 and `s` a float32 scale such that
    `q ~= round(y / s)` clipped to `[-qmax, qmax]` for
    `qmax = 127 // npod` — the clip makes the integer all-reduce
    OVERFLOW-PROOF: the sum of `npod` values each bounded by `qmax` is
    bounded by `npod * qmax <= 127`, so the s8 accumulator can never wrap
    regardless of reduction order. The scale is SHARED across the axis
    (a `lax.pmax` of the local absmax), so every pod quantizes onto the
    same grid and `dequantize_psum(psum(q), s, npod)` is exactly the mean
    of the quantized values — bitwise identical on every pod.

    `per_row=True` scales each leading-axis row by its own absmax (the
    (n_buckets, k) sketch layout: one scale per bucket row costs 4 bytes
    against the row's k payload bytes); `per_row=False` uses one scalar
    scale for the whole array (dense local-mean leaves).

    `jnp.round` (half-to-even) and the integer psum are both deterministic
    and order-independent, so the dequantized result is bitwise
    reproducible across runs and pod counts — the property the
    determinism test in tests/test_compress.py pins.
    """
    if npod > 127:
        raise ValueError(
            f"int8 wire quantization supports at most 127 pods (qmax = "
            f"127 // npod would be 0), got npod={npod}")
    qmax = 127 // npod
    if per_row:
        a = jnp.max(jnp.abs(y), axis=tuple(range(1, y.ndim)), keepdims=True)
    else:
        a = jnp.max(jnp.abs(y))
    a = jax.lax.pmax(a, axis_name)
    s = jnp.maximum(a, jnp.finfo(jnp.float32).tiny) / qmax
    q = jnp.clip(jnp.round(y / s), -qmax, qmax).astype(jnp.int8)
    return q, s


def dequantize_psum(q_sum: jnp.ndarray, s: jnp.ndarray,
                    npod: int) -> jnp.ndarray:
    """Mean-dequantize an int8 `lax.psum` result: q_sum * s / npod."""
    return q_sum.astype(jnp.float32) * s / npod


def sketch_tree_sharded(cfg, tree, key, *, mesh, spec: P | None = None,
                        sketcher=None) -> jnp.ndarray:
    """Whole-tree sketch with every leaf's bucket axis sharded over `mesh`.

    The sharded-engine formulation of `PytreeSketcher.sketch`: buckets are
    built per leaf exactly as the sketcher does (same padding, same
    tensorization), then projected through `project_sharded` — ONE kernel
    dispatch per leaf per shard, with a per-leaf divisibility fallback to
    the unsharded dispatch (ragged tail leaves still sketch correctly, they
    just run replicated). Structured (TT/CP-format) leaves keep their
    compressed-domain single-dispatch route.

    Returns the `(n_buckets, k)` sketch, buckets concatenated over leaves in
    the sketcher's canonical order — bit-compatible with
    `PytreeSketcher.sketch` under the same key (it IS the sketcher's loop,
    with the dense-bucket projection swapped for the shard_map one).
    """
    from repro.core.sketch import PytreeSketcher
    sk = sketcher if sketcher is not None else PytreeSketcher(
        cfg, tree, mesh=mesh, bucket_spec=spec)

    def project_fn(op, buckets):
        nb = buckets.shape[0]
        leaf_spec = spec if spec is not None else bucket_pspec(mesh, nb)
        _, _, size = shard_entry(mesh, leaf_spec)
        if size > 1 and nb % size == 0:
            return project_sharded(op, buckets, mesh=mesh, spec=leaf_spec,
                                   backend=sk.cfg.backend)
        return project(op, buckets, backend=sk.cfg.backend)

    return sk.sketch(tree, key, project_fn=project_fn)
