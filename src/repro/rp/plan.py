"""ExecutionPlan: the one plan/compile layer under every projection path.

Every `rp.project` / `rp.reconstruct` / `rp.project_many` / serve-tick
execution resolves through a frozen, hashable `ExecutionPlan` produced by
`plan_execution(op_spec, structure_sig, *, backend, pipeline)` and held in
an LRU plan cache keyed by the same jit-cache-stable signature `many.py`
buckets traffic on: (family, k, dims, rank) x (structure, batch, in_rank,
chunk) x (backend, pipeline) x routing environment. Dispatch is plan
lookup -> record stats -> execute; the policy that used to live in three
places (`dispatch._use_kernel`, the planners' inline checks, the
benchmarks' re-derived ledgers) lives HERE, once.

Dispatch matrix (input format x operator family -> route):

  dense/flat x tt/cp (2<=N<=MAX_ORDER)  mode-sweep kernel | einsum
  (*batch, k) sketch x tt/cp            mode-sweep adjoint kernel | einsum
  (Batched)TT/CP x tt/cp (2<=N)         carry-sweep kernel
                                        (`kernels.struct.struct_project`,
                                        all four pairings, ONE launch per
                                        batched call) | batched einsum refs
  (Batched)TT/CP x gaussian/sparse      densified (`x.full()`) flat einsum
  order outside [2, MAX_ORDER] x any    einsum, even under 'pallas'

Backend policy (`backend='auto' | 'pallas' | 'xla'`)
---------------------------------------------------
Dense-input projections of the TT/CP families at any kernel-supported
order (2 <= N <= `repro.kernels.MAX_ORDER`) have batched mode-sweep Pallas
kernels (`repro.kernels.tt_project` / `cp_project` — `(*batch, *dims)`
inputs run in ONE launch with a native batch grid axis, never vmap); the
adjoints route the same way through `tt_reconstruct` / `cp_reconstruct`
for `(*batch, k)` sketches; structured (TT/CP-format) inputs — single or
batched, any pairing with a TT/CP operator — route to the carry-sweep
kernels in `repro.kernels.struct` (compressed-domain projection,
O(k N d R R~ (R + R~)), never densifying). Routing:

* 'xla'    — always the einsum path.
* 'pallas' — always the kernel (operators outside the supported order
             range — order-1 classical Gaussian, order > MAX_ORDER — take
             the einsum path); interpret mode off-TPU.
* 'auto'   — the kernel iff the shapes are MXU-aligned (k a multiple of the
             128 lane width, every mode a multiple of the 8 sublanes, order
             >= 2) AND we are on real TPU hardware. Off-TPU the kernels
             only run in interpret mode — a validation device, not a fast
             path — so 'auto' stays on XLA there unless `force_pallas()` is
             active (which tests use to prove the routing).

`chunk` on reconstruct is part of the plan, not a warning: the kernel
route records `chunk_policy='folded'` (the planner's VMEM budget already
tiles k, so the requested bound is honored by the kernel's own k-tiling);
the einsum route records `'honored'` and threads `chunk` through to
`op.reconstruct`. Pass `backend='xla'` to make a specific chunk value
authoritative.

The plan carries a unified `CostLedger` — flops, analytic HBM bytes (the
SAME `sweep_hbm_bytes` / `struct_hbm_bytes` planner ledgers the kernels
are scheduled by), VMEM footprint, collective wire bytes, the operator
parameter count, and the paper's Thm-1 variance factor — so benchmarks,
rooflines, and the compressor read one ledger instead of re-deriving
three. `rp.explain(op, x)` returns the chosen plan with its rejected
alternatives and reasons: this docstring, executable.

Routing environment (`jax.default_backend()`, `force_pallas()` depth) is
part of the cache key, so a plan never outlives the conditions that chose
its route.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.core.baselines import GaussianRP, VerySparseRP
from repro.core.cp_rp import CPRP
from repro.core.formats import (BatchedCPTensor, BatchedTTTensor, CPTensor,
                                TTTensor, _prod)
from repro.core.tt_rp import TTRP
from repro.core import theory

from .protocol import ProjectorSpec

# ---------------------------------------------------------------------------
# centralized backend / pipeline validation (the ONE typed check; dispatch,
# ProjectorSpec, ServeConfig and the planners all delegate here)
# ---------------------------------------------------------------------------

BACKENDS = ("auto", "pallas", "xla")
STRUCTURES = ("dense", "tt", "cp", "sketch")


def validate_backend(backend: str) -> str:
    """The single `backend=` check: returns it, or raises the one typed
    ValueError naming the accepted set. Survives `python -O`."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    return backend


def validate_pipeline(pipeline: str) -> str:
    """The single `pipeline=` check — delegates to the kernels layer, which
    owns the `PIPELINES` tuple the schedules implement."""
    # local import: repro.kernels is deliberately not a module-level dep
    from repro.kernels.ops import validate_pipeline as _vp
    return _vp(pipeline)


def pow2ceil(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the canonical shape-bucket
    rounding `project_many` pads batches/ranks with and the serve engine
    pre-plans against (same function => same plan-cache key)."""
    out = 1
    while out < max(int(n), floor):
        out *= 2
    return out


def structure_tag(payload) -> str:
    """'tt' | 'cp' | 'dense' — the canonical structure of ONE payload (the
    group key of `project_many` and the serve batcher's lane splitter)."""
    if isinstance(payload, (TTTensor, BatchedTTTensor)):
        return "tt"
    if isinstance(payload, (CPTensor, BatchedCPTensor)):
        return "cp"
    return "dense"


# ---------------------------------------------------------------------------
# the plan IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StructureSig:
    """Jit-cache-stable signature of WHAT is being executed.

    structure : 'dense' | 'tt' | 'cp' (structured input) | 'sketch'
                (reconstruct input).
    batch     : coalesced batch rows the dispatch will see (1 for a single
                payload; `project_many`/serve bucket to `pow2ceil(n, 8)`).
    in_rank   : structured-input rank as the carry-sweep planner sees it
                (TT: max bond rank incl. boundary 1s; CP: component rank);
                0 for dense/sketch.
    chunk     : reconstruct-only k-intermediate bound (None elsewhere).
    """

    structure: str = "dense"
    batch: int = 1
    in_rank: int = 0
    chunk: int | None = None

    def __post_init__(self):
        if self.structure not in STRUCTURES:
            raise ValueError(f"unknown structure {self.structure!r}; "
                             f"expected {STRUCTURES}")


@dataclasses.dataclass(frozen=True)
class CostLedger:
    """The unified analytic cost ledger of one planned execution.

    flops      : 2x multiply-add count for the WHOLE batch (per-item cost
                 times `plan.batch`), from `repro.core.theory`.
    hbm_bytes  : analytic HBM traffic — the kernel routes read the SAME
                 planner ledgers the schedules are budgeted by
                 (`sweep_hbm_bytes` / `struct_hbm_bytes` /
                 `fused_hbm_bytes`); einsum routes report the one-pass
                 lower bound (inputs + operator + outputs, streamed once).
    vmem_bytes : accounted per-kernel-instance VMEM footprint (0 on xla).
    wire_bytes : collective payload bytes (0 for local dispatch; the
                 compressed-all-reduce ledger via `collective_wire_bytes`).
    params     : operator parameter count (the paper's memory axis).
    var_factor : Thm-1 variance factor of the family at this order/rank.
    """

    flops: int
    hbm_bytes: int
    vmem_bytes: int
    wire_bytes: int
    params: int
    var_factor: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully-resolved, frozen, hashable execution decision.

    `route` is the RESOLVED backend ('pallas' | 'xla') under the requested
    `backend` policy and the routing environment; `rejected` names every
    alternative route with the reason it lost — `rp.explain` is just this
    field. `tiles`/`grid`/`vmem` come from the kernel planner actually
    used (`plan_contraction` / `plan_carry_sweep`); None/0 on the einsum
    route. `plan_id` is a short stable hash of the cache key, tagged onto
    the dispatch obs spans so traces join to exact routes.
    """

    plan_id: str
    family: str
    structure: str
    kind: str                      # 'project' | 'reconstruct' | 'update'
    order: int
    k: int
    batch: int
    dims: tuple
    rank: int
    in_rank: int
    backend: str                   # requested policy
    route: str                     # resolved 'pallas' | 'xla'
    kernel: str
    pipeline: str
    chunk: int | None
    chunk_policy: str              # 'n/a' | 'folded' | 'honored'
    tiles: tuple | None            # (tk, tb, ba) / (tk, tb)
    grid: tuple | None
    rejected: tuple                # ((route, reason), ...)
    cost: CostLedger
    carry_bytes: int = 0           # structured routes: the (B, k, R·R~)
                                   # bond state replacing dense sweep temps

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["cost"] = self.cost.as_dict()
        return out

    def describe(self) -> str:
        """Markdown block for `rp.explain` / `obs_report --explain`."""
        c = self.cost
        lines = [
            f"### plan {self.plan_id}: {self.kind} "
            f"{self.family}/{self.structure} N={self.order}",
            "",
            f"* route: **{self.route}** (requested backend="
            f"'{self.backend}', pipeline='{self.pipeline}')",
            f"* kernel: {self.kernel}",
            f"* shape: k={self.k} dims={'x'.join(map(str, self.dims))} "
            f"rank={self.rank} batch={self.batch}"
            + (f" in_rank={self.in_rank}" if self.in_rank else ""),
        ]
        if self.tiles is not None:
            lines.append(f"* tiles: {self.tiles} grid={self.grid}")
        if self.carry_bytes:
            lines.append(f"* carry_bytes: {self.carry_bytes}")
        if self.kind == "reconstruct":
            lines.append(f"* chunk: {self.chunk} ({self.chunk_policy})")
        lines += [
            f"* cost: flops={c.flops} hbm_bytes={c.hbm_bytes} "
            f"vmem_bytes={c.vmem_bytes} wire_bytes={c.wire_bytes} "
            f"params={c.params} var_factor={c.var_factor:.2f}",
            "",
            "rejected alternatives:",
        ]
        for route, reason in self.rejected:
            lines.append(f"* {route}: {reason}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

_CACHE_CAP = 512


@dataclasses.dataclass
class PlanCacheStats:
    builds: int = 0
    hits: int = 0
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.builds + self.hits

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {"builds": self.builds, "hits": self.hits,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


_PLAN_CACHE: "OrderedDict[tuple, ExecutionPlan]" = OrderedDict()
_CACHE_STATS = PlanCacheStats()


def plan_cache_stats() -> PlanCacheStats:
    """The LIVE global plan-cache stats object (builds/hits/evictions)."""
    return _CACHE_STATS


def clear_plan_cache() -> None:
    """Drop every cached plan and reset the stats (tests/benchmarks)."""
    _PLAN_CACHE.clear()
    _CACHE_STATS.builds = 0
    _CACHE_STATS.hits = 0
    _CACHE_STATS.evictions = 0


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------

# operator class -> family tag for plans/spans/breakdowns; third-party
# registered families fall back to their lowercased class name
_FAMILY_BY_TYPE = {TTRP: "tt", CPRP: "cp", GaussianRP: "gaussian",
                   VerySparseRP: "sparse"}
_TN_FAMILIES = ("tt", "cp")


def _family_tag(op) -> str:
    for cls, name in _FAMILY_BY_TYPE.items():
        if isinstance(op, cls):
            return name
    return type(op).__name__.lower()


def _order_tag(op) -> int:
    try:
        return int(op.order)
    except (AttributeError, TypeError):
        return len(tuple(op.in_dims))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _aligned(k: int, dims: tuple) -> bool:
    """MXU alignment: k on the 128 lane width, >= 2 modes, every mode a
    multiple of the 8 sublanes — the 'auto' policy's hardware predicate."""
    return k % 128 == 0 and len(dims) >= 2 and all(d % 8 == 0 for d in dims)


@dataclasses.dataclass(frozen=True)
class _OpSig:
    """Jit-cache-stable signature of the OPERATOR side of a plan key."""

    family: str
    k: int
    dims: tuple
    rank: int
    order: int
    is_tn: bool


def _op_signature(op_spec) -> _OpSig:
    """Normalize an operator instance OR a `ProjectorSpec` to one key.

    Operator instances are authoritative (dispatch plans from them);
    spec-based plans (benchmarks, `obs_report --explain`) see the spec's
    dims, which for flat-vector families differ from the operator's
    single-mode `in_dims` — routing is identical either way (non-TN
    families have no kernel), only the cache keys differ.
    """
    if isinstance(op_spec, ProjectorSpec):
        family = op_spec.family
        is_tn = family in _TN_FAMILIES
        dims = tuple(op_spec.dims)
        return _OpSig(family=family, k=int(op_spec.k), dims=dims,
                      rank=int(op_spec.rank) if is_tn else 0,
                      order=len(dims), is_tn=is_tn)
    op = op_spec
    is_tn = isinstance(op, (TTRP, CPRP))
    return _OpSig(family=_family_tag(op), k=int(op.k),
                  dims=tuple(int(d) for d in op.in_dims),
                  rank=int(op.rank) if is_tn else 0,
                  order=_order_tag(op), is_tn=is_tn)


def struct_in_rank(x) -> int:
    """The structured-input rank exactly as the carry-sweep planner sees
    it: max TT bond rank (boundary 1s included) or the CP component rank."""
    if isinstance(x, (TTTensor, BatchedTTTensor)):
        return int(max(x.ranks))
    return int(x.rank)


def group_signature(op, payloads, *, bucket: bool = True) -> StructureSig:
    """The `StructureSig` a coalesced `project_many` group will dispatch.

    Computes — WITHOUT materializing the batch — the exact padded shape
    `many.py` produces for a homogeneous payload list: batch rows bucketed
    to `pow2ceil(n, 8)`, TT interior bond ranks / CP component ranks
    bucketed per-position to powers of two. The serve engine pre-plans
    with this signature, so its tick hits the SAME plan-cache entry the
    coalesced dispatch resolves — one plan build per lane shape, total.
    """
    payloads = list(payloads)
    if not payloads:
        raise ValueError("group_signature needs at least one payload")
    tags = {structure_tag(p) for p in payloads}
    if len(tags) > 1:
        raise ValueError(
            f"group_signature needs a structurally homogeneous group, got "
            f"{sorted(tags)}; split by structure_tag first")
    tag = tags.pop()
    b = pow2ceil(len(payloads), 8) if bucket else len(payloads)
    if tag == "dense":
        return StructureSig(structure="dense", batch=b)
    if tag == "tt":
        n_bonds = len(payloads[0].ranks)
        per_pos = [max(p.ranks[i] for p in payloads)
                   for i in range(n_bonds)]
        if bucket:
            per_pos = ([per_pos[0]]
                       + [pow2ceil(r) for r in per_pos[1:-1]]
                       + [per_pos[-1]])
        return StructureSig(structure="tt", batch=b,
                            in_rank=int(max(per_pos)))
    r = max(int(p.rank) for p in payloads)
    return StructureSig(structure="cp", batch=b,
                        in_rank=pow2ceil(r) if bucket else r)


# ---------------------------------------------------------------------------
# the resolver
# ---------------------------------------------------------------------------

def _force_pallas_active() -> bool:
    # local import: dispatch imports this module at module level
    from . import dispatch
    return dispatch.current_stats().force_pallas


def _resolve_route(backend: str, *, supported: bool, aligned: bool,
                   on_tpu: bool, force: bool) -> tuple[str, tuple]:
    """(route, rejected) under the backend policy — the old `_use_kernel`
    decision, with the losing route's reason made explicit."""
    if not supported:
        return "xla", (("pallas", "no mode-sweep kernel for this "
                        "(family, order): kernels cover tt/cp at "
                        "2 <= N <= MAX_ORDER"),)
    if backend == "pallas":
        return "pallas", (("xla", "backend='pallas' pins the kernel "
                           "route"),)
    if backend == "xla":
        return "xla", (("pallas", "backend='xla' pins the einsum route"),)
    if not aligned:
        return "xla", (("pallas", "'auto' needs MXU-aligned shapes "
                        "(k % 128 == 0, >= 2 modes, every mode % 8 == 0)"),)
    if on_tpu or force:
        return "pallas", (("xla", "'auto' on aligned shapes on TPU (or "
                           "under force_pallas()) selects the kernel"),)
    return "xla", (("pallas", "off-TPU the kernels only run in interpret "
                    "mode — a validation device, not a fast path; 'auto' "
                    "stays on XLA (force_pallas() overrides)"),)


def _xla_dense_hbm(sig_b: int, k: int, dims: tuple, params: int) -> int:
    """One-pass lower bound of the einsum route: x + operator + y."""
    return 4 * (sig_b * _prod(dims) + params + sig_b * k)


def _safe_params(family: str, k: int, dims: tuple, rank: int) -> int:
    try:
        return int(theory.params_rp(family, k, dims, max(1, rank)))
    except Exception:
        return int(k * _prod(dims))  # unknown registered family: dense-eq


def _safe_var_factor(family: str, order: int, rank: int, dims: tuple
                     ) -> float:
    try:
        return float(theory.variance_factor(family, N=order,
                                            R=max(1, rank), D=_prod(dims)))
    except Exception:
        return float(theory.variance_factor_gaussian())


def _kernel_name(op_sig: _OpSig, sig: StructureSig, kind: str, route: str,
                 pipeline: str) -> str:
    if route == "xla":
        return {"project": "einsum", "reconstruct": "einsum_adjoint"}[kind]
    if sig.structure in ("tt", "cp"):
        return ("carry_sweep_pipelined" if pipeline == "double"
                else "carry_sweep")
    if kind == "reconstruct":
        return f"{op_sig.family}_sweep_adjoint"
    return ("sweep_pipelined" if pipeline == "double"
            else f"{op_sig.family}_sweep")


def _build_plan(op_sig: _OpSig, sig: StructureSig, kind: str, backend: str,
                pipeline: str, on_tpu: bool, force: bool,
                key: tuple) -> ExecutionPlan:
    # local import: repro.kernels is deliberately not a module-level dep of
    # the rp layer's import graph (dispatch no longer imports it at all)
    from repro.kernels import ops as kops
    from repro.kernels.struct import plan as ksplan

    f, k, dims, rank = op_sig.family, op_sig.k, op_sig.dims, op_sig.rank
    order, b = op_sig.order, int(sig.batch)
    order_ok = kops.kernel_order_supported(order)
    supported = op_sig.is_tn and order_ok
    aligned = _aligned(k, dims)
    route, rejected = _resolve_route(backend, supported=supported,
                                    aligned=aligned, on_tpu=on_tpu,
                                    force=force)
    params = _safe_params(f, k, dims, rank)
    var = _safe_var_factor(f, order, rank, dims)
    tiles = grid = None
    vmem = 0
    carry = 0
    if sig.structure in ("tt", "cp"):
        # structured input x TT/CP operator: the carry sweep
        per_item = theory.flops_project_struct(f, sig.structure, k, dims,
                                               max(1, rank),
                                               max(1, sig.in_rank))
        flops = b * per_item
        carry = theory.mem_carry_struct(k, max(1, rank),
                                        max(1, sig.in_rank), batch=b)
        if route == "pallas":
            cplan = ksplan.plan_carry_sweep(f, sig.structure, k, b, dims,
                                            rank, sig.in_rank,
                                            pipeline=pipeline)
            tiles, grid = (cplan.tk, cplan.tb), cplan.grid
            vmem = cplan.vmem_bytes
            hbm = ksplan.struct_hbm_bytes(cplan)
        else:
            in_elems = ksplan._core_elems(sig.structure, dims,
                                          max(1, sig.in_rank))
            hbm = 4 * (k * ksplan._core_elems(f, dims, max(1, rank))
                       + b * in_elems + b * k)
    else:
        if op_sig.is_tn:
            per_item = (theory.flops_project_dense_tt(k, dims, max(1, rank))
                        if f == "tt"
                        else theory.flops_project_dense_cp(k, dims,
                                                           max(1, rank)))
        else:
            # flat-vector families: 2 flops per stored parameter per item
            per_item = 2 * params
        flops = b * per_item
        if route == "pallas":
            kplan = kops.plan_contraction(f, kind, k, b, dims, rank,
                                          pipeline=pipeline)
            tiles, grid = (kplan.tk, kplan.tb, kplan.ba), kplan.grid
            vmem = kplan.vmem_bytes
            hbm = kops.sweep_hbm_bytes(kplan)
        else:
            hbm = _xla_dense_hbm(b, k, dims, params)
    if kind == "reconstruct":
        chunk_policy = "folded" if route == "pallas" else "honored"
    else:
        chunk_policy = "n/a"
    plan_id = hashlib.blake2s(repr(key).encode(),
                              digest_size=6).hexdigest()
    return ExecutionPlan(
        plan_id=plan_id, family=f, structure=sig.structure, kind=kind,
        order=order, k=k, batch=b, dims=dims, rank=rank,
        in_rank=int(sig.in_rank), backend=backend, route=route,
        kernel=_kernel_name(op_sig, sig, kind, route, pipeline),
        pipeline=pipeline, chunk=sig.chunk, chunk_policy=chunk_policy,
        tiles=tiles, grid=grid, rejected=rejected,
        cost=CostLedger(flops=int(flops), hbm_bytes=int(hbm),
                        vmem_bytes=int(vmem), wire_bytes=0, params=params,
                        var_factor=var),
        carry_bytes=int(carry))


def plan_execution(op_spec, structure_sig: StructureSig | None = None, *,
                   kind: str = "project", backend: str = "auto",
                   pipeline: str = "serial",
                   force_pallas: bool | None = None) -> ExecutionPlan:
    """Resolve (or fetch from the LRU cache) the `ExecutionPlan` for one
    execution of `op_spec` (an operator instance or a `ProjectorSpec`)
    against `structure_sig` (defaults to a single dense payload).

    This is THE resolver: backend/pipeline validation happens here once,
    the route decision replicates the dispatch policy bitwise (see the
    module docstring), and the returned plan carries the unified cost
    ledger. The cache key includes the routing environment
    (`jax.default_backend()`, `force_pallas()` — pass `force_pallas=` to
    pin it explicitly), so cached plans cannot outlive the conditions
    that chose their route.
    """
    validate_backend(backend)
    validate_pipeline(pipeline)
    if kind not in ("project", "reconstruct"):
        raise ValueError(f"unknown kind {kind!r}; expected "
                         "('project', 'reconstruct')")
    sig = structure_sig if structure_sig is not None else StructureSig()
    if kind == "reconstruct" and sig.structure != "sketch":
        raise ValueError(
            f"kind='reconstruct' plans take structure='sketch' signatures, "
            f"got {sig.structure!r}")
    op_sig = _op_signature(op_spec)
    if sig.structure in ("tt", "cp") and not op_sig.is_tn:
        raise ValueError(
            f"structured ({sig.structure!r}) execution plans exist for "
            f"tt/cp operators only; {op_sig.family!r} operators densify "
            "first (plan the resulting dense signature instead)")
    force = _force_pallas_active() if force_pallas is None else force_pallas
    key = (op_sig, sig, kind, backend, pipeline, _on_tpu(), bool(force))
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_CACHE.move_to_end(key)
        _CACHE_STATS.hits += 1
        return cached
    plan = _build_plan(op_sig, sig, kind, backend, pipeline, _on_tpu(),
                       bool(force), key)
    _CACHE_STATS.builds += 1
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
        _CACHE_STATS.evictions += 1
    return plan


# ---------------------------------------------------------------------------
# signature builders used by dispatch (operator + concrete input -> sig)
# ---------------------------------------------------------------------------

def dense_signature(op, xt) -> StructureSig:
    """Signature of a COERCED dense input `(*batch, *op.in_dims)`."""
    n = len(tuple(op.in_dims))
    return StructureSig(structure="dense",
                        batch=int(_prod(xt.shape[:-n])) if xt.ndim > n
                        else 1)


def struct_signature(op, x) -> StructureSig:
    """Signature of a structured (TT/CP-format) input, single or batched."""
    del op
    batch = int(x.batch) if isinstance(
        x, (BatchedTTTensor, BatchedCPTensor)) else 1
    return StructureSig(structure=structure_tag(x), batch=batch,
                        in_rank=struct_in_rank(x))


def sketch_signature(op, y, chunk: int | None = None) -> StructureSig:
    """Signature of a reconstruct input `(*batch, k)`."""
    del op
    return StructureSig(structure="sketch",
                        batch=int(_prod(y.shape[:-1])) if y.ndim > 1 else 1,
                        chunk=chunk)


# ---------------------------------------------------------------------------
# execution: the plan's route, run (owns every kernels import)
# ---------------------------------------------------------------------------

def execute_plan(plan: ExecutionPlan, op, x):
    """Run one planned execution. `x` is the dispatch-normalized input:
    a coerced dense array, a structured container, or a sketch array."""
    if plan.kind == "reconstruct":
        return _exec_reconstruct(plan, op, x)
    if plan.structure in ("tt", "cp"):
        return _exec_struct_project(plan, op, x)
    return _exec_dense_project(plan, op, x)


def _exec_dense_project(plan: ExecutionPlan, op, xt):
    if plan.route == "xla":
        return op.project(xt)
    from repro.kernels import ops as kops
    interpret = not _on_tpu()
    kern = kops.tt_project if plan.family == "tt" else kops.cp_project
    n = plan.order
    if xt.ndim <= n + 1:  # single input/1-D batch: native batch axis
        return kern(op, xt, interpret=interpret, pipeline=plan.pipeline)
    batch = xt.shape[:-n]
    flat = xt.reshape((-1,) + xt.shape[-n:])
    return kern(op, flat, interpret=interpret,
                pipeline=plan.pipeline).reshape(batch + (op.k,))


def _exec_struct_project(plan: ExecutionPlan, op, x):
    from repro.kernels import struct as kstruct
    if plan.route == "pallas":
        return kstruct.struct_project(op, x, interpret=not _on_tpu(),
                                      pipeline=plan.pipeline)
    return kstruct.struct_project(op, x, use_kernel=False)


def _exec_reconstruct(plan: ExecutionPlan, op, y):
    chunk = plan.chunk
    if plan.route == "pallas":
        # chunk_policy='folded': the planner's VMEM budget already tiles k
        # (plan.tiles[0]), so the requested bound is honored by the
        # kernel's own k-tiling — no dense (D, k) intermediate exists
        from repro.kernels import ops as kops
        interpret = not _on_tpu()
        kern = (kops.tt_reconstruct if plan.family == "tt"
                else kops.cp_reconstruct)
        if y.ndim <= 2:
            return kern(op, y, interpret=interpret)
        batch = y.shape[:-1]
        out = kern(op, y.reshape(-1, op.k), interpret=interpret)
        return out.reshape(batch + tuple(op.in_dims))
    if y.ndim == 1:
        return op.reconstruct(y, chunk=chunk)
    batch = y.shape[:-1]
    out = jax.vmap(lambda yy: op.reconstruct(yy, chunk=chunk))(
        y.reshape(-1, op.k))
    return out.reshape(batch + tuple(op.in_dims))


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------

def explain(op, x, *, kind: str = "project", backend: str = "auto",
            pipeline: str = "serial",
            chunk: int | None = None) -> ExecutionPlan:
    """The `ExecutionPlan` that `rp.project` / `rp.reconstruct` would
    resolve for `(op, x)` — route, kernel, tiles, the unified cost ledger,
    and the REJECTED alternatives with reasons (the dispatch matrix in
    this module's docstring, executable). Pure: nothing is executed, but
    the plan lands in the same cache the real dispatch reads, so asking
    is also prewarming.

    `x` may be anything `project` accepts (dense/flat array, (Batched)
    TT/CP container) or, for `kind='reconstruct'`, a `(*batch, k)` sketch.
    Mirrors dispatch exactly: a structured input under a flat-vector
    operator densifies, so it is explained as the dense plan it executes.
    """
    if kind == "reconstruct":
        y = jnp.asarray(x)
        return plan_execution(op, sketch_signature(op, y, chunk),
                              kind="reconstruct", backend=backend)
    if isinstance(x, (TTTensor, CPTensor, BatchedTTTensor, BatchedCPTensor)):
        op_sig = _op_signature(op)
        if op_sig.is_tn:
            return plan_execution(op, struct_signature(op, x),
                                  backend=backend, pipeline=pipeline)
        batch = (int(x.batch)
                 if isinstance(x, (BatchedTTTensor, BatchedCPTensor)) else 1)
        sig = StructureSig(structure="dense", batch=batch)
        return plan_execution(op, sig, backend=backend, pipeline=pipeline)
    from .dispatch import _coerce_dense
    xt = _coerce_dense(op, jnp.asarray(x))
    return plan_execution(op, dense_signature(op, xt), backend=backend,
                          pipeline=pipeline)


# ---------------------------------------------------------------------------
# update (fused unsketch+EF+AdamW) and collective wire ledgers
# ---------------------------------------------------------------------------

def plan_update(op_spec, batch: int, *, fused: bool = True) -> ExecutionPlan:
    """The `ExecutionPlan` of one fused unsketch+EF+AdamW launch over
    `batch` buckets (or of the UNFUSED reconstruct -> EF -> AdamW chain
    when `fused=False` — same reconstruct-sweep plan, nine extra dense
    passes in the ledger). `cost.hbm_bytes` is the analytic traffic the
    perf benches gate (`fused_hbm_bytes` / `unfused_hbm_bytes`)."""
    from repro.kernels import fused_update as kfused

    op_sig = _op_signature(op_spec)
    if not op_sig.is_tn:
        raise ValueError(
            f"plan_update needs a tt/cp operator (the fused kernel IS the "
            f"reconstruct sweep), got family {op_sig.family!r}")
    sig = StructureSig(structure="sketch", batch=int(batch))
    kind = "update" if fused else "update-unfused"
    key = (op_sig, sig, kind, "pallas", "serial", _on_tpu(), False)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_CACHE.move_to_end(key)
        _CACHE_STATS.hits += 1
        return cached
    fplan = kfused.plan_fused_update(op_sig.family, op_sig.k, int(batch),
                                     op_sig.dims, op_sig.rank)
    hbm = (kfused.fused_hbm_bytes(fplan) if fused
           else kfused.unfused_hbm_bytes(fplan))
    plan = ExecutionPlan(
        plan_id=hashlib.blake2s(repr(key).encode(),
                                digest_size=6).hexdigest(),
        family=op_sig.family, structure="sketch", kind=kind,
        order=op_sig.order, k=op_sig.k, batch=int(batch), dims=op_sig.dims,
        rank=op_sig.rank, in_rank=0, backend="pallas",
        route="pallas" if fused else "xla",
        kernel="fused_update" if fused else "unfused_chain",
        pipeline="serial", chunk=None, chunk_policy="folded",
        tiles=(fplan.tk, fplan.tb, fplan.ba), grid=fplan.grid,
        rejected=((("xla", "fused path requested: the dense gradient "
                    "estimate never touches HBM"),) if fused
                  else (("pallas", "unfused chain requested for "
                         "comparison"),)),
        cost=CostLedger(
            flops=int(batch) * int(
                theory.flops_project_dense_tt(op_sig.k, op_sig.dims,
                                              max(1, op_sig.rank))
                if op_sig.family == "tt"
                else theory.flops_project_dense_cp(op_sig.k, op_sig.dims,
                                                   max(1, op_sig.rank))),
            hbm_bytes=int(hbm), vmem_bytes=int(fplan.vmem_bytes),
            wire_bytes=0,
            params=_safe_params(op_sig.family, op_sig.k, op_sig.dims,
                                op_sig.rank),
            var_factor=_safe_var_factor(op_sig.family, op_sig.order,
                                        op_sig.rank, op_sig.dims)))
    _CACHE_STATS.builds += 1
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
        _CACHE_STATS.evictions += 1
    return plan


def collective_wire_bytes(*, sync: str, wire: str, sketch_bytes: int,
                          dense_bytes: int, n_buckets: int,
                          n_leaves: int) -> int:
    """Analytic per-step pod-link payload of the compressed all-reduce —
    the plan layer's wire ledger, which `SketchCompressor.wire_bytes`
    reads. 'sketch-mean' syncs the (nb, k) sketches, 'local-mean' the
    densified tree; int8 payloads carry their float32 scales (one per
    bucket row under 'sketch-mean', one per leaf under 'local-mean')."""
    payload = sketch_bytes if sync == "sketch-mean" else dense_bytes
    if wire == "fp32":
        return int(payload)
    scales = n_buckets if sync == "sketch-mean" else n_leaves
    return int(payload) // 4 + 4 * int(scales)


__all__ = [
    "BACKENDS", "CostLedger", "ExecutionPlan", "PlanCacheStats",
    "StructureSig", "clear_plan_cache", "collective_wire_bytes",
    "dense_signature", "execute_plan", "explain", "group_signature",
    "plan_cache_stats", "plan_execution", "plan_update", "pow2ceil",
    "sketch_signature", "struct_in_rank", "struct_signature",
    "structure_tag", "validate_backend", "validate_pipeline",
]
