"""Family registry: name -> factory(spec, key) -> RPOperator.

New projection families (e.g. the Rademacher tensor-network maps of
Rakhshan & Rabusseau 2021, or orthogonalized-core TT projections of
Feng et al. 2020 — see PAPERS.md) plug in with a single decorated factory;
every call site that goes through `make_projector` / `repro.rp.project`
picks them up without modification.
"""
from __future__ import annotations

from typing import Callable

from .protocol import ProjectorSpec, RPOperator

Factory = Callable[[ProjectorSpec, object], RPOperator]

_FAMILIES: dict[str, Factory] = {}
_ALIASES: dict[str, str] = {}


def register_family(name: str, *aliases: str) -> Callable[[Factory], Factory]:
    """Decorator registering `factory(spec, key) -> RPOperator` under `name`.

    >>> @register_family("tt")
    ... def _make_tt(spec, key):
    ...     return sample_tt_rp(key, spec.dims, spec.k, spec.rank, spec.dtype)
    """

    def deco(factory: Factory) -> Factory:
        for n in (name,) + aliases:
            if n in _FAMILIES or n in _ALIASES:
                raise ValueError(f"RP family {n!r} already registered")
        _FAMILIES[name] = factory
        for a in aliases:
            _ALIASES[a] = name
        return factory

    return deco


def list_families() -> tuple[str, ...]:
    """Canonical registered family names (aliases resolve but aren't listed)."""
    return tuple(sorted(_FAMILIES))


def get_family(name: str) -> Factory:
    try:
        return _FAMILIES[_ALIASES.get(name, name)]
    except KeyError:
        raise KeyError(
            f"unknown RP family {name!r}; registered: {list_families()}"
        ) from None


def make_projector(spec: ProjectorSpec, key) -> RPOperator:
    """Sample a projector for `spec` using PRNG `key`.

    Deterministic given (spec, key): distributed hosts regenerate the same
    operator locally from a shared key — only sketches cross the network.
    """
    return get_family(spec.family)(spec, key)
