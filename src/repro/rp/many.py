"""Mixed-structure batch projection: the serving engine's fan-out entry.

`project_many(op, inputs)` takes a heterogeneous LIST of single-item
payloads — dense tensors / flat vectors (ragged lengths, zero-padded),
`TTTensor`s (rank-ragged: interior bond ranks zero-padded, exact) and
`CPTensor`s (rank-ragged likewise) — and projects ALL of them with the
fewest possible kernel dispatches: the inputs are grouped by structure,
each group is coalesced into one batched container (`(B, prod(in_dims))`
for dense payloads, `BatchedTTTensor` / `BatchedCPTensor` for structured
ones) and fanned out to the EXISTING dispatch paths of `rp.project` — the
batched mode-sweep kernels for the dense group, the carry-sweep kernels
for the structured ones. One dispatch per non-empty structure group; a
structurally homogeneous list (what the serving batcher's lanes deliver)
is exactly ONE dispatch regardless of per-item ranks or flat lengths.

Results come back as a `(len(inputs), k)` sketch stack in input order.

Shape bucketing (`bucket=True`, the default): the coalesced batch size is
zero-padded up to a power of two (floor 8) and structured interior ranks
up to powers of two before dispatch, the padding sliced away afterwards.
Padding is EXACT (zero rows / zero rank channels contribute nothing) and
exists purely so a serving loop's per-tick shapes REPEAT: without it every
ragged (B, ranks) combination traces and compiles its own kernel — a
compile storm — while bucketed ticks hit the jit cache after the first.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import (BatchedCPTensor, BatchedTTTensor, _prod,
                                stack_ragged_cp, stack_ragged_tt)

from .dispatch import project
from .plan import pow2ceil as _pow2ceil
from .plan import structure_tag
from .protocol import FormatMismatchError, RPOperator

# The bucketed shapes this module produces are EXACTLY what
# `rp.plan.group_signature` predicts without materializing the batch: the
# coalesced group key IS the plan-cache key, so the serve engine's
# pre-planned ticks and this fan-out resolve the same cached ExecutionPlan.


def _pad_batch_tt(xb: BatchedTTTensor, b_pad: int) -> BatchedTTTensor:
    """Zero-pad batch to `b_pad` rows and interior bond ranks to powers of
    two (exact; see module docstring)."""
    rk = xb.ranks
    tgt = (rk[0],) + tuple(_pow2ceil(r) for r in rk[1:-1]) + (rk[-1],)
    cores = tuple(
        jnp.pad(c, ((0, b_pad - xb.batch), (0, tgt[n] - rk[n]), (0, 0),
                    (0, tgt[n + 1] - rk[n + 1])))
        for n, c in enumerate(xb.cores))
    return BatchedTTTensor(cores)


def _pad_batch_cp(xb: BatchedCPTensor, b_pad: int) -> BatchedCPTensor:
    """Zero-pad batch to `b_pad` rows and the component rank to a power of
    two (exact)."""
    r_pad = _pow2ceil(xb.rank)
    factors = tuple(
        jnp.pad(f, ((0, b_pad - xb.batch), (0, 0), (0, r_pad - xb.rank)))
        for f in xb.factors)
    weights = (None if xb.weights is None else jnp.pad(
        xb.weights, ((0, b_pad - xb.batch), (0, r_pad - xb.rank))))
    return BatchedCPTensor(factors, weights)


def _flat_payload(op: RPOperator, x) -> jnp.ndarray:
    """One dense payload -> a `(prod(in_dims),)` flat vector, zero-padded.

    Accepts an `in_dims`-shaped tensor, any tensorization with the right
    element count, or a 1-D flat vector no longer than prod(in_dims) —
    padding a SHORT vector is harmless under a linear map. Anything bigger
    (including an already-batched array) is a typed error: `project_many`
    is a per-request fan-out, one payload = one sketch row.
    """
    x = jnp.asarray(x)
    size = _prod(op.in_dims)
    if x.size == size:
        return x.reshape(-1)
    if x.ndim == 1 and x.size < size:
        return jnp.pad(x, (0, size - x.size))
    raise FormatMismatchError(
        f"dense payload of shape {tuple(x.shape)} is not a single input for "
        f"operator in_dims={tuple(op.in_dims)} (flat size {size}); "
        "project_many takes one payload per sketch row")


def project_many(op: RPOperator, inputs, *, backend: str = "auto",
                 bucket: bool = True) -> jnp.ndarray:
    """Project a heterogeneous list of payloads in the fewest dispatches.

    inputs : sequence of dense arrays / flat vectors / `TTTensor`s /
             `CPTensor`s (each a SINGLE item — batched containers already
             are one dispatch via `rp.project` and are rejected here).
    bucket : pad batch size / interior ranks to powers of two before
             dispatch (exact; keeps repeat-call shapes stable so jit
             caches hit — see module docstring). Disable to dispatch the
             tight ragged shapes as-is.
    Returns the `(len(inputs), k)` sketches in input order. Dispatch count
    equals the number of distinct structure groups present (<= 3), counted
    by the usual `rp.dispatch_stats()` instrumentation.
    """
    inputs = list(inputs)
    if not inputs:
        return jnp.zeros((0, op.k), jnp.float32)
    groups: dict[str, tuple[list[int], list]] = {}
    for i, x in enumerate(inputs):
        if isinstance(x, (BatchedTTTensor, BatchedCPTensor)):
            raise FormatMismatchError(
                f"project_many got a {type(x).__name__}; batched containers "
                "are already one dispatch — call rp.project directly")
        tag = structure_tag(x)
        idxs, xs = groups.setdefault(tag, ([], []))
        idxs.append(i)
        xs.append(x)
    rows: list = [None] * len(inputs)
    for tag, (idxs, xs) in groups.items():
        b_pad = _pow2ceil(len(xs), 8) if bucket else len(xs)
        if tag == "dense":
            xb = jnp.stack([_flat_payload(op, x) for x in xs])
            if b_pad > len(xs):
                xb = jnp.pad(xb, ((0, b_pad - len(xs)), (0, 0)))
        elif tag == "tt":
            xb = stack_ragged_tt(xs)
            if bucket:
                xb = _pad_batch_tt(xb, b_pad)
        else:
            xb = stack_ragged_cp(xs)
            if bucket:
                xb = _pad_batch_cp(xb, b_pad)
        y = project(op, xb, backend=backend)        # ONE dispatch per group
        for j, idx in enumerate(idxs):
            rows[idx] = y[j]
    return jnp.stack(rows)
