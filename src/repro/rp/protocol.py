"""The single projector protocol every RP family implements.

The paper compares *families* of random projections (f_TT, f_CP, dense
Gaussian, very-sparse JLT); the code therefore needs one interface that all
of them satisfy so benchmarks, tests, the sketching stack, and the
compressed all-reduce can iterate over families uniformly.

`RPOperator` is a structural protocol — existing operator classes
(`repro.core.tt_rp.TTRP`, `repro.core.cp_rp.CPRP`,
`repro.core.baselines.GaussianRP` / `VerySparseRP`) conform without
inheriting from anything here. `ProjectorSpec` is the declarative
description a registry factory turns into a sampled operator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax.numpy as jnp


class FormatMismatchError(TypeError):
    """Input structure/shape is incompatible with the operator.

    Raised by `repro.rp.project` (and friends) instead of bare asserts so
    callers can catch a typed error when routing heterogeneous inputs.
    """


@runtime_checkable
class RPOperator(Protocol):
    """Structural interface of a sampled random-projection operator.

    Attributes / methods
    --------------------
    k            : embedding dimension (number of rows of the implicit map).
    in_dims      : input mode sizes; `(D,)` for flat-vector operators,
                   `(d_1, ..., d_N)` for tensorized ones.
    num_params() : stored parameter count (the paper's memory axis).
    project(x)   : dense input `(*batch, *in_dims) -> (*batch, k)`.
    reconstruct(y, *, chunk): unbiased adjoint `(k,) -> in_dims`-shaped
                   estimate; `chunk` bounds the k-sized intermediate.
    as_dense_matrix(): materialize the `(k, prod(in_dims))` matrix
                   (small problems / tests only).
    """

    @property
    def k(self) -> int: ...

    @property
    def in_dims(self) -> tuple[int, ...]: ...

    def num_params(self) -> int: ...

    def project(self, x: jnp.ndarray) -> jnp.ndarray: ...

    def reconstruct(self, y: jnp.ndarray, *,
                    chunk: int | None = None) -> jnp.ndarray: ...

    def as_dense_matrix(self) -> jnp.ndarray: ...


@dataclasses.dataclass(frozen=True)
class ProjectorSpec:
    """Declarative description of a projector; `make_projector` samples it.

    family  : registered family name ('tt', 'cp', 'gaussian', 'sparse', ...).
    k       : embedding dimension.
    dims    : input mode sizes. Flat-vector families contract over
              prod(dims), so a tensorized `dims` is valid for every family.
    rank    : structural rank R (ignored by unstructured families).
    dtype   : parameter dtype.
    backend : preferred execution backend for dense-input projections,
              'auto' | 'pallas' | 'xla' (see `repro.rp.project`).
    """

    family: str
    k: int
    dims: tuple[int, ...]
    rank: int = 2
    dtype: Any = jnp.float32
    backend: str = "auto"

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(int(d) for d in self.dims))
        if self.k <= 0:
            raise ValueError(f"k must be positive, got {self.k}")
        # local import: plan.py imports ProjectorSpec from this module
        from .plan import validate_backend
        validate_backend(self.backend)

    @property
    def input_size(self) -> int:
        out = 1
        for d in self.dims:
            out *= d
        return out

    def to_dict(self) -> dict:
        """JSON-able description (a cache/checkpoint manifest entry).

        Round-trips through `from_dict`: the operator a spec describes is
        fully determined by these fields plus a seed, so a manifest of
        spec dicts IS a registry of operators — no weights serialized.
        """
        return {"family": self.family, "k": self.k,
                "dims": list(self.dims), "rank": self.rank,
                "dtype": jnp.dtype(self.dtype).name, "backend": self.backend}

    @classmethod
    def from_dict(cls, d: dict) -> "ProjectorSpec":
        """Inverse of `to_dict`; equal (==, hash) to the original spec."""
        try:
            # jnp.float32 etc., not np.dtype instances — ProjectorSpec
            # equality (and so cache keys) must match specs built in code
            dtype = jnp.dtype(d["dtype"]).type
        except TypeError as e:
            raise ValueError(
                f"unknown dtype {d.get('dtype')!r} in spec dict") from e
        return cls(family=d["family"], k=int(d["k"]),
                   dims=tuple(int(x) for x in d["dims"]),
                   rank=int(d.get("rank", 2)), dtype=dtype,
                   backend=d.get("backend", "auto"))

    @classmethod
    def for_flat(cls, family: str, size: int, k: int, *, rank: int = 2,
                 dtype: Any = jnp.float32, backend: str = "auto",
                 max_order: int = 4, align: int = 128) -> "ProjectorSpec":
        """Spec for a flat vector of `size` elements, auto-tensorized.

        Picks MXU-friendly dims via `formats.auto_dims` (padding the size up
        to the lane width first); `repro.rp.project` zero-pads short flat
        inputs to prod(dims), which leaves the projection of the embedded
        vector unchanged (the map is linear).
        """
        import math

        from repro.core.formats import auto_dims

        padded = int(math.ceil(size / align) * align)
        dims = auto_dims(padded, max_order=max_order, align=align)
        return cls(family=family, k=k, dims=dims, rank=rank, dtype=dtype,
                   backend=backend)
