"""Built-in projector families: the paper's two maps and its two baselines.

family      operator     params (theory.*)        structured fast paths
------      --------     ------------------       ---------------------
'tt'        TTRP         O(k N d R^2)             TT, CP inputs
'cp'        CPRP         O(k N d R)               TT, CP inputs
'gaussian'  GaussianRP   k * D                    — (flat; streamed blocks)
'sparse'    VerySparseRP ~ k * D / sqrt(D)        — (flat; streamed blocks)
"""
from __future__ import annotations

from repro.core.baselines import GaussianRP, VerySparseRP
from repro.core.cp_rp import sample_cp_rp
from repro.core.tt_rp import sample_tt_rp

from .protocol import ProjectorSpec
from .registry import register_family


@register_family("tt")
def _make_tt(spec: ProjectorSpec, key):
    return sample_tt_rp(key, spec.dims, spec.k, spec.rank, dtype=spec.dtype)


@register_family("cp")
def _make_cp(spec: ProjectorSpec, key):
    return sample_cp_rp(key, spec.dims, spec.k, spec.rank, dtype=spec.dtype)


@register_family("gaussian", "dense")
def _make_gaussian(spec: ProjectorSpec, key):
    return GaussianRP(key=key, k=spec.k, dim=spec.input_size)


@register_family("sparse", "verysparse")
def _make_sparse(spec: ProjectorSpec, key):
    return VerySparseRP(key=key, k=spec.k, dim=spec.input_size)
