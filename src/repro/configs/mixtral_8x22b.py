"""mixtral-8x22b [moe] — 8 experts top-2, SWA (arXiv:2401.04088; hf).
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768. Follows the ASSIGNED
spec (SWA on, window 4096) — the sliding window bounds the decode cache, so
long_500k runs with a 4096-slot ring buffer."""
from repro.models.config import ArchConfig, MoESpec, lm_shapes

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="decoder",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768, rope_theta=1_000_000.0,
    window_pattern=(4096,),
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=16384),
    shapes=lm_shapes(long_ok=True),
)
