"""Architecture registry: the 10 assigned archs + paper-experiment configs.

`get_config(name)` -> full ArchConfig;  `reduced(cfg)` -> CPU-smoke variant
of the same family (small widths/layers/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, MoESpec, ShapeSpec

from . import (arctic_480b, deepseek_67b, gemma2_9b, llama32_3b,
               mamba2_13b, mixtral_8x22b, qwen15_110b, qwen2_vl_2b,
               recurrentgemma_2b, whisper_medium)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        deepseek_67b, qwen15_110b, gemma2_9b, llama32_3b, arctic_480b,
        mixtral_8x22b, whisper_medium, recurrentgemma_2b, qwen2_vl_2b,
        mamba2_13b,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


SMOKE_SHAPES = (
    ShapeSpec("smoke_train", 32, 2, "train"),
    ShapeSpec("smoke_decode", 64, 2, "decode"),
)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, num_experts=4, top_k=2, d_ff_expert=64,
            dense_residual_ff=64 if moe.dense_residual_ff else None,
            capacity_factor=4.0)
    n_layers = 3 if cfg.family == "hybrid" else 2
    if cfg.family == "hybrid":
        n_layers = 4  # one scanned (rec,rec,attn) group + 1 tail rec layer
    window = tuple((8 if w is not None else None) for w in cfg.window_pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else None,
        d_ff=96 if cfg.d_ff else 0,
        vocab=256,
        moe=moe,
        window_pattern=window,
        rnn_width=64 if cfg.rnn_width else None,
        ssm_state=16 if cfg.ssm_state else None,
        ssm_head_dim=16,
        ssm_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else None,
        encoder_seq=12 if cfg.encoder_seq else None,
        num_patches=4,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
        shapes=SMOKE_SHAPES,
    )
