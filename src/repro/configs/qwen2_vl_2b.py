"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191; hf).
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. Vision tower is a
STUB: input_specs feeds precomputed patch embeddings scattered into the
token stream; M-RoPE sections (16, 24, 24) over hd=128."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="decoder",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), num_patches=256, frontend="vision",
    tie_embeddings=True,
    shapes=lm_shapes(long_ok=False),
)
