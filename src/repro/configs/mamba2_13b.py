"""mamba2-1.3b [ssm] — SSD, attention-free (arXiv:2405.21060; unverified).
48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128, head_dim=64, expand=2.
O(1)-state decode -> runs long_500k."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, conv_width=4, tie_embeddings=True,
    shapes=lm_shapes(long_ok=True),
)
