"""arctic-480b [moe] — 128 experts top-2 + dense residual
(hf:Snowflake/snowflake-arctic-base; hf). 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000. Dense-MoE hybrid: per layer, dense FFN(4864) and the
top-2-of-128 MoE both feed the residual stream. 'lean' bf16 policy on the
single-pod mesh (see DESIGN.md memory notes)."""
from repro.models.config import ArchConfig, MoESpec, lm_shapes

CONFIG = ArchConfig(
    name="arctic-480b", family="decoder",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000, rope_theta=10000.0,
    moe=MoESpec(num_experts=128, top_k=2, d_ff_expert=4864,
                dense_residual_ff=4864),
    policy="lean",
    shapes=lm_shapes(long_ok=False),
)
