"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 (arXiv:2402.19427;
hf). 26L d_model=2560 10H (MQA kv=1, hd=256) d_ff=7680 vocab=256000;
rnn width 2560; local window 2048; pattern (rec, rec, attn); GeGLU.
O(1)-state decode -> runs long_500k."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, mlp="geglu", rnn_width=2560, conv_width=4,
    window_pattern=(2048,), block_pattern=("rec", "rec", "attn"),
    embed_scale=True, tie_embeddings=True,
    shapes=lm_shapes(long_ok=True),
)
