"""gemma2-9b [dense] — local+global alternating, logit softcaps
(arXiv:2408.00118; hf). 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; hd=256; attn softcap 50, final softcap 30; pre+post RMSNorm;
GeGLU; (1+w) norm offset; sqrt(D) embed scaling; tied embeddings."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="gemma2-9b", family="decoder",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000, mlp="geglu", rope_theta=10000.0,
    attn_softcap=50.0, final_softcap=30.0, post_norm=True,
    norm_offset=1.0, embed_scale=True, tie_embeddings=True,
    window_pattern=(4096, None),
    shapes=lm_shapes(long_ok=False, reason="alternating local/global — "
                     "global layers need the full 512k cache; see DESIGN.md"),
)
