"""qwen1.5-110b [dense] — QKV bias (hf:Qwen/Qwen1.5-0.5B; hf).
80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="decoder",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    shapes=lm_shapes(long_ok=False),
)
