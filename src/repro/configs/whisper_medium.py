"""whisper-medium [audio] — enc-dec, conv frontend STUB (arXiv:2212.04356;
unverified). 24L(+24 enc) d_model=1024 16H (kv=16 -> MHA) d_ff=4096
vocab=51865; encoder consumes 1500 precomputed frame embeddings."""
from repro.models.config import ArchConfig, lm_shapes

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, encoder_layers=24, encoder_seq=1500,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865, mlp="gelu", norm="ln", frontend="audio",
    tie_embeddings=True,
    shapes=lm_shapes(long_ok=False, reason="full-attention enc-dec decoder; "
                     "512k decoder context infeasible; see DESIGN.md"),
)
