"""repro.obs — the unified telemetry layer (spans + metrics + distortion).

Zero-dependency, OFF by default, and safe to leave wired into every hot
path: when disabled, `obs.span(...)` returns a shared no-op context and
`obs.counter/gauge/histogram/event` return inert singletons — the cost is
one module-global read per call, which the gated `obs/overhead` bench row
pins at <= 5% of a reference kernel dispatch.

    from repro import obs

    ctx = obs.enable()                       # Tracer + MetricsRegistry
    ...run a serve replay / train steps...
    ctx.tracer.export("trace.json")          # open in ui.perfetto.dev
    ctx.metrics.write_jsonl("metrics.jsonl")
    obs.disable()

or the one-shot form (used by launch/serve_rp.py --trace-out):

    with obs.capture(trace_path="trace.json",
                     metrics_path="metrics.jsonl") as ctx:
        ...

State is a MODULE GLOBAL, not a contextvar: background threads (the async
checkpoint writer, batcher worker pools) must land their spans in the SAME
trace as the main thread — Perfetto renders them as separate tracks of one
timeline. Span NESTING stays context-local inside `Tracer`, so threads
cannot corrupt each other's span stacks. Tests that need isolation wrap
their body in enable()/disable() (conftest runs tests single-threaded per
module, matching the rest of the context-local instrumentation in
`rp.dispatch_stats`).

Wired call sites (all behind the disabled fast path):
  rp.dispatch        — per-dispatch spans tagged (family, structure,
                       order, backend, pipeline) + the launch breakdown
  serve.engine       — per-tick spans, queue-delay histograms, distortion
                       feed for dense payloads
  runtime.train_loop — per-step spans; straggler/resume/fallback events
  ckpt.checkpointer  — save/verify/restore spans (async saves on their
                       own thread track) + fallback events
  optim.compress     — collective wire-byte gauges/counters (trace-time)
"""
from __future__ import annotations

import contextlib
import dataclasses

from .distortion import DistortionAlert, DistortionMonitor, required_k
from .metrics import (LATENCY_BOUNDS_US, Counter, Gauge, Histogram,
                      MetricsRegistry, read_jsonl)
from .trace import SpanHandle, Tracer

__all__ = [
    "Counter", "DistortionAlert", "DistortionMonitor", "Gauge", "Histogram",
    "LATENCY_BOUNDS_US", "MetricsRegistry", "ObsContext", "SpanHandle",
    "Tracer", "capture", "counter", "disable", "enable", "enabled", "event",
    "gauge", "get_context", "get_distortion", "get_metrics", "get_tracer",
    "histogram", "instant", "read_jsonl", "required_k", "span",
]


@dataclasses.dataclass
class ObsContext:
    """One enabled telemetry session: tracer + metrics (+ distortion)."""

    tracer: Tracer
    metrics: MetricsRegistry
    distortion: DistortionMonitor | None = None


# The enabled session, or None. Read on every obs.* call — keep it a plain
# module global so the disabled fast path is one LOAD_GLOBAL + is-check.
_STATE: ObsContext | None = None


class _NoopSpan:
    """Shared inert span: context manager + SpanHandle surface, no state."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


class _NoopInstrument:
    """Shared inert counter/gauge/histogram."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NOOP_SPAN = _NoopSpan()
_NOOP_INSTRUMENT = _NoopInstrument()


def enable(*, tracer: Tracer | None = None,
           metrics: MetricsRegistry | None = None,
           distortion: DistortionMonitor | None = None) -> ObsContext:
    """Install (and return) the process-wide telemetry session.

    A `DistortionMonitor` passed here gets its alerts mirrored into the
    metrics event log and the trace (as instants) automatically. Calling
    `enable` while already enabled replaces the session — the old context
    object stays valid for export.
    """
    global _STATE
    ctx = ObsContext(tracer=tracer or Tracer(),
                     metrics=metrics or MetricsRegistry(),
                     distortion=distortion)
    if distortion is not None and distortion.on_alert is None:
        def _on_alert(alert, ctx=ctx):
            ev = alert.as_event()
            name = ev.pop("name")
            ctx.metrics.event(name, **ev)
            ctx.tracer.instant(name, **ev)
        distortion.on_alert = _on_alert
    _STATE = ctx
    return ctx


def disable() -> ObsContext | None:
    """Tear down the session; returns it so callers can still export."""
    global _STATE
    ctx, _STATE = _STATE, None
    return ctx


def enabled() -> bool:
    return _STATE is not None


def get_context() -> ObsContext | None:
    return _STATE


def get_tracer() -> Tracer | None:
    s = _STATE
    return s.tracer if s is not None else None


def get_metrics() -> MetricsRegistry | None:
    s = _STATE
    return s.metrics if s is not None else None


def get_distortion() -> DistortionMonitor | None:
    s = _STATE
    return s.distortion if s is not None else None


# -- the hot-path entry points (no-ops when disabled) ---------------------

def span(name: str, **attrs):
    """A tracer span scope, or the shared no-op when telemetry is off."""
    s = _STATE
    if s is None:
        return _NOOP_SPAN
    return s.tracer.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    s = _STATE
    if s is not None:
        s.tracer.instant(name, **attrs)


def event(name: str, **attrs) -> None:
    """A structured event: metrics event log + trace instant, both."""
    s = _STATE
    if s is not None:
        s.metrics.event(name, **attrs)
        s.tracer.instant(name, **attrs)


def counter(name: str):
    s = _STATE
    return _NOOP_INSTRUMENT if s is None else s.metrics.counter(name)


def gauge(name: str):
    s = _STATE
    return _NOOP_INSTRUMENT if s is None else s.metrics.gauge(name)


def histogram(name: str, bounds=LATENCY_BOUNDS_US):
    s = _STATE
    return (_NOOP_INSTRUMENT if s is None
            else s.metrics.histogram(name, bounds))


@contextlib.contextmanager
def capture(*, trace_path=None, metrics_path=None,
            distortion: DistortionMonitor | None = None):
    """enable() for a scope; export to the given paths on clean exit."""
    ctx = enable(distortion=distortion)
    try:
        yield ctx
    finally:
        disable()
        if trace_path is not None and ctx.tracer.open_spans() == 0:
            ctx.tracer.export(trace_path)
        if metrics_path is not None:
            ctx.metrics.write_jsonl(metrics_path)
