"""Nested-span tracer with Chrome/Perfetto trace-event export.

`Tracer` records complete spans ("ph": "X") and instant events ("ph": "i")
on a monotonic microsecond clock. Nesting is CONTEXT-LOCAL: the open-span
stack lives in a `contextvars.ContextVar`, so threads (which start from the
default context) each get their own stack and cannot corrupt each other's
nesting, while the recorded event list is a single lock-protected buffer —
spans from a background thread (e.g. the `AsyncCheckpointer` writer) land
in the SAME trace on their own `tid` lane, sharing one timeline with the
caller's spans. That is exactly what the Perfetto UI renders: one process
row, one track per thread.

Every span also enters `jax.named_scope` and (on non-trivial backends)
`jax.profiler.TraceAnnotation`, so a device profile captured around the
same region lines up name-for-name with the host spans exported here.

Export misuse is a typed `ValueError` that survives ``python -O``:
exporting while spans are still open would emit a trace whose durations
lie, so `export`/`to_chrome` refuse until every span has exited.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Any

# Open-span depth stack, context-local: a fresh thread/context starts at
# depth 0 with no parent, matching Perfetto's per-track nesting model.
_SPAN_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_obs_span_stack", default=())


def _now_us() -> float:
    return time.monotonic_ns() / 1e3


def _jsonable(v: Any):
    """Coerce an attribute value to something json.dumps accepts."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return str(v)


class SpanHandle:
    """The object a `Tracer.span(...)` scope yields.

    `set(**attrs)` adds/overrides attributes after the span opened — used
    by call sites that only learn a tag mid-region (e.g. the resolved
    dispatch route). Attributes land in the Chrome event's `args`.
    """

    __slots__ = ("name", "attrs", "t0", "depth")

    def __init__(self, name: str, attrs: dict, t0: float, depth: int):
        self.name = name
        self.attrs = attrs
        self.t0 = t0
        self.depth = depth

    def set(self, **attrs) -> "SpanHandle":
        self.attrs.update(attrs)
        return self


class Tracer:
    """Thread-safe span/instant recorder with Chrome trace-event export."""

    def __init__(self, *, pid: int | None = None):
        self.pid = os.getpid() if pid is None else pid
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._open = 0          # spans entered but not yet exited (global)

    # -- recording -------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record one complete span around the with-body.

        Nesting depth comes from the context-local stack; the body also
        runs under `jax.named_scope(name)` (and `TraceAnnotation` when the
        profiler supports it) so device-side profiles align with this span.
        """
        stack = _SPAN_STACK.get()
        handle = SpanHandle(name, dict(attrs), _now_us(), len(stack))
        token = _SPAN_STACK.set(stack + (name,))
        with self._lock:
            self._open += 1
        tid = threading.get_ident()
        try:
            with _device_scope(name):
                yield handle
        finally:
            t1 = _now_us()
            _SPAN_STACK.reset(token)
            ev = {"name": handle.name, "ph": "X", "ts": handle.t0,
                  "dur": max(0.0, t1 - handle.t0), "pid": self.pid,
                  "tid": tid,
                  "args": {k: _jsonable(v) for k, v in handle.attrs.items()}}
            if handle.depth:
                ev["args"]["depth"] = handle.depth
            with self._lock:
                self._events.append(ev)
                self._open -= 1

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration marker (straggler, alert, fallback...)."""
        ev = {"name": name, "ph": "i", "ts": _now_us(), "s": "t",
              "pid": self.pid, "tid": threading.get_ident(),
              "args": {k: _jsonable(v) for k, v in attrs.items()}}
        with self._lock:
            self._events.append(ev)

    # -- introspection ---------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> list[dict]:
        """A snapshot copy of the recorded events (chronological append
        order; spans append at EXIT, instants at their timestamp)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def open_spans(self) -> int:
        with self._lock:
            return self._open

    # -- export ----------------------------------------------------------
    def to_chrome(self) -> dict:
        """The trace as a Chrome/Perfetto trace-event JSON object.

        Raises a typed `ValueError` (never a bare assert — must fire under
        ``python -O``) when spans are still open: their durations do not
        exist yet and exporting would silently drop or misreport them.
        """
        with self._lock:
            if self._open:
                raise ValueError(
                    f"cannot export a trace with {self._open} unclosed "
                    "span(s): exit every tracer.span(...) scope first")
            events = [dict(e) for e in self._events]
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path) -> int:
        """Write the Chrome trace JSON to `path`; returns #events."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return len(doc["traceEvents"])

    def clear(self) -> None:
        with self._lock:
            if self._open:
                raise ValueError(
                    f"cannot clear a trace with {self._open} unclosed "
                    "span(s)")
            self._events.clear()


@contextlib.contextmanager
def _device_scope(name: str):
    """jax.named_scope + TraceAnnotation around a span body.

    Both are best-effort: named_scope only affects code that is tracing
    jaxprs, TraceAnnotation only shows up when the jax profiler is
    capturing. Neither may break the span on an exotic backend.
    """
    import jax

    with contextlib.ExitStack() as es:
        try:
            es.enter_context(jax.named_scope(name))
        except Exception:       # pragma: no cover - defensive
            pass
        try:
            es.enter_context(jax.profiler.TraceAnnotation(name))
        except Exception:       # pragma: no cover - defensive
            pass
        yield
