"""Streaming check of the quantity the paper actually guarantees.

Theorem 1 (Tensorized Random Projections) bounds the variance of the
sketch's squared-norm estimate: for a unit vector x,
Var[‖Sx‖²] = c/k with c the family's variance factor
(`core.theory.variance_factor` — TT: 3(1+2/R)^(N-1) - 1,
CP: 3^(N-1)(1+2/R) - 1). Chebyshev then gives the distortion interval:

    P(|‖Sx‖²/‖x‖² - 1| > eps) <= c / (k · eps²) <= delta
                                  whenever k >= c / (delta · eps²).

`DistortionMonitor` watches that guarantee EMPIRICALLY: callers declare a
fixed quality target (eps, delta) once, stream per-sketch distortions
‖Sx‖²/‖x‖² grouped per (family, order, k), and the monitor raises a typed
alert event as soon as a group's observed out-of-interval rate exceeds
delta (after `min_samples`, so one unlucky sketch can't page anyone). At
the paper-prescribed k (>= c/(delta·eps²)) the alert provably stays
silent up to sampling noise; an under-sized k inflates the variance past
the target and the out-rate crosses delta — which is exactly the
misconfiguration this monitor exists to catch in production, where nothing
else in the serving/training path ever looks at distortion.

The target eps is deliberately NOT derived from each group's own k: the
self-derived interval sqrt(c/(k·delta)) widens as k shrinks and would
never flag an under-provisioned sketch. Fixed target, per-group verdict.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core import theory


@dataclasses.dataclass(frozen=True)
class DistortionAlert:
    """Typed alert payload: one (family, order, k) group crossed delta."""

    family: str
    order: int
    k: int
    n: int                   # samples seen when the alert fired
    out_rate: float          # observed P(|distortion - 1| > eps)
    eps: float               # the fixed target interval half-width
    delta: float             # the target out-rate the group exceeded
    k_required: int          # paper-prescribed k for (eps, delta)

    def as_event(self) -> dict:
        d = dataclasses.asdict(self)
        d["name"] = "distortion.alert"
        return d


@dataclasses.dataclass
class _Group:
    n: int = 0
    out: int = 0
    sum: float = 0.0         # running mean of the distortion, for reports
    alerted: bool = False


def required_k(family: str, order: int, *, rank: int, eps: float,
               delta: float) -> int:
    """Paper-prescribed sketch size: the smallest k with c/(k·eps²) <= delta."""
    if eps <= 0.0:
        raise ValueError(f"eps must be positive, got {eps}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    c = theory.variance_factor(family, N=order, R=rank)
    return math.ceil(c / (delta * eps * eps))


class DistortionMonitor:
    """Streams empirical distortion against a fixed (eps, delta) target.

    `observe(family, order, k, distortion)` ingests one sketch's
    ‖Sx‖²/‖x‖²; `observe_norms` computes it from the two squared norms.
    When a (family, order, k) group has seen >= `min_samples` samples and
    its out-of-interval rate exceeds `delta`, a `DistortionAlert` is
    recorded (once per group — a stuck config should not page every
    sketch) and `on_alert` is invoked with it. `repro.obs.enable()` wires
    `on_alert` to the metrics event log + a trace instant by default.
    """

    def __init__(self, eps: float, delta: float, *, min_samples: int = 64,
                 on_alert: Callable[[DistortionAlert], None] | None = None):
        if not eps > 0.0:
            raise ValueError(
                f"distortion target eps must be > 0, got {eps}")
        if not 0.0 < delta < 1.0:
            raise ValueError(
                f"distortion target delta must be in (0, 1), got {delta}")
        if min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {min_samples}")
        self.eps = float(eps)
        self.delta = float(delta)
        self.min_samples = int(min_samples)
        self.on_alert = on_alert
        self.groups: dict[tuple[str, int, int], _Group] = {}
        self.alerts: list[DistortionAlert] = []

    # -- ingestion -------------------------------------------------------
    def observe(self, family: str, order: int, k: int, distortion: float,
                *, rank: int = 2) -> DistortionAlert | None:
        """Ingest one sketch's distortion ‖Sx‖²/‖x‖² for its group.

        Returns the alert iff THIS observation crossed the threshold.
        `rank` only feeds the alert's `k_required` diagnostic (unknown
        families fall back to a Gaussian variance factor there).
        """
        if int(k) <= 0:
            raise ValueError(f"sketch size k must be positive, got {k}")
        g = self.groups.setdefault((family, int(order), int(k)), _Group())
        d = float(distortion)
        g.n += 1
        g.sum += d
        if abs(d - 1.0) > self.eps:
            g.out += 1
        if g.alerted or g.n < self.min_samples:
            return None
        rate = g.out / g.n
        if rate <= self.delta:
            return None
        g.alerted = True
        try:
            k_req = required_k(family, order, rank=rank, eps=self.eps,
                               delta=self.delta)
        except (KeyError, ValueError):
            k_req = required_k("gaussian", order, rank=rank, eps=self.eps,
                               delta=self.delta)
        alert = DistortionAlert(family=family, order=int(order), k=int(k),
                                n=g.n, out_rate=rate, eps=self.eps,
                                delta=self.delta, k_required=k_req)
        self.alerts.append(alert)
        if self.on_alert is not None:
            self.on_alert(alert)
        return alert

    def observe_norms(self, family: str, order: int, k: int,
                      x_norm2: float, y_norm2: float, *,
                      rank: int = 2) -> DistortionAlert | None:
        """Ingest from squared norms; zero-norm inputs are skipped (their
        distortion is undefined, not out-of-interval)."""
        x2 = float(x_norm2)
        if x2 <= 0.0:
            return None
        return self.observe(family, order, k, float(y_norm2) / x2, rank=rank)

    # -- reporting -------------------------------------------------------
    def summary(self) -> list[dict]:
        """Per-group report rows (the obs_report CLI renders these)."""
        rows = []
        for (family, order, k), g in sorted(self.groups.items()):
            rows.append({
                "family": family, "order": order, "k": k, "n": g.n,
                "mean_distortion": g.sum / g.n if g.n else 0.0,
                "out_rate": g.out / g.n if g.n else 0.0,
                "eps": self.eps, "delta": self.delta,
                "alerted": g.alerted,
            })
        return rows
