"""Counters, gauges, fixed-bucket histograms, and an event log — the
process-local metrics half of `repro.obs`.

Design constraints, in order:

  * zero dependencies beyond numpy (and numpy only in tests' reference
    math — the registry itself is pure Python);
  * MERGEABLE across processes: a histogram is (bounds, per-bucket counts,
    sum, count) — two histograms with identical bounds add bucket-wise, so
    per-host JSONL snapshots can be folded into one fleet view without the
    raw samples;
  * misuse raises typed ValueErrors that survive ``python -O`` (negative
    or non-ascending bucket bounds, merging mismatched bounds, re-creating
    a name as a different instrument type) — never bare asserts.

Percentiles come from the buckets: `Histogram.percentile(p)` linearly
interpolates inside the bucket holding the p-th sample, which is exact to
within one bucket width — the standard fixed-bucket tradeoff (Prometheus
histograms make the same one).
"""
from __future__ import annotations

import json
import threading
import time


class Counter:
    """Monotonic counter. `inc(n)` with n >= 0; `.value` reads it."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} is monotonic: inc({n}) is negative "
                "(use a gauge for values that go down)")
        self.value += n

    def merge(self, other: "Counter") -> None:
        self.value += other.value

    def snapshot(self) -> dict:
        return {"type": "counter", "name": self.name, "value": self.value}


class Gauge:
    """Last-write-wins value (e.g. wire bytes per step of the active
    config). `set(v)`; `.value` reads it."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def merge(self, other: "Gauge") -> None:
        self.value = other.value    # last write wins across a merge too

    def snapshot(self) -> dict:
        return {"type": "gauge", "name": self.name, "value": self.value}


class Histogram:
    """Fixed-bound bucket histogram with interpolated percentiles.

    `bounds` are the strictly-ascending POSITIVE upper edges of the finite
    buckets; one overflow bucket catches everything past the last edge.
    Bucket i (i < len(bounds)) holds samples in (lower_i, bounds[i]] with
    lower_0 = 0. Negative samples are clamped into the first bucket (the
    instruments here measure durations and byte counts, which cannot be
    negative — a clamp beats crashing a hot path on clock skew).
    """

    __slots__ = ("name", "bounds", "counts", "sum", "count")

    def __init__(self, name: str, bounds):
        bounds = tuple(float(b) for b in bounds)
        if not bounds:
            raise ValueError(
                f"histogram {name!r} needs at least one bucket bound")
        if any(b <= 0 for b in bounds):
            raise ValueError(
                f"histogram {name!r} bounds must be positive, got {bounds} "
                "(durations/bytes are non-negative; a 0 or negative edge "
                "would create an unreachable bucket)")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name!r} bounds must be strictly ascending, "
                f"got {bounds}")
        self.name = name
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # +1: overflow bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def percentile(self, p: float) -> float:
        """The p-th percentile (0 <= p <= 100), bucket-interpolated.

        Exact to within one bucket width; the overflow bucket reports its
        lower edge (the last finite bound) — a deliberate UNDER-estimate,
        the same convention Prometheus uses for +Inf.
        """
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile p must be in [0, 100], got {p}")
        if self.count == 0:
            return 0.0
        target = p / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                if i >= len(self.bounds):       # overflow: report the edge
                    return self.bounds[-1]
                hi = self.bounds[i]
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram in (cross-process aggregation)."""
        if tuple(other.bounds) != self.bounds:
            raise ValueError(
                f"cannot merge histogram {self.name!r}: bucket bounds "
                f"differ ({other.bounds} != {self.bounds}); mergeability "
                "requires identical fixed bounds on every process")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def snapshot(self) -> dict:
        return {"type": "histogram", "name": self.name,
                "bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "p50": self.percentile(50), "p99": self.percentile(99)}


# Default latency buckets (us): ~log-spaced 10us .. 10s.
LATENCY_BOUNDS_US = (10.0, 30.0, 100.0, 300.0, 1_000.0, 3_000.0, 10_000.0,
                     30_000.0, 100_000.0, 300_000.0, 1_000_000.0,
                     3_000_000.0, 10_000_000.0)


class MetricsRegistry:
    """Get-or-create registry of named instruments plus an event log.

    Thread-safe; instrument lookups take the lock, the returned instrument
    objects are then mutated without it (additions of Python floats/ints —
    atomic enough for telemetry; the registry is not a database).
    `event(name, **attrs)` appends a timestamped record to the event log —
    the structured form of what used to be bare log strings (stragglers,
    resume/fallback, distortion alerts).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}
        self.events: list[dict] = []

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, *args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds=LATENCY_BOUNDS_US) -> Histogram:
        h = self._get(name, Histogram, bounds)
        if tuple(float(b) for b in bounds) != h.bounds:
            raise ValueError(
                f"histogram {name!r} already registered with bounds "
                f"{h.bounds}; re-registering with different bounds would "
                "silently split one metric into incompatible series")
        return h

    def event(self, name: str, **attrs) -> dict:
        ev = {"type": "event", "name": name, "time": time.time(), **attrs}
        with self._lock:
            self.events.append(ev)
        return ev

    def instruments(self) -> list[object]:
        with self._lock:
            return list(self._instruments.values())

    def snapshot(self) -> list[dict]:
        """All instruments + events as JSON-able records (JSONL rows)."""
        rows = [inst.snapshot() for inst in self.instruments()]
        with self._lock:
            rows.extend(dict(e) for e in self.events)
        return rows

    def write_jsonl(self, path) -> int:
        """One JSON object per line; returns the number of rows written."""
        rows = self.snapshot()
        with open(path, "w") as f:
            for row in rows:
                f.write(json.dumps(row, sort_keys=True) + "\n")
        return len(rows)

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in: counters/histograms add, gauges take
        the other's value, events concatenate. Cross-process aggregation
        of per-host snapshots."""
        for inst in other.instruments():
            mine = self._get(inst.name, type(inst),
                             *((inst.bounds,) if isinstance(inst, Histogram)
                               else ()))
            mine.merge(inst)
        with other._lock:
            evs = [dict(e) for e in other.events]
        with self._lock:
            self.events.extend(evs)


def read_jsonl(path) -> list[dict]:
    """Parse a `write_jsonl` file back into records (the report CLI and
    the CI schema check both go through this)."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
