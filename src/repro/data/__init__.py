from .pipeline import DataConfig, SyntheticLM
from .tokenizer import ByteTokenizer

__all__ = ["ByteTokenizer", "DataConfig", "SyntheticLM"]
