"""Byte-level tokenizer stub (real deployments plug a sentencepiece model in
behind the same interface)."""
from __future__ import annotations

import numpy as np


class ByteTokenizer:
    vocab_size = 256 + 2
    bos = 256
    eos = 257

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        ids = [int(i) for i in ids if int(i) < 256]
        return bytes(ids).decode("utf-8", errors="replace")
