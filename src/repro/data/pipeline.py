"""Deterministic, resumable, host-shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard), so:
  * crash-restart resumes exactly (fast-forward = set the step counter),
  * multi-host training shards by host id with no coordination,
  * elastic re-sharding (different host count after restart) reproduces the
    same global token stream.

The synthetic stream is a mixture of Zipf-distributed unigrams and short
Markov motifs — enough structure that a language model's loss decreases, so
convergence tests (e.g. compressed vs uncompressed grad parity) mean
something.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed motif table (the learnable structure)
        self.motifs = root.integers(0, v, size=(cfg.n_motifs, cfg.motif_len))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.unigram = p / p.sum()

    def batch(self, step: int, *, shard: int = 0, num_shards: int = 1):
        """Returns {'tokens', 'labels'} for this host's slice of the batch."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_local = cfg.global_batch // num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + shard)
        toks = rng.choice(cfg.vocab, size=(b_local, cfg.seq_len + 1),
                          p=self.unigram).astype(np.int32)
        # overwrite random spans with motifs (predictable structure)
        n_spans = cfg.seq_len // (cfg.motif_len * 4)
        for i in range(b_local):
            for _ in range(max(1, n_spans)):
                m = rng.integers(0, cfg.n_motifs)
                pos = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[i, pos:pos + cfg.motif_len] = self.motifs[m]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
