"""Contraction planner + jit'd public wrappers around the Pallas kernels.

The planner (`plan_contraction` -> `ContractionPlan`) is the single source
of truth for the order-N mode-sweep schedule: for a static order N it emits
the einsum program of the sweep (one contraction per mode, rank carried
between steps), the VMEM-budgeted tiles `(tk, tb, ba)`, and the grid — and
the family-specific kernel modules (`tt_sweep.py` / `cp_sweep.py`) execute
exactly that program inside a `pallas_call` that preserves the batched
order-3 schedule the plan generalizes: k-tile outermost for `project` (cores
stay VMEM-resident across the batch), k-tile innermost for `reconstruct`
(partial sums accumulate in the revisited output block), batch grid axis,
and the JLT 1/sqrt(k) scaling FUSED into the kernel epilogue.

The wrappers (`tt_project` / `cp_project` and the adjoints `tt_reconstruct`
/ `cp_reconstruct`) handle batch/mode/k padding and layout conversion from
the repro.core operator containers for ANY order N >= 2; order-1 operators
(classical Gaussian RP) fall back to the jnp reference path. Each accepts a
single input (`(*dims)` tensor / `(k,)` sketch) or a batch (`(B, *dims)` /
`(B, k)`); the batch runs in ONE kernel launch with a native batch grid
axis — this is how `PytreeSketcher` sketches all buckets of a leaf per
launch.

Structured (TT/CP-format) inputs do NOT pass through here: they route to
the compressed-domain carry-sweep subsystem in `repro.kernels.struct`
(which has its own planner mirroring this one's conventions).

`interpret` defaults to True because this container is CPU-only; on real
TPU hardware pass interpret=False (the BlockSpecs are written for TPU VMEM).
"""
from __future__ import annotations

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp

from repro.core.cp_rp import CPRP
from repro.core.formats import _prod
from repro.core.tt_rp import TTRP

from . import ref

# Per-kernel-instance VMEM budget. Real TPU cores have ~16 MiB; half of it
# leaves headroom for Pallas' double-buffered pipeline copies.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# Mode axis letters of the einsum programs ('a' = leading mode). Bounds the
# supported order; 8 modes is far past the paper's N<=6 evaluation range.
MODES = "abcdefgh"
MAX_ORDER = len(MODES)

_FAMILIES = ("tt", "cp")
_KINDS = ("project", "reconstruct")
# 'serial': one streamed tile per grid step (Pallas-managed copies).
# 'double': the d1 axis moves inside the kernel and the streamed operands
# are double-buffered by explicit DMAs (project only) — the second VMEM
# slot is accounted by the planner, halving the usable tile budget.
PIPELINES = ("serial", "double")


def validate_pipeline(pipeline: str) -> str:
    """The single `pipeline=` check (every layer — planners, dispatch,
    `rp.plan_execution` — delegates here): returns it, or raises the one
    typed ValueError naming the accepted set. Survives `python -O`."""
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; expected "
                         f"{PIPELINES}")
    return pipeline


def _pad_axis(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = a.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _lane_tile(k: int) -> int:
    return 128 if k >= 128 else max(8, 1 << (k - 1).bit_length())


def _pow2_at_most(n: int, cap: int) -> int:
    return min(cap, 1 << max(0, (n - 1).bit_length()))


# ---------------------------------------------------------------------------
# mode-sweep einsum programs
# ---------------------------------------------------------------------------

def _project_steps(family: str, order: int) -> tuple[str, ...]:
    """Einsum program of the projection mode sweep, rightmost mode first.

    Step s contracts operands `(carry, core)` where `carry` starts as the
    batched input block `(TB, BA, d2..dN)` and the cores are visited last to
    first; the rank bond ('u'/'v' for TT, 'r' for CP) is carried between
    steps and the final step collapses it against the leading core into the
    `(TB, TK)` output tile.
    """
    modes = MODES[:order]
    steps = []
    if family == "tt":
        steps.append(f"n{modes},ku{modes[-1]}->kn{modes[:-1]}u")
        carry = "u"
        for i in range(order - 2, 0, -1):
            new = "v" if carry == "u" else "u"
            steps.append(f"kn{modes[:i + 1]}{carry},k{new}{modes[i]}{carry}"
                         f"->kn{modes[:i]}{new}")
            carry = new
        steps.append(f"kna{carry},ka{carry}->nk")
    else:
        steps.append(f"n{modes},k{modes[-1]}r->kn{modes[:-1]}r")
        for i in range(order - 2, 0, -1):
            steps.append(f"kn{modes[:i + 1]}r,k{modes[i]}r->kn{modes[:i]}r")
        steps.append("knar,kar->nk")
    return tuple(steps)


def _reconstruct_steps(family: str, order: int):
    """Einsum program of the adjoint: `(m_steps, h_spec, out_spec)`.

    The trailing cores are folded right-to-left into a batch-independent
    transfer block m `(TK, R, d2..dN)` (m_steps; the first entry is a unary
    layout transpose for CP, None for TT whose squeezed last core already
    has the bond leading); h grafts the sketch onto the leading core, and
    out_spec is the one big `(TB*BA, TK*R) x (TK*R, prod(d2..dN))` MXU
    contraction.
    """
    modes = MODES[:order]
    m_steps = []
    if family == "tt":
        m_steps.append(None)
        carry = "u"
        for i in range(order - 2, 0, -1):
            new = "v" if carry == "u" else "u"
            m_steps.append(f"k{new}{modes[i]}{carry},k{carry}{modes[i + 1:]}"
                           f"->k{new}{modes[i:]}")
            carry = new
    else:
        m_steps.append(f"k{modes[-1]}r->kr{modes[-1]}")
        carry = "r"
        for i in range(order - 2, 0, -1):
            m_steps.append(f"k{modes[i]}r,kr{modes[i + 1:]}->kr{modes[i:]}")
    h_spec = f"nk,ka{carry}->nak{carry}"
    out_spec = f"nak{carry},k{carry}{modes[1:]}->na{modes[1:]}"
    return (tuple(m_steps), h_spec, out_spec)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ContractionPlan:
    """A fully-resolved mode-sweep schedule for one kernel launch.

    `steps` is the einsum program (`_project_steps` /
    `_reconstruct_steps`) that the sweep kernels execute verbatim — it is
    static (a tuple of strings), so it participates in the jit cache key and
    a given (family, kind, order) compiles exactly once per tiling.
    `vmem_bytes` is the accounted per-instance footprint at the chosen
    tiles.
    """

    family: str
    kind: str
    k: int
    b: int
    dims: tuple[int, ...]
    rank: int
    tk: int
    tb: int
    ba: int
    steps: tuple
    vmem_bytes: int
    pipeline: str = "serial"

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def grid(self) -> tuple[int, ...]:
        """Grid for the padded problem (k-tile outermost for project,
        innermost for reconstruct — the PR-2 schedule, order-generic).
        Under pipeline='double' the project d1 axis moves inside the
        kernel (an in-kernel fori_loop over double-buffered tiles), so
        the launch grid is (nk, nb)."""
        nk = -(-self.k // self.tk)
        nb = -(-self.b // self.tb)
        na = -(-self.dims[0] // self.ba)
        if self.kind == "project":
            if self.pipeline == "double":
                return (nk, nb)
            return (nk, nb, na)
        return (nb, na, nk)


def plan_contraction(family: str, kind: str, k: int, b: int,
                     dims: tuple[int, ...], rank: int, *,
                     budget: int = VMEM_BUDGET_BYTES,
                     pipeline: str = "serial") -> ContractionPlan:
    """Plan a mode-sweep kernel launch for static order N = len(dims).

    Accounts every per-instance VMEM buffer — streamed input/output blocks,
    per-k-tile cores (TT transfer cores are R x R on interior modes, CP
    factors are rank vectors), and every intermediate of the mode sweep —
    and shrinks tiles until the footprint fits `budget`:

    * kind='project': the sweep intermediates (sum over sweep steps of
      TK*TB*BA*prod(d2..dj)*R floats) dominate and scale with both TK and
      TB; the batch tile is shrunk first (TK=128 keeps k on the lane axis,
      which matters more than batch amortization).
    * kind='reconstruct': the fused transfer-block stages m (sum of
      TK*R*prod(dj..dN) floats) dominate and are batch-independent, so TK
      is shrunk first and the batch tile survives (it is what fills the
      MXU).
    """
    if kind not in _KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected {_KINDS}")
    if family not in _FAMILIES:
        raise ValueError(f"unknown family {family!r}; expected {_FAMILIES}")
    validate_pipeline(pipeline)
    if pipeline == "double" and kind != "project":
        raise ValueError(
            "pipeline='double' is implemented for kind='project' only: the "
            "reconstruct sweep accumulates over the k grid axis in the "
            "revisited output block and stays serial")
    dims = tuple(int(d) for d in dims)
    order = len(dims)
    if order < 2:
        raise ValueError(f"mode-sweep kernels need order >= 2, got dims={dims}")
    if order > MAX_ORDER:
        raise ValueError(f"order {order} exceeds MAX_ORDER={MAX_ORDER}")
    r = max(1, int(rank))
    d1, trail = dims[0], dims[1:]
    tk = _lane_tile(k)
    tb = _pow2_at_most(max(1, b), 8)
    ba = 8 if d1 % 8 == 0 or d1 >= 8 else d1
    if family == "tt":
        core_elems = (ba * r + sum(r * d * r for d in trail[:-1])
                      + r * trail[-1])
    else:
        core_elems = ba * r + sum(d * r for d in trail)

    def project_bytes(tk: int, tb: int) -> int:
        x_blk = tb * ba * _prod(trail)
        sweep = sum(tk * tb * ba * _prod(trail[:j]) * r
                    for j in range(len(trail)))
        # double buffering: a SECOND slot for each streamed operand — the
        # input block and the d1-tiled leading core (tk*ba*r) — lives in
        # VMEM scratch while the first contracts; the trailing cores keep
        # single-slot BlockSpec residency (indexed by ik only)
        extra = (x_blk + tk * ba * r) if pipeline == "double" else 0
        return 4 * (x_blk + sweep + tk * core_elems + tb * tk + extra)

    def reconstruct_bytes(tk: int, tb: int) -> int:
        m = sum(tk * r * _prod(trail[i:]) for i in range(len(trail) - 1))
        h = tb * ba * tk * r
        out_blk = tb * ba * _prod(trail)
        return 4 * (m + h + tk * core_elems + out_blk + tb * tk)

    if kind == "project":
        footprint, first, second = project_bytes, "tb", "tk"
    else:
        footprint, first, second = reconstruct_bytes, "tk", "tb"
    for axis in (first, second):
        while footprint(tk, tb) > budget:
            if axis == "tb" and tb > 1:
                tb //= 2
            elif axis == "tk" and tk > 8:
                tk //= 2
            else:
                break
    if footprint(tk, tb) > budget:
        # tb/tk are at their floors and the untiled trailing modes alone
        # exceed the budget — compiles in interpret mode, but on real TPU
        # hardware expect a VMEM allocation failure; surface the cause here,
        # next to the dims that chose it, not deep in the Mosaic compiler.
        warnings.warn(
            f"plan_contraction(kind={kind!r}): dims={dims}, rank={r} need "
            f"{footprint(tk, tb)} bytes of VMEM at the smallest tiling "
            f"(tk={tk}, tb={tb}, ba={ba}) > budget {budget}; the kernel may "
            "not fit on real TPU hardware — use smaller trailing modes or a "
            "higher order (smaller modes) for the same bucket size",
            RuntimeWarning, stacklevel=2)
    steps = (_project_steps(family, order) if kind == "project"
             else _reconstruct_steps(family, order))
    return ContractionPlan(family=family, kind=kind, k=k, b=b, dims=dims,
                           rank=r, tk=tk, tb=tb, ba=ba, steps=steps,
                           vmem_bytes=footprint(tk, tb), pipeline=pipeline)


def sweep_hbm_bytes(plan: ContractionPlan) -> int:
    """Grid-accurate analytic HBM traffic of ONE batched sweep launch.

    Follows the BlockSpec index maps laid out in `_sweep.py`: a block is
    re-fetched whenever its index map changes between consecutive grid
    steps and stays resident otherwise. The SAME traffic applies to the
    serial and double-buffered project schedules — pipelining overlaps the
    transfers with compute, it does not remove bytes — so timing rows,
    rooflines, and the fused-update accounting all read this one function.
    """
    k, b, dims, r = plan.k, plan.b, plan.dims, plan.rank
    nk = -(-k // plan.tk)
    nb_t = -(-b // plan.tb)
    na = -(-dims[0] // plan.ba)
    x_total = 4 * b * _prod(dims)
    y_total = 4 * b * k
    c1 = 4 * k * dims[0] * r               # leading core, d1-tile indexed
    if plan.family == "tt":
        c_rest = (sum(4 * k * r * d * r for d in dims[1:-1])
                  + 4 * k * r * dims[-1])
    else:
        c_rest = sum(4 * k * d * r for d in dims[1:])
    if plan.kind == "project":
        # grid (ik, ib[, ia]): x re-streamed once per k-tile; the d1-tiled
        # leading core once per batch tile; trailing cores resident per
        # k-tile. The double-buffered schedule's manual DMAs fetch exactly
        # the same tiles in the same order.
        return nk * x_total + nb_t * c1 + c_rest + y_total
    # grid (ib, ia, ik): y re-fetched once per d1-tile; leading core once
    # per batch tile; trailing cores re-streamed per (batch, d1) tile.
    return na * y_total + nb_t * c1 + nb_t * na * c_rest + x_total


def pick_tiles(k: int, b: int, dims: tuple[int, ...], rank: int, *,
               kind: str = "project", family: str = "tt",
               budget: int = VMEM_BUDGET_BYTES) -> tuple[int, int, int]:
    """VMEM-budgeted (tk, tb, ba) for an order-N batched kernel — the tile
    view of `plan_contraction` (kept as the stable public selector)."""
    plan = plan_contraction(family, kind, k, b, dims, rank, budget=budget)
    return plan.tk, plan.tb, plan.ba


# ---------------------------------------------------------------------------
# operator-container layouts
# ---------------------------------------------------------------------------

def tt_cores_squeezed(op: TTRP) -> tuple[jnp.ndarray, ...]:
    """Kernel layout of TT cores: boundary bonds (r_0 = r_N = 1) squeezed —
    (k, d1, R), interior (k, R, dn, R), (k, R, dN). Requires order >= 2."""
    cores = op.cores
    return ((cores[0][:, 0, :, :],) + tuple(cores[1:-1])
            + (cores[-1][:, :, :, 0],))


def _as_batch(x: jnp.ndarray, ndim: int) -> tuple[jnp.ndarray, bool]:
    """Add a singleton batch axis when `x` is a single input of rank `ndim`."""
    if x.ndim == ndim:
        return x[None], False
    assert x.ndim == ndim + 1, (x.shape, ndim)
    return x, True


def _pad_operands(plan: ContractionPlan, cores) -> list[jnp.ndarray]:
    """Pad every core's k axis to the k tile and the leading core's mode
    axis to the leading-mode tile (zero rows are inert under a linear map)."""
    padded = [_pad_axis(c, 0, plan.tk) for c in cores]
    padded[0] = _pad_axis(padded[0], 1, plan.ba)
    return padded


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _sweep_project(family, op, cores, x, interpret, pipeline="serial"):
    from ._sweep import sweep_project_pipelined
    from .cp_sweep import cp_sweep_project
    from .tt_sweep import tt_sweep_project
    k = op.k
    xb, batched = _as_batch(x, op.order)
    plan = plan_contraction(family, "project", k, xb.shape[0], op.in_dims,
                            op.rank, pipeline=pipeline)
    xk = _pad_axis(_pad_axis(xb, 0, plan.tb), 1, plan.ba)
    if plan.pipeline == "double":
        kern = sweep_project_pipelined
    else:
        kern = tt_sweep_project if family == "tt" else cp_sweep_project
    y = kern(xk, *_pad_operands(plan, cores), steps=plan.steps, tk=plan.tk,
             tb=plan.tb, ba=plan.ba, scale=1.0 / math.sqrt(k),
             interpret=interpret)
    y = y[:xb.shape[0], :k]
    return y if batched else y[0]


def kernel_order_supported(order: int) -> bool:
    """Orders the mode-sweep kernels cover; outside it (order-1 classical
    Gaussian, order > MAX_ORDER) the wrappers fall back to einsum."""
    return 2 <= order <= MAX_ORDER


def tt_project(op: TTRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True,
               pipeline: str = "serial") -> jnp.ndarray:
    """f_TT(R)(x) for dense order-N input(s) via the mode-sweep kernel.

    x: (*dims) -> (k,)  or  (B, *dims) -> (B, k), one launch either way.
    `pipeline='double'` selects the double-buffered DMA schedule
    (`sweep_project_pipelined`) — same result, overlapped streams.
    """
    if not kernel_order_supported(op.order) or not use_kernel:
        return op.project(x)
    return _sweep_project("tt", op, tt_cores_squeezed(op), x, interpret,
                          pipeline)


def cp_project(op: CPRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True,
               pipeline: str = "serial") -> jnp.ndarray:
    """f_CP(R)(x) for dense order-N input(s) via the mode-sweep kernel."""
    if not kernel_order_supported(op.order) or not use_kernel:
        return op.project(x)
    return _sweep_project("cp", op, op.factors, x, interpret, pipeline)


# ---------------------------------------------------------------------------
# adjoints
# ---------------------------------------------------------------------------

def _sweep_reconstruct(family, op, cores, y, interpret):
    from .cp_sweep import cp_sweep_reconstruct
    from .tt_sweep import tt_sweep_reconstruct
    k = op.k
    yb, batched = _as_batch(y, 1)
    plan = plan_contraction(family, "reconstruct", k, yb.shape[0],
                            op.in_dims, op.rank)
    yk = _pad_axis(_pad_axis(yb, 0, plan.tb), 1, plan.tk)
    kern = tt_sweep_reconstruct if family == "tt" else cp_sweep_reconstruct
    out = kern(yk, *_pad_operands(plan, cores), steps=plan.steps, tk=plan.tk,
               tb=plan.tb, ba=plan.ba, scale=1.0 / math.sqrt(k),
               interpret=interpret)
    out = out[:yb.shape[0], :op.in_dims[0]]
    return out if batched else out[0]


def tt_reconstruct(op: TTRP, y: jnp.ndarray, *, interpret: bool = True,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Unbiased adjoint for sketch(es): (k,) -> dims or (B,k) -> (B,*dims).

    Batched sketches reconstruct in ONE launch; padding k with zero sketch
    entries keeps padded core rows inert (y multiplies every term).
    """
    if not kernel_order_supported(op.order) or not use_kernel:
        if y.ndim == 2:
            return jax.vmap(op.reconstruct)(y)
        return op.reconstruct(y)
    return _sweep_reconstruct("tt", op, tt_cores_squeezed(op), y, interpret)


def cp_reconstruct(op: CPRP, y: jnp.ndarray, *, interpret: bool = True,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Unbiased adjoint for sketch(es) of a CP operator; see tt_reconstruct."""
    if not kernel_order_supported(op.order) or not use_kernel:
        if y.ndim == 2:
            return jax.vmap(op.reconstruct)(y)
        return op.reconstruct(y)
    return _sweep_reconstruct("cp", op, op.factors, y, interpret)


__all__ = ["ContractionPlan", "MAX_ORDER", "PIPELINES", "VMEM_BUDGET_BYTES",
           "cp_project", "cp_reconstruct", "kernel_order_supported",
           "pick_tiles", "plan_contraction", "ref", "sweep_hbm_bytes",
           "tt_cores_squeezed", "tt_project", "tt_reconstruct",
           "validate_pipeline"]
