"""Jit'd public wrappers around the Pallas kernels.

Handle padding (k to the lane tile, d1 to the stream block), the JLT
1/sqrt(k) scaling, layout conversion from the repro.core operator containers,
and graceful fallback to the jnp reference path for orders != 3.

`interpret` defaults to True because this container is CPU-only; on real TPU
hardware pass interpret=False (the BlockSpecs are written for TPU VMEM).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.cp_rp import CPRP
from repro.core.formats import TTTensor
from repro.core.tt_rp import TTRP

from . import ref
from .cp_project import cp_project3
from .tt_dot import tt_dot3
from .tt_project import tt_project3


def _pad_axis(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = a.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _pick_tiles(k: int, d1: int) -> tuple[int, int]:
    tk = 128 if k >= 128 else max(8, 1 << (k - 1).bit_length())
    ba = 8 if d1 % 8 == 0 or d1 >= 8 else d1
    return tk, ba


def tt_project(op: TTRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True) -> jnp.ndarray:
    """f_TT(R)(x) for a dense order-3 input via the Pallas kernel."""
    if op.order != 3 or not use_kernel:
        return op.project(x)
    k = op.k
    g1 = op.cores[0][:, 0, :, :]          # (k, d1, R)
    g2 = op.cores[1]                      # (k, R, d2, R)
    g3 = op.cores[2][:, :, :, 0]          # (k, R, d3)
    tk, ba = _pick_tiles(k, x.shape[0])
    xk = _pad_axis(x, 0, ba)
    g1k = _pad_axis(_pad_axis(g1, 0, tk), 1, ba)
    g2k = _pad_axis(g2, 0, tk)
    g3k = _pad_axis(g3, 0, tk)
    y = tt_project3(xk, g1k, g2k, g3k, tk=tk, ba=ba, interpret=interpret)
    return y[:k] / jnp.sqrt(jnp.asarray(k, y.dtype))


def cp_project(op: CPRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True) -> jnp.ndarray:
    """f_CP(R)(x) for a dense order-3 input via the Pallas kernel."""
    if op.order != 3 or not use_kernel:
        return op.project(x)
    k = op.k
    f1, f2, f3 = op.factors
    tk, ba = _pick_tiles(k, x.shape[0])
    xk = _pad_axis(x, 0, ba)
    f1k = _pad_axis(_pad_axis(f1, 0, tk), 1, ba)
    f2k = _pad_axis(f2, 0, tk)
    f3k = _pad_axis(f3, 0, tk)
    y = cp_project3(xk, f1k, f2k, f3k, tk=tk, ba=ba, interpret=interpret)
    return y[:k] / jnp.sqrt(jnp.asarray(k, y.dtype))


def tt_dot(op: TTRP, x: TTTensor, *, interpret: bool = True,
           use_kernel: bool = True) -> jnp.ndarray:
    """f_TT(R)(X) for a TT-format order-3 input via the Pallas kernel."""
    if op.order != 3 or x.order != 3 or not use_kernel:
        return op.project_tt(x)
    k = op.k
    g1 = op.cores[0][:, 0, :, :]
    g2 = op.cores[1]
    g3 = op.cores[2][:, :, :, 0]
    tk, _ = _pick_tiles(k, 8)
    g1k = _pad_axis(g1, 0, tk)
    g2k = _pad_axis(g2, 0, tk)
    g3k = _pad_axis(g3, 0, tk)
    y = tt_dot3(x.cores[0], x.cores[1], x.cores[2], g1k, g2k, g3k,
                tk=tk, interpret=interpret)
    return y[:k] / jnp.sqrt(jnp.asarray(k, y.dtype))


__all__ = ["tt_project", "cp_project", "tt_dot", "ref"]
