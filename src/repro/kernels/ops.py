"""Jit'd public wrappers around the Pallas kernels.

Handle batch/mode/k padding, layout conversion from the repro.core operator
containers, VMEM-budgeted tile selection, and graceful fallback to the jnp
reference path for orders != 3. The JLT 1/sqrt(k) scaling is FUSED into the
kernel epilogues (`scale=`), so no separate scaling pass runs over the output.

All four dense-path wrappers (`tt_project` / `cp_project` and the adjoints
`tt_reconstruct` / `cp_reconstruct`) accept either a single input
(`(d1,d2,d3)` tensor / `(k,)` sketch) or a batch (`(B,d1,d2,d3)` / `(B,k)`);
the batch runs in ONE kernel launch with a native batch grid axis — this is
how `PytreeSketcher` sketches all buckets of a leaf per launch.

`interpret` defaults to True because this container is CPU-only; on real TPU
hardware pass interpret=False (the BlockSpecs are written for TPU VMEM).
"""
from __future__ import annotations

import math
import warnings

import jax
import jax.numpy as jnp

from repro.core.cp_rp import CPRP
from repro.core.formats import TTTensor
from repro.core.tt_rp import TTRP

from . import ref
from .cp_project import cp_project3
from .cp_reconstruct import cp_reconstruct3
from .tt_dot import tt_dot3
from .tt_project import tt_project3
from .tt_reconstruct import tt_reconstruct3

# Per-kernel-instance VMEM budget. Real TPU cores have ~16 MiB; half of it
# leaves headroom for Pallas' double-buffered pipeline copies.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _pad_axis(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = a.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _lane_tile(k: int) -> int:
    return 128 if k >= 128 else max(8, 1 << (k - 1).bit_length())


def _pow2_at_most(n: int, cap: int) -> int:
    return min(cap, 1 << max(0, (n - 1).bit_length()))


def pick_tiles(k: int, b: int, dims: tuple[int, ...], rank: int, *,
               kind: str = "project", family: str = "tt",
               budget: int = VMEM_BUDGET_BYTES) -> tuple[int, int, int]:
    """VMEM-budgeted (tk, tb, ba) for the batched order-3 kernels.

    Accounts for every per-instance buffer — streamed input/output blocks,
    per-k-tile cores (`family='tt'` transfer cores are R x R on the middle
    mode, `'cp'` factors are rank vectors), and the kernel-internal einsum
    intermediates — and shrinks tiles until the footprint fits `budget`:

    * kind='project': the z intermediate (TK*TB*BA*d2*R floats) dominates and
      scales with both TK and TB; the batch tile is shrunk first (TK=128 keeps
      k on the lane axis, which matters more than batch amortization).
    * kind='reconstruct': the fused transfer-core intermediate m
      (TK*R*d2*d3 floats) dominates and is batch-independent, so TK is shrunk
      first and the batch tile survives (it is what fills the MXU).
    """
    d1, d2, d3 = dims
    r = max(1, int(rank))
    tk = _lane_tile(k)
    tb = _pow2_at_most(max(1, b), 8)
    ba = 8 if d1 % 8 == 0 or d1 >= 8 else d1
    if family == "tt":     # (tk,ba,r) + (tk,r,d2,r) + (tk,r,d3)
        core_elems = ba * r + r * d2 * r + r * d3
    else:                  # cp: (tk,ba,r) + (tk,d2,r) + (tk,d3,r)
        core_elems = ba * r + d2 * r + d3 * r

    def project_bytes(tk: int, tb: int) -> int:
        x_blk = tb * ba * d2 * d3
        z = tk * tb * ba * d2 * r
        v = tk * tb * ba * r
        return 4 * (x_blk + z + v + tk * core_elems + tb * tk)

    def reconstruct_bytes(tk: int, tb: int) -> int:
        m = tk * r * d2 * d3
        h = tb * ba * tk * r
        out_blk = tb * ba * d2 * d3
        return 4 * (m + h + tk * core_elems + out_blk + tb * tk)

    if kind == "project":
        footprint, first, second = project_bytes, "tb", "tk"
    elif kind == "reconstruct":
        footprint, first, second = reconstruct_bytes, "tk", "tb"
    else:
        raise ValueError(f"unknown kind {kind!r}")
    for axis in (first, second):
        while footprint(tk, tb) > budget:
            if axis == "tb" and tb > 1:
                tb //= 2
            elif axis == "tk" and tk > 8:
                tk //= 2
            else:
                break
    if footprint(tk, tb) > budget:
        # tb/tk are at their floors and the untiled d2/d3 modes alone exceed
        # the budget — compiles in interpret mode, but on real TPU hardware
        # expect a VMEM allocation failure; surface the cause here, next to
        # the dims that chose it, rather than deep in the Mosaic compiler.
        warnings.warn(
            f"pick_tiles(kind={kind!r}): dims={tuple(dims)}, rank={r} need "
            f"{footprint(tk, tb)} bytes of VMEM at the smallest tiling "
            f"(tk={tk}, tb={tb}, ba={ba}) > budget {budget}; the kernel may "
            "not fit on real TPU hardware — use smaller trailing modes",
            RuntimeWarning, stacklevel=2)
    return tk, tb, ba


def _as_batch(x: jnp.ndarray, ndim: int) -> tuple[jnp.ndarray, bool]:
    """Add a singleton batch axis when `x` is a single input of rank `ndim`."""
    if x.ndim == ndim:
        return x[None], False
    assert x.ndim == ndim + 1, (x.shape, ndim)
    return x, True


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def tt_project(op: TTRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True) -> jnp.ndarray:
    """f_TT(R)(x) for dense order-3 input(s) via the batched Pallas kernel.

    x: (d1,d2,d3) -> (k,)  or  (B,d1,d2,d3) -> (B,k), one launch either way.
    """
    if op.order != 3 or not use_kernel:
        return op.project(x)
    k = op.k
    g1 = op.cores[0][:, 0, :, :]          # (k, d1, R)
    g2 = op.cores[1]                      # (k, R, d2, R)
    g3 = op.cores[2][:, :, :, 0]          # (k, R, d3)
    xb, batched = _as_batch(x, 3)
    tk, tb, ba = pick_tiles(k, xb.shape[0], op.in_dims, op.rank,
                            kind="project")
    xk = _pad_axis(_pad_axis(xb, 0, tb), 1, ba)
    g1k = _pad_axis(_pad_axis(g1, 0, tk), 1, ba)
    g2k = _pad_axis(g2, 0, tk)
    g3k = _pad_axis(g3, 0, tk)
    y = tt_project3(xk, g1k, g2k, g3k, tk=tk, tb=tb, ba=ba,
                    scale=1.0 / math.sqrt(k), interpret=interpret)
    y = y[:xb.shape[0], :k]
    return y if batched else y[0]


def cp_project(op: CPRP, x: jnp.ndarray, *, interpret: bool = True,
               use_kernel: bool = True) -> jnp.ndarray:
    """f_CP(R)(x) for dense order-3 input(s) via the batched Pallas kernel."""
    if op.order != 3 or not use_kernel:
        return op.project(x)
    k = op.k
    f1, f2, f3 = op.factors
    xb, batched = _as_batch(x, 3)
    tk, tb, ba = pick_tiles(k, xb.shape[0], op.in_dims, op.rank,
                            kind="project", family="cp")
    xk = _pad_axis(_pad_axis(xb, 0, tb), 1, ba)
    f1k = _pad_axis(_pad_axis(f1, 0, tk), 1, ba)
    f2k = _pad_axis(f2, 0, tk)
    f3k = _pad_axis(f3, 0, tk)
    y = cp_project3(xk, f1k, f2k, f3k, tk=tk, tb=tb, ba=ba,
                    scale=1.0 / math.sqrt(k), interpret=interpret)
    y = y[:xb.shape[0], :k]
    return y if batched else y[0]


# ---------------------------------------------------------------------------
# adjoints
# ---------------------------------------------------------------------------

def tt_reconstruct(op: TTRP, y: jnp.ndarray, *, interpret: bool = True,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Unbiased adjoint for sketch(es): (k,) -> dims or (B,k) -> (B,*dims).

    Batched sketches reconstruct in ONE launch; padding k with zero sketch
    entries keeps padded core rows inert (y multiplies every term).
    """
    if op.order != 3 or not use_kernel:
        if y.ndim == 2:
            return jax.vmap(op.reconstruct)(y)
        return op.reconstruct(y)
    k = op.k
    g1 = op.cores[0][:, 0, :, :]
    g2 = op.cores[1]
    g3 = op.cores[2][:, :, :, 0]
    yb, batched = _as_batch(y, 1)
    tk, tb, ba = pick_tiles(k, yb.shape[0], op.in_dims, op.rank,
                            kind="reconstruct")
    yk = _pad_axis(_pad_axis(yb, 0, tb), 1, tk)
    g1k = _pad_axis(_pad_axis(g1, 0, tk), 1, ba)
    g2k = _pad_axis(g2, 0, tk)
    g3k = _pad_axis(g3, 0, tk)
    out = tt_reconstruct3(yk, g1k, g2k, g3k, tk=tk, tb=tb, ba=ba,
                          scale=1.0 / math.sqrt(k), interpret=interpret)
    d1 = op.in_dims[0]
    out = out[:yb.shape[0], :d1]
    return out if batched else out[0]


def cp_reconstruct(op: CPRP, y: jnp.ndarray, *, interpret: bool = True,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Unbiased adjoint for sketch(es) of a CP operator; see tt_reconstruct."""
    if op.order != 3 or not use_kernel:
        if y.ndim == 2:
            return jax.vmap(op.reconstruct)(y)
        return op.reconstruct(y)
    k = op.k
    f1, f2, f3 = op.factors
    yb, batched = _as_batch(y, 1)
    tk, tb, ba = pick_tiles(k, yb.shape[0], op.in_dims, op.rank,
                            kind="reconstruct", family="cp")
    yk = _pad_axis(_pad_axis(yb, 0, tb), 1, tk)
    f1k = _pad_axis(_pad_axis(f1, 0, tk), 1, ba)
    f2k = _pad_axis(f2, 0, tk)
    f3k = _pad_axis(f3, 0, tk)
    out = cp_reconstruct3(yk, f1k, f2k, f3k, tk=tk, tb=tb, ba=ba,
                          scale=1.0 / math.sqrt(k), interpret=interpret)
    d1 = op.in_dims[0]
    out = out[:yb.shape[0], :d1]
    return out if batched else out[0]


# ---------------------------------------------------------------------------
# structured input
# ---------------------------------------------------------------------------

def tt_dot(op: TTRP, x: TTTensor, *, interpret: bool = True,
           use_kernel: bool = True) -> jnp.ndarray:
    """f_TT(R)(X) for a TT-format order-3 input via the Pallas kernel."""
    if op.order != 3 or x.order != 3 or not use_kernel:
        return op.project_tt(x)
    k = op.k
    g1 = op.cores[0][:, 0, :, :]
    g2 = op.cores[1]
    g3 = op.cores[2][:, :, :, 0]
    tk = _lane_tile(k)
    g1k = _pad_axis(g1, 0, tk)
    g2k = _pad_axis(g2, 0, tk)
    g3k = _pad_axis(g3, 0, tk)
    y = tt_dot3(x.cores[0], x.cores[1], x.cores[2], g1k, g2k, g3k,
                tk=tk, interpret=interpret)
    return y[:k] / jnp.sqrt(jnp.asarray(k, y.dtype))


__all__ = ["tt_project", "cp_project", "tt_reconstruct", "cp_reconstruct",
           "tt_dot", "pick_tiles", "ref", "VMEM_BUDGET_BYTES"]
