"""Pallas TPU kernel: dense-input TT random projection (order 3).

Computes y[i] = sum_{a,b,c,r,s} g1[i,a,r] g2[i,r,b,s] g3[i,s,c] x[a,b,c]
for i in [k] — the hot loop of f_TT(R) applied to a flat (tensorized) vector
such as a gradient bucket.

TPU mapping
-----------
* grid = (k/TK, d1/BA): k tiled by TK=128 (lane width — every per-k einsum
  becomes an MXU/VPU op with k on the minor axis), the leading input mode
  tiled by BA so the streamed x block (BA, d2, d3) plus the per-tile cores and
  the (TK, BA, d2, R) intermediate stay inside VMEM.
* The output block index depends only on the k-tile, so partial sums over the
  d1 grid axis accumulate in-place (revisited output block) — the canonical
  Pallas matmul accumulation pattern.
* VMEM budget at defaults (TK=128, BA=8, d2=128, d3=64, R=2), f32:
    x block      8*128*64*4      = 256 KiB
    z intermed.  128*8*128*2*4   = 1   MiB
    cores        ~0.3 MiB        -> << 16 MiB VMEM.
* All contraction shapes are multiples of (8,128) when dims are MXU-aligned
  (the compressor picks (128,128,64) buckets for exactly this reason).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_project3_kernel(x_ref, g1_ref, g2_ref, g3_ref, o_ref):
    ia = pl.program_id(1)
    x = x_ref[...]                                    # (BA, d2, d3)
    g3 = g3_ref[...]                                  # (TK, R, d3)
    # contract c: (TK, BA, d2, R)
    z = jnp.einsum("abc,ksc->kabs", x, g3, preferred_element_type=jnp.float32)
    g2 = g2_ref[...]                                  # (TK, R, d2, R)
    # contract (b, s): (TK, BA, R)
    v = jnp.einsum("kabs,krbs->kar", z, g2, preferred_element_type=jnp.float32)
    g1 = g1_ref[...]                                  # (TK, BA, R)
    y = jnp.einsum("kar,kar->k", v, g1, preferred_element_type=jnp.float32)

    @pl.when(ia == 0)
    def _init():
        o_ref[...] = y[:, None]

    @pl.when(ia != 0)
    def _acc():
        o_ref[...] += y[:, None]


@functools.partial(jax.jit, static_argnames=("tk", "ba", "interpret"))
def tt_project3(x: jnp.ndarray, g1: jnp.ndarray, g2: jnp.ndarray,
                g3: jnp.ndarray, *, tk: int = 128, ba: int = 8,
                interpret: bool = True) -> jnp.ndarray:
    """Raw contraction (no 1/sqrt(k)); ops.py applies scaling/padding.

    x (d1,d2,d3); g1 (k,d1,R); g2 (k,R,d2,R); g3 (k,R,d3). k%tk==0, d1%ba==0.
    """
    d1, d2, d3 = x.shape
    k, _, r = g1.shape
    assert g2.shape == (k, r, d2, r) and g3.shape == (k, r, d3)
    assert k % tk == 0, (k, tk)
    assert d1 % ba == 0, (d1, ba)
    grid = (k // tk, d1 // ba)
    out = pl.pallas_call(
        _tt_project3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, d2, d3), lambda ik, ia: (ia, 0, 0)),
            pl.BlockSpec((tk, ba, r), lambda ik, ia: (ik, ia, 0)),
            pl.BlockSpec((tk, r, d2, r), lambda ik, ia: (ik, 0, 0, 0)),
            pl.BlockSpec((tk, r, d3), lambda ik, ia: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tk, 1), lambda ik, ia: (ik, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x, g1, g2, g3)
    return out[:, 0]
