"""Pallas TPU kernel: batched dense-input TT random projection (order 3).

Computes y[n,i] = scale * sum_{a,b,c,r,s} g1[i,a,r] g2[i,r,b,s] g3[i,s,c]
x[n,a,b,c] for i in [k], n in [B] — the hot loop of f_TT(R) applied to a whole
*batch* of flat (tensorized) vectors, e.g. every gradient bucket of a pytree
leaf in one launch. `scale` fuses the JLT 1/sqrt(k) into the kernel epilogue
(each k-tile partial sum is scaled, so the accumulated total carries it too).

TPU mapping
-----------
* grid = (k/TK, B/TB, d1/BA): the k-tile index is OUTERMOST so the per-tile
  cores — whose block index depends only on ik — stay resident in VMEM while
  the whole batch streams through; with the old per-bucket vmap the cores
  were re-fetched from HBM once per bucket. TK=128 puts k on the lane axis so
  every per-k einsum is an MXU/VPU op; the batch tile TB enlarges each
  contraction (B*BA rows instead of BA) toward the 128x128 systolic shape.
* The output block index (ib, ik) is independent of the d1 grid axis (ia,
  innermost), so partial sums over d1 accumulate in-place in the revisited
  output block — the canonical Pallas matmul accumulation pattern.
* VMEM per instance at defaults (TK=128, TB=4, BA=8, d2=128, d3=64, R=2), f32:
    x block      4*8*128*64*4        = 1   MiB
    z intermed.  128*4*8*128*2*4     = 4   MiB
    cores        ~0.3 MiB, out 128*4*4 -> well under the 16 MiB/core VMEM;
  ops.pick_tiles shrinks TB (then TK) when B/d2/R would blow the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_project3_kernel(x_ref, g1_ref, g2_ref, g3_ref, o_ref, *, scale):
    ia = pl.program_id(2)
    x = x_ref[...]                                    # (TB, BA, d2, d3)
    g3 = g3_ref[...]                                  # (TK, R, d3)
    # contract c: (TK, TB, BA, d2, R)
    z = jnp.einsum("nabc,ksc->knabs", x, g3, preferred_element_type=jnp.float32)
    g2 = g2_ref[...]                                  # (TK, R, d2, R)
    # contract (b, s): (TK, TB, BA, R)
    v = jnp.einsum("knabs,krbs->knar", z, g2, preferred_element_type=jnp.float32)
    g1 = g1_ref[...]                                  # (TK, BA, R)
    y = jnp.einsum("knar,kar->nk", v, g1,
                   preferred_element_type=jnp.float32) * scale

    @pl.when(ia == 0)
    def _init():
        o_ref[...] = y

    @pl.when(ia != 0)
    def _acc():
        o_ref[...] += y


@functools.partial(jax.jit,
                   static_argnames=("tk", "tb", "ba", "scale", "interpret"))
def tt_project3(x: jnp.ndarray, g1: jnp.ndarray, g2: jnp.ndarray,
                g3: jnp.ndarray, *, tk: int = 128, tb: int = 4, ba: int = 8,
                scale: float = 1.0, interpret: bool = True) -> jnp.ndarray:
    """Batched contraction; ops.py handles padding and layout.

    x (B,d1,d2,d3); g1 (k,d1,R); g2 (k,R,d2,R); g3 (k,R,d3). Requires
    k%tk==0, B%tb==0, d1%ba==0. `scale` (static) is fused into the epilogue —
    pass 1/sqrt(k_logical) for the JLT scaling, 1.0 for the raw contraction.
    Returns (B, k) float32.
    """
    b, d1, d2, d3 = x.shape
    k, _, r = g1.shape
    assert g2.shape == (k, r, d2, r) and g3.shape == (k, r, d3)
    assert k % tk == 0, (k, tk)
    assert b % tb == 0, (b, tb)
    assert d1 % ba == 0, (d1, ba)
    grid = (k // tk, b // tb, d1 // ba)
    return pl.pallas_call(
        functools.partial(_tt_project3_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, ba, d2, d3), lambda ik, ib, ia: (ib, ia, 0, 0)),
            pl.BlockSpec((tk, ba, r), lambda ik, ib, ia: (ik, ia, 0)),
            pl.BlockSpec((tk, r, d2, r), lambda ik, ib, ia: (ik, 0, 0, 0)),
            pl.BlockSpec((tk, r, d3), lambda ik, ib, ia: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tk), lambda ik, ib, ia: (ib, ik)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, g1, g2, g3)
