"""Fused unsketch + error-feedback + AdamW Pallas kernel.

The unfused sketch-compressed train step runs, per dense leaf,

    g_hat = alpha * Unsketch(y)     (reconstruct kernel -> dense HBM write)
    resid = p - g_hat               (EF residual: two dense reads, one write)
    m/v/w updates                   (AdamW: three dense read/write passes)

which materializes the dense reconstruction g_hat in HBM and then streams
every dense operand again for error feedback and the optimizer math. This
module fuses the whole chain into ONE launch per leaf on the reconstruct
sweep's own grid `(B/TB, d1/BA, k/TK)` (k-tile INNERMOST): each
`(TB, BA, d2..dN)` tile accumulates its reconstruction across the k grid
axis in the revisited RESIDUAL output block — the same revisited-block
accumulation as `_sweep._reconstruct_kernel`, with the residual output
doubling as the g_hat accumulator — and the LAST k step runs the epilogue
while the tile is still in VMEM:

    resid = p - g_hat                         (error feedback)
    m32   = b1 m + (1-b1) g_hat               (AdamW moments, f32)
    v32   = b2 v + (1-b2) g_hat^2
    w'    = w - lr ((m32/c1)/(sqrt(v32/c2)+eps) + wd w)

so the dense g_hat NEVER round-trips through HBM. The JLT 1/sqrt(k) and
the MMSE shrinkage alpha fuse into one static per-k-step scale.

Inputs arrive in BUCKET space, all float32 (`PytreeSketcher.
_leaf_to_buckets` casts on the way in, `_leaf_from_buckets` casts back to
the storage dtype on the way out — the same cast points as the unfused
reference, so 'lean'-policy bf16 moments see identical rounding).

`plan_fused_update` budgets the launch: a reconstruct-sweep plan whose
VMEM budget additionally charges the eight dense `(TB, BA, d2..dN)` blocks
the fusion keeps resident (p/w/m/v in, resid/w'/m'/v' out).
`fused_hbm_bytes` / `unfused_hbm_bytes` give the analytic HBM traffic of
the two formulations for the SAME plan — the accounting behind the
`perf/fused/*` bench rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cp_rp import CPRP
from repro.core.formats import _prod
from repro.core.tt_rp import TTRP

from ._sweep import _core_specs, _imap
from .ops import (MAX_ORDER, VMEM_BUDGET_BYTES, ContractionPlan, _pad_axis,
                  _pad_operands, kernel_order_supported, plan_contraction,
                  sweep_hbm_bytes, tt_cores_squeezed)


def plan_fused_update(family: str, k: int, b: int, dims: tuple[int, ...],
                      rank: int, *,
                      budget: int = VMEM_BUDGET_BYTES) -> ContractionPlan:
    """Reconstruct-sweep plan for the fused launch.

    Fixed point over `plan_contraction(kind='reconstruct')`: the fused
    kernel keeps EIGHT dense `(TB, BA, d2..dN)` blocks resident on top of
    the sweep's own buffers (four optimizer inputs, four outputs), and
    those extra bytes depend on the tiles the budget chooses — iterate
    until the tiling is stable under its own surcharge.
    """
    dims = tuple(int(d) for d in dims)
    trail_elems = _prod(dims[1:])
    plan = plan_contraction(family, "reconstruct", k, b, dims, rank,
                            budget=budget)
    for _ in range(16):
        extra = 8 * 4 * plan.tb * plan.ba * trail_elems
        new = plan_contraction(family, "reconstruct", k, b, dims, rank,
                               budget=max(1, budget - extra))
        if (new.tk, new.tb, new.ba) == (plan.tk, plan.tb, plan.ba):
            return new
        plan = new
    return plan


def fused_hbm_bytes(plan: ContractionPlan) -> int:
    """Analytic HBM traffic of ONE fused launch under `plan`.

    The sweep-side traffic (sketches re-fetched per d1-tile, cores per
    the reconstruct index maps) is `sweep_hbm_bytes` MINUS its dense
    output write — g_hat lives only in the revisited VMEM block — plus
    eight dense passes: p/w/m/v read once each, resid/w'/m'/v' written
    once each.
    """
    dense = 4 * plan.b * _prod(plan.dims)
    return (sweep_hbm_bytes(plan) - dense) + 8 * dense


def unfused_hbm_bytes(plan: ContractionPlan) -> int:
    """Analytic HBM traffic of the UNFUSED chain for the same `plan`.

    The reconstruct launch (`sweep_hbm_bytes`, which includes the dense
    g_hat WRITE) plus the nine dense passes XLA then streams: g_hat and p
    read for the residual, resid written, and w/m/v each read and written
    by the optimizer step.
    """
    dense = 4 * plan.b * _prod(plan.dims)
    return sweep_hbm_bytes(plan) + 9 * dense


def _fused_kernel(y_ref, s_ref, *refs, steps, n_core, scale, b1, b2, eps,
                  wd, nk):
    core_refs = refs[:n_core]
    p_ref, w_ref, m_ref, v_ref = refs[n_core:n_core + 4]
    r_ref, wo_ref, mo_ref, vo_ref = refs[n_core + 4:]
    m_steps, h_spec, out_spec = steps
    ik = pl.program_id(2)
    # one reconstruct k-step, verbatim from _sweep._reconstruct_kernel
    mm = core_refs[-1][...]
    if m_steps[0] is not None:           # CP layout transpose; None for TT
        mm = jnp.einsum(m_steps[0], mm)
    for spec, g_ref in zip(m_steps[1:], reversed(core_refs[1:-1])):
        mm = jnp.einsum(spec, g_ref[...], mm,
                        preferred_element_type=jnp.float32)
    h = jnp.einsum(h_spec, y_ref[...], core_refs[0][...],
                   preferred_element_type=jnp.float32)
    out = jnp.einsum(out_spec, h, mm,
                     preferred_element_type=jnp.float32) * scale

    @pl.when(ik == 0)
    def _init():
        r_ref[...] = out

    @pl.when(ik != 0)
    def _acc():
        r_ref[...] += out

    @pl.when(ik == nk - 1)
    def _epilogue():
        # the accumulated block IS alpha * g_hat for this tile; consume it
        # for EF + AdamW while it is still in VMEM, then overwrite it with
        # the residual
        g = r_ref[...]
        lr, c1, c2 = s_ref[0, 0], s_ref[0, 1], s_ref[0, 2]
        w = w_ref[...]
        m32 = b1 * m_ref[...] + (1.0 - b1) * g
        v32 = b2 * v_ref[...] + (1.0 - b2) * g * g
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        r_ref[...] = p_ref[...] - g
        wo_ref[...] = w - lr * (step + wd * w)
        mo_ref[...] = m32
        vo_ref[...] = v32


@functools.partial(jax.jit, static_argnames=("steps", "trail", "tk", "tb",
                                             "ba", "scale", "b1", "b2",
                                             "eps", "wd", "interpret"))
def _fused_launch(y, s, *arrs, steps, trail, tk, tb, ba, scale, b1, b2,
                  eps, wd, interpret):
    cores, dense = arrs[:-4], arrs[-4:]
    b, k = y.shape
    d1 = cores[0].shape[1]
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (b // tb, d1 // ba, k // tk)
    dense_spec = pl.BlockSpec((tb, ba) + trail,
                              _imap(0, 1, *([None] * len(trail))))
    in_specs = [pl.BlockSpec((tb, tk), _imap(0, 2)),
                pl.BlockSpec((1, 4), _imap(None, None))]
    in_specs += _core_specs(cores, tk, ba, lead_pos=1, k_pos=2)
    in_specs += [dense_spec] * 4
    blk = jax.ShapeDtypeStruct((b, d1) + trail, jnp.float32)
    return pl.pallas_call(
        functools.partial(_fused_kernel, steps=steps, n_core=len(cores),
                          scale=scale, b1=b1, b2=b2, eps=eps, wd=wd,
                          nk=k // tk),
        grid=grid,
        in_specs=in_specs,
        out_specs=(dense_spec,) * 4,
        out_shape=(blk,) * 4,
        interpret=interpret,
    )(y, s, *arrs)


def fused_update_buckets(op, y, p, w, m, v, lr, c1, c2, *, alpha: float,
                         b1: float, b2: float, eps: float,
                         weight_decay: float, interpret: bool = True):
    """ONE launch: unsketch + error feedback + AdamW for one leaf's buckets.

    op     : a TT/CP operator at a kernel-supported order (the one the
             sketch was drawn with — regenerated from the same key).
    y      : (nb, k) sketch rows of this leaf.
    p      : (nb, *dims) error-fed gradient buckets (g + e), float32.
    w/m/v  : (nb, *dims) param / first-moment / second-moment buckets, f32.
    lr/c1/c2: traced scalars — learning rate and the AdamW bias corrections
             1-b1^t / 1-b2^t (they change every step; statics would retrace).
    alpha  : MMSE shrinkage (`SketchConfig.shrinkage()`), fused with the
             JLT 1/sqrt(k) into the kernel's static scale.

    Returns (resid, w_new, m_new, v_new), each (nb, *dims) float32:
    resid = p - alpha*Unsketch(y) is the next error-feedback state.
    """
    if not isinstance(op, (TTRP, CPRP)):
        raise TypeError(f"fused_update_buckets needs a TT/CP operator, got "
                        f"{type(op).__name__}")
    if not kernel_order_supported(op.order):
        raise ValueError(
            f"fused_update_buckets needs a kernel-supported operator order "
            f"(2..{MAX_ORDER}), got order {op.order}")
    family = "tt" if isinstance(op, TTRP) else "cp"
    cores = tt_cores_squeezed(op) if family == "tt" else op.factors
    nb = y.shape[0]
    dims = tuple(op.in_dims)
    plan = plan_fused_update(family, op.k, nb, dims, op.rank)
    yk = _pad_axis(_pad_axis(y, 0, plan.tb), 1, plan.tk)
    dense = [_pad_axis(_pad_axis(a, 0, plan.tb), 1, plan.ba)
             for a in (p, w, m, v)]
    scal = jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(c1, jnp.float32),
                      jnp.asarray(c2, jnp.float32),
                      jnp.zeros((), jnp.float32)]).reshape(1, 4)
    out = _fused_launch(yk, scal, *_pad_operands(plan, cores), *dense,
                        steps=plan.steps, trail=dims[1:], tk=plan.tk,
                        tb=plan.tb, ba=plan.ba,
                        scale=float(alpha) / math.sqrt(op.k),
                        b1=float(b1), b2=float(b2), eps=float(eps),
                        wd=float(weight_decay), interpret=interpret)
    return tuple(o[:nb, :dims[0]] for o in out)


__all__ = ["fused_hbm_bytes", "fused_update_buckets", "plan_fused_update",
           "unfused_hbm_bytes"]
