"""Pallas TPU kernels for the paper's projection hot-spots.

tt_project / cp_project: dense-input (tensorized flat vector) projections.
tt_dot: structured TT-input projection (the paper's O(kNd max(R,R~)^3) path).
Validated in interpret mode against ref.py; BlockSpecs target TPU VMEM.
"""
from .ops import cp_project, tt_dot, tt_project
from . import ref

__all__ = ["cp_project", "tt_dot", "tt_project", "ref"]
