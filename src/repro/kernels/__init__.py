"""Pallas TPU kernels for the paper's projection hot-spots, order-generic.

tt_project / cp_project: batched dense-input (tensorized flat vector)
projections for ANY order N >= 2 — one launch per batch of buckets, JLT
scaling fused — via the mode-sweep kernels (tt_sweep.py / cp_sweep.py).
tt_reconstruct / cp_reconstruct: the batched adjoint reconstructions.
struct: the compressed-domain subsystem — batched structured-input
(TT/CP-format) projections for all four (operator, input) pairings via
carry-sweep kernels (`struct.struct_project`, the paper's
O(k N d R R~ (R + R~)) path, any order 2..MAX_ORDER; replaces the retired
order-3-only `tt_dot`).
plan_contraction / ContractionPlan: the dense mode-sweep contraction
planner — einsum program + VMEM-budgeted tiles + grid for a static order;
`struct.plan_carry_sweep` is its structured-input counterpart.
pick_tiles: the tile view of the planner, shared by all dense wrappers.
Validated in interpret mode against ref.py / struct/ref.py; BlockSpecs
target TPU VMEM.
"""
from . import ref, struct
from .fused_update import (fused_hbm_bytes, fused_update_buckets,
                           plan_fused_update, unfused_hbm_bytes)
from .ops import (MAX_ORDER, PIPELINES, ContractionPlan, cp_project,
                  cp_reconstruct, kernel_order_supported, pick_tiles,
                  plan_contraction, sweep_hbm_bytes, tt_cores_squeezed,
                  tt_project, tt_reconstruct)
from .struct import plan_carry_sweep, struct_hbm_bytes, struct_project

__all__ = ["MAX_ORDER", "PIPELINES", "ContractionPlan", "cp_project",
           "cp_reconstruct", "fused_hbm_bytes", "fused_update_buckets",
           "kernel_order_supported", "pick_tiles", "plan_carry_sweep",
           "plan_contraction", "plan_fused_update", "ref", "struct",
           "struct_hbm_bytes", "struct_project", "sweep_hbm_bytes",
           "tt_cores_squeezed", "tt_project", "tt_reconstruct",
           "unfused_hbm_bytes"]
