"""Pallas TPU kernels for the paper's projection hot-spots.

tt_project / cp_project: batched dense-input (tensorized flat vector)
projections — one launch per batch of buckets, JLT scaling fused.
tt_reconstruct / cp_reconstruct: batched adjoint reconstructions.
tt_dot: structured TT-input projection (the paper's O(kNd max(R,R~)^3) path).
pick_tiles: the VMEM-budgeted tile selector shared by all dense wrappers.
Validated in interpret mode against ref.py; BlockSpecs target TPU VMEM.
"""
from . import ref
from .ops import (cp_project, cp_reconstruct, pick_tiles, tt_dot, tt_project,
                  tt_reconstruct)

__all__ = ["cp_project", "cp_reconstruct", "pick_tiles", "tt_dot",
           "tt_project", "tt_reconstruct", "ref"]
