"""Pallas TPU kernels for the paper's projection hot-spots, order-generic.

tt_project / cp_project: batched dense-input (tensorized flat vector)
projections for ANY order N >= 2 — one launch per batch of buckets, JLT
scaling fused — via the mode-sweep kernels (tt_sweep.py / cp_sweep.py).
tt_reconstruct / cp_reconstruct: the batched adjoint reconstructions.
tt_dot: structured TT-input projection (the paper's O(kNd max(R,R~)^3)
path; order-3 kernel, transfer-matrix einsum elsewhere).
plan_contraction / ContractionPlan: the mode-sweep contraction planner —
einsum program + VMEM-budgeted tiles + grid for a static order.
pick_tiles: the tile view of the planner, shared by all dense wrappers.
Validated in interpret mode against ref.py; BlockSpecs target TPU VMEM.
"""
from . import ref
from .ops import (MAX_ORDER, ContractionPlan, cp_project, cp_reconstruct,
                  kernel_order_supported, pick_tiles, plan_contraction,
                  tt_cores_squeezed, tt_dot, tt_project, tt_reconstruct)

__all__ = ["MAX_ORDER", "ContractionPlan", "cp_project", "cp_reconstruct",
           "kernel_order_supported", "pick_tiles", "plan_contraction", "ref",
           "tt_cores_squeezed", "tt_dot", "tt_project", "tt_reconstruct"]
