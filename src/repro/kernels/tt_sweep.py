"""Pallas TPU kernels: order-N batched dense-input TT projection + adjoint.

These replace the retired order-3 `tt_project3` / `tt_reconstruct3` kernels
with a mode-sweep pair driven by the contraction planner in `ops.py`: the
planner emits the einsum program (`plan.steps`) and the VMEM-budgeted tiles
for a static order N, and the shared machinery in `_sweep.py` lays that
program out on the TPU grid.

Projection — y[n,i] = scale * sum g1[i,a,u] g2[i,u,b,v] ... gN[i,·,z]
x[n,a,b,...,z], i in [k], n in [B]:

* grid = (k/TK, B/TB, d1/BA): the k-tile index is OUTERMOST so the per-tile
  cores — whose block index depends only on ik — stay resident in VMEM
  while the whole batch streams through. TK=128 puts k on the lane axis so
  every sweep step is an MXU/VPU op; the batch tile TB enlarges each
  contraction (TB*BA rows instead of BA) toward the 128x128 systolic shape.
* The sweep contracts the rightmost mode first, carrying the R-sized TT
  bond between steps; intermediates shrink by one mode per step, so the
  first step's (TK, TB, BA, d2..d_{N-1}, R) block is the VMEM peak the
  planner budgets for. Accumulation over d1 happens in the revisited
  (TB, TK) output block (ia is the innermost grid axis).

Reconstruction — x_hat[n,a,b,...] = scale * sum_i y[n,i] g1[i,a,u] ... :

* grid = (B/TB, d1/BA, k/TK), k-tile INNERMOST; per-k-tile partial sums
  accumulate in the revisited (TB, BA, d2..dN) output block.
* The N-1 trailing cores are pre-fused once per instance into the transfer
  block m[i,u,d2..dN] — independent of batch AND of the d1 tile; the rest
  is one (TB*BA, TK*R) x (TK*R, prod(d2..dN)) MXU contraction. m dominates
  VMEM, so the planner shrinks TK first for this direction.

Core layout is `ops.tt_cores_squeezed`: (k, d1, R), interior (k, R, d, R),
(k, R, dN). `scale` fuses the JLT 1/sqrt(k) into the epilogue. `interpret`
defaults to the caller's choice (True off-TPU). Validated against `ref.py`.
"""
from __future__ import annotations

import jax.numpy as jnp

from ._sweep import sweep_project, sweep_reconstruct


def tt_sweep_project(x: jnp.ndarray, *cores: jnp.ndarray, steps,
                     tk: int = 128, tb: int = 4, ba: int = 8,
                     scale: float = 1.0,
                     interpret: bool = True) -> jnp.ndarray:
    """Batched order-N TT contraction; ops.py plans steps/tiles and pads.

    x (B, d1, ..., dN); cores squeezed. Requires k%tk==0, B%tb==0, d1%ba==0.
    `scale` (static) is fused into the epilogue — pass 1/sqrt(k_logical) for
    the JLT scaling, 1.0 for the raw contraction. Returns (B, k) float32.
    """
    return sweep_project(x, *cores, steps=steps, tk=tk, tb=tb, ba=ba,
                         scale=scale, interpret=interpret)


def tt_sweep_reconstruct(y: jnp.ndarray, *cores: jnp.ndarray, steps,
                         tk: int = 32, tb: int = 4, ba: int = 8,
                         scale: float = 1.0,
                         interpret: bool = True) -> jnp.ndarray:
    """Batched order-N TT adjoint; y (B, k), cores squeezed.

    Padding k with zero sketch entries (and arbitrary core rows) is safe:
    the sketch multiplies every term. `scale` is fused — pass
    1/sqrt(k_logical). Returns (B, d1, ..., dN) float32.
    """
    trail = tuple(int(c.shape[2]) for c in cores[1:])
    return sweep_reconstruct(y, *cores, steps=steps, trail=trail, tk=tk,
                             tb=tb, ba=ba, scale=scale, interpret=interpret)
