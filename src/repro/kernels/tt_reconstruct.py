"""Pallas TPU kernel: batched TT adjoint reconstruction (order 3).

x_hat[n,a,b,c] = scale * sum_{i,r,s} y[n,i] g1[i,a,r] g2[i,r,b,s] g3[i,s,c]

— the unbiased adjoint x_hat = (1/sqrt k) sum_i y_i S_i, batched over sketches
so `unsketch` reconstructs every bucket of a pytree leaf in ONE launch instead
of a vmap of reference einsums that materialize a (k, d1, d2, R) intermediate
per bucket.

TPU mapping
-----------
* grid = (B/TB, d1/BA, k/TK): the k-tile axis is INNERMOST; the output block
  index (ib, ia) is constant across it, so per-k-tile partial sums accumulate
  in the revisited output block (same pattern as the projection kernels, with
  the contraction axis moved to k).
* Per instance the two transfer cores are pre-fused once,
  m[i,r,b,c] = sum_s g2[i,r,b,s] g3[i,s,c], independent of batch AND of the
  d1 tile; the remaining work is a single (TB*BA, TK*R) x (TK*R, d2*d3) MXU
  contraction — the batched formulation is exactly what makes this matmul
  large enough to fill the systolic array.
* VMEM: m is TK*R*d2*d3*4 bytes (the dominant buffer — 8 MiB at TK=128, R=2,
  d2=128, d3=64), so ops.pick_tiles shrinks TK first for the adjoint; the
  output block is TB*BA*d2*d3*4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_reconstruct3_kernel(y_ref, g1_ref, g2_ref, g3_ref, o_ref, *, scale):
    ik = pl.program_id(2)
    g2 = g2_ref[...]                                  # (TK, R, d2, R)
    g3 = g3_ref[...]                                  # (TK, R, d3)
    # fuse the two transfer cores: (TK, R, d2, d3)
    m = jnp.einsum("krbs,ksc->krbc", g2, g3, preferred_element_type=jnp.float32)
    y = y_ref[...]                                    # (TB, TK)
    g1 = g1_ref[...]                                  # (TK, BA, R)
    h = jnp.einsum("nk,kar->nakr", y, g1, preferred_element_type=jnp.float32)
    out = jnp.einsum("nakr,krbc->nabc", h, m,
                     preferred_element_type=jnp.float32) * scale

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = out

    @pl.when(ik != 0)
    def _acc():
        o_ref[...] += out


@functools.partial(jax.jit,
                   static_argnames=("tk", "tb", "ba", "scale", "interpret"))
def tt_reconstruct3(y: jnp.ndarray, g1: jnp.ndarray, g2: jnp.ndarray,
                    g3: jnp.ndarray, *, tk: int = 32, tb: int = 4, ba: int = 8,
                    scale: float = 1.0,
                    interpret: bool = True) -> jnp.ndarray:
    """Batched adjoint; y (B,k); g1 (k,d1,R); g2 (k,R,d2,R); g3 (k,R,d3).

    Requires k%tk==0, B%tb==0, d1%ba==0. Padding k with zero sketch entries
    (and arbitrary core rows) is safe: h carries y as a factor. `scale` is
    fused — pass 1/sqrt(k_logical). Returns (B, d1, d2, d3) float32.
    """
    b, k = y.shape
    _, d1, r = g1.shape
    d2 = g2.shape[2]
    d3 = g3.shape[2]
    assert g1.shape == (k, d1, r) and g2.shape == (k, r, d2, r)
    assert g3.shape == (k, r, d3)
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (b // tb, d1 // ba, k // tk)
    return pl.pallas_call(
        functools.partial(_tt_reconstruct3_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tk), lambda ib, ia, ik: (ib, ik)),
            pl.BlockSpec((tk, ba, r), lambda ib, ia, ik: (ik, ia, 0)),
            pl.BlockSpec((tk, r, d2, r), lambda ib, ia, ik: (ik, 0, 0, 0)),
            pl.BlockSpec((tk, r, d3), lambda ib, ia, ik: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ba, d2, d3),
                               lambda ib, ia, ik: (ib, ia, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d1, d2, d3), jnp.float32),
        interpret=interpret,
    )(y, g1, g2, g3)
