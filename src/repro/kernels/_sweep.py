"""Family-agnostic Pallas machinery for the order-N mode-sweep kernels.

The kernel bodies execute the einsum program emitted by the contraction
planner (`ops.plan_contraction`) verbatim — `steps` arrives as a static
tuple of strings, so each (family, kind, order, tiling) compiles exactly
once. `tt_sweep.py` / `cp_sweep.py` wrap these with the family core layouts
and document the TPU schedule; nothing here is family-specific beyond what
the program strings encode.

Grid conventions (the PR-2 batched schedule, order-generic):
* project: grid = (k/TK, B/TB, d1/BA), k-tile OUTERMOST, accumulate over
  the d1 axis in the revisited (TB, TK) output block.
* reconstruct: grid = (B/TB, d1/BA, k/TK), k-tile INNERMOST, accumulate
  over k in the revisited (TB, BA, d2..dN) output block.

`sweep_project_pipelined` is the DOUBLE-BUFFERED variant of the project
schedule (plan `pipeline='double'`): the d1 grid axis moves inside the
kernel as a fori_loop and the two streamed operands — the input block and
the d1-tiled leading core — are prefetched into a second VMEM slot with
explicit `pltpu.make_async_copy` DMAs while the current tile contracts on
the MXU, so per-tile transfers overlap compute instead of serializing per
grid step. The trailing cores keep their BlockSpec residency (their index
depends only on ik, so Pallas fetches them once per k-tile either way).
The planner accounts the second slot (`plan_contraction(pipeline=
'double')` — two slots halve the usable tile budget); analytic HBM traffic
is IDENTICAL to the serial schedule (`ops.sweep_hbm_bytes`): pipelining
buys overlap, not fewer bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _project_kernel(x_ref, *refs, steps, scale):
    core_refs, o_ref = refs[:-1], refs[-1]
    ia = pl.program_id(2)
    z = x_ref[...]                       # (TB, BA, d2..dN)
    # mode sweep: rightmost core first, rank bond carried between steps
    for spec, g_ref in zip(steps, reversed(core_refs)):
        z = jnp.einsum(spec, z, g_ref[...],
                       preferred_element_type=jnp.float32)
    y = z * scale                        # (TB, TK)

    @pl.when(ia == 0)
    def _init():
        o_ref[...] = y

    @pl.when(ia != 0)
    def _acc():
        o_ref[...] += y


def _reconstruct_kernel(y_ref, *refs, steps, scale):
    core_refs, o_ref = refs[:-1], refs[-1]
    m_steps, h_spec, out_spec = steps
    ik = pl.program_id(2)
    # fold the trailing cores into the batch-independent transfer block m
    m = core_refs[-1][...]
    if m_steps[0] is not None:           # CP layout transpose; None for TT
        m = jnp.einsum(m_steps[0], m)
    for spec, g_ref in zip(m_steps[1:], reversed(core_refs[1:-1])):
        m = jnp.einsum(spec, g_ref[...], m,
                       preferred_element_type=jnp.float32)
    h = jnp.einsum(h_spec, y_ref[...], core_refs[0][...],
                   preferred_element_type=jnp.float32)
    out = jnp.einsum(out_spec, h, m,
                     preferred_element_type=jnp.float32) * scale

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = out

    @pl.when(ik != 0)
    def _acc():
        o_ref[...] += out


def _imap(*pattern):
    """Index map selecting grid axes by position (`int`) or pinning 0
    (`None`) — replaces the per-arity lambdas of the order-3 kernels."""
    def f(i0, i1, i2):
        prog = (i0, i1, i2)
        return tuple(prog[p] if p is not None else 0 for p in pattern)
    return f


def _core_specs(cores, tk, ba, *, lead_pos, k_pos):
    """BlockSpecs for the cores: the leading core is tiled on its mode axis
    (it rides the d1 grid axis at `lead_pos`); the rest are full-size per
    k-tile (grid axis `k_pos`) so they stay VMEM-resident across it."""
    specs = [pl.BlockSpec((tk, ba, cores[0].shape[2]),
                          _imap(k_pos, lead_pos, None))]
    for g in cores[1:]:
        specs.append(pl.BlockSpec((tk,) + g.shape[1:],
                                  _imap(k_pos, *([None] * (g.ndim - 1)))))
    return specs


@functools.partial(jax.jit, static_argnames=("steps", "tk", "tb", "ba",
                                             "scale", "interpret"))
def sweep_project(x: jnp.ndarray, *cores: jnp.ndarray, steps, tk: int,
                  tb: int, ba: int, scale: float,
                  interpret: bool) -> jnp.ndarray:
    b, d1 = x.shape[:2]
    trail = x.shape[2:]
    k = cores[0].shape[0]
    assert len(cores) == x.ndim - 1 and len(steps) == len(cores)
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (k // tk, b // tb, d1 // ba)
    in_specs = [pl.BlockSpec((tb, ba) + trail,
                             _imap(1, 2, *([None] * len(trail))))]
    in_specs += _core_specs(cores, tk, ba, lead_pos=2, k_pos=0)
    return pl.pallas_call(
        functools.partial(_project_kernel, steps=steps, scale=scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, tk), _imap(1, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, *cores)


def _project_pipelined_kernel(x_hbm, c0_hbm, *refs, steps, scale, na, tk,
                              tb, ba, trail, r0):
    core_refs, o_ref = refs[:-1], refs[-1]
    ik = pl.program_id(0)
    ib = pl.program_id(1)

    def body(xs, cs, sems):
        # slot s of xs/cs holds d1-tile i with s == i % 2; sems[0] guards
        # the input-block copies, sems[1] the leading-core copies
        def x_dma(slot, i):
            return pltpu.make_async_copy(
                x_hbm.at[pl.ds(ib * tb, tb), pl.ds(i * ba, ba)],
                xs.at[slot], sems.at[0, slot])

        def c_dma(slot, i):
            return pltpu.make_async_copy(
                c0_hbm.at[pl.ds(ik * tk, tk), pl.ds(i * ba, ba)],
                cs.at[slot], sems.at[1, slot])

        x_dma(0, 0).start()              # warm-up: tile 0 into slot 0
        c_dma(0, 0).start()

        def step(i, acc):
            slot = jax.lax.rem(i, 2)
            nxt = jax.lax.rem(i + 1, 2)

            @pl.when(i + 1 < na)
            def _prefetch():             # next tile streams during compute
                x_dma(nxt, i + 1).start()
                c_dma(nxt, i + 1).start()

            x_dma(slot, i).wait()
            c_dma(slot, i).wait()
            z = xs[slot]
            for spec, g_ref in zip(steps[:-1], reversed(core_refs)):
                z = jnp.einsum(spec, z, g_ref[...],
                               preferred_element_type=jnp.float32)
            z = jnp.einsum(steps[-1], z, cs[slot],
                           preferred_element_type=jnp.float32)
            return acc + z

        acc = jax.lax.fori_loop(0, na, step,
                                jnp.zeros((tb, tk), jnp.float32))
        o_ref[...] = acc * scale

    pl.run_scoped(body,
                  xs=pltpu.VMEM((2, tb, ba) + trail, jnp.float32),
                  cs=pltpu.VMEM((2, tk, ba, r0), jnp.float32),
                  sems=pltpu.SemaphoreType.DMA((2, 2)))


@functools.partial(jax.jit, static_argnames=("steps", "tk", "tb", "ba",
                                             "scale", "interpret"))
def sweep_project_pipelined(x: jnp.ndarray, *cores: jnp.ndarray, steps,
                            tk: int, tb: int, ba: int, scale: float,
                            interpret: bool) -> jnp.ndarray:
    """Double-buffered project sweep: same contraction, overlapped streams.

    Identical contract to `sweep_project` (padded operands, same einsum
    program, same output) laid out as grid = (k/TK, B/TB) with the d1 axis
    swept by an in-kernel fori_loop: the input block and the leading-core
    tile live in `memory_space=ANY` and are double-buffered into VMEM
    scratch by explicit DMAs, prefetching tile i+1 while tile i contracts.
    """
    b, d1 = x.shape[:2]
    trail = x.shape[2:]
    k = cores[0].shape[0]
    r0 = cores[0].shape[2]
    assert len(cores) == x.ndim - 1 and len(steps) == len(cores)
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (k // tk, b // tb)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),   # x: manual DMA
                pl.BlockSpec(memory_space=pltpu.ANY)]   # leading core
    for g in cores[1:]:
        in_specs.append(pl.BlockSpec((tk,) + g.shape[1:],
                                     _imap2(0, *([None] * (g.ndim - 1)))))
    return pl.pallas_call(
        functools.partial(_project_pipelined_kernel, steps=steps,
                          scale=scale, na=d1 // ba, tk=tk, tb=tb, ba=ba,
                          trail=trail, r0=r0),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, tk), _imap2(1, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, *cores)


def _imap2(*pattern):
    """`_imap` over the 2-axis (ik, ib) pipelined grid."""
    def f(i0, i1):
        prog = (i0, i1)
        return tuple(prog[p] if p is not None else 0 for p in pattern)
    return f


@functools.partial(jax.jit, static_argnames=("steps", "trail", "tk", "tb",
                                             "ba", "scale", "interpret"))
def sweep_reconstruct(y: jnp.ndarray, *cores: jnp.ndarray, steps,
                      trail: tuple[int, ...], tk: int, tb: int, ba: int,
                      scale: float, interpret: bool) -> jnp.ndarray:
    b, k = y.shape
    d1 = cores[0].shape[1]
    assert len(trail) == len(cores) - 1
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (b // tb, d1 // ba, k // tk)
    in_specs = [pl.BlockSpec((tb, tk), _imap(0, 2))]
    in_specs += _core_specs(cores, tk, ba, lead_pos=1, k_pos=2)
    return pl.pallas_call(
        functools.partial(_reconstruct_kernel, steps=steps, scale=scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, ba) + trail,
                               _imap(0, 1, *([None] * len(trail)))),
        out_shape=jax.ShapeDtypeStruct((b, d1) + trail, jnp.float32),
        interpret=interpret,
    )(y, *cores)
