"""Carry-sweep contraction planner for structured (TT/CP-format) inputs.

This is the structured-input counterpart of `repro.kernels.ops.plan_contraction`
(which plans the DENSE-input mode sweep): instead of streaming a dense
`(B, d1..dN)` block and peeling one mode per step, the carry sweep contracts
one mode of the OPERATOR against the same mode of the INPUT's compressed
representation, carrying a small `(TB, TK, R_op·R_in)` bond state between
steps — the paper's "project without ever densifying" formulation
(Sec. 4.1; Feng et al.'s TT-input carry sweep; Iwen et al.'s modewise maps
on compressed inputs). Cost is O(k N d R R~ (R + R~)) per item instead of
the dense path's O(k R d^N) (`repro.core.theory.flops_project_struct`).

All FOUR structured pairings share one program shape — a flat tuple of
two-operand einsum steps `(dst, spec, src_a, src_b)` with sources in
{'c' (carry), 't' (temp), 'g<n>' (operator core/factor n), 'x<n>' (input
core/factor n)} — emitted by `_carry_program` for any static order
2 <= N <= `MAX_ORDER`:

  op   input  per-mode carry update                       carry axes
  tt x tt     c,g -> t;  t,x -> c                          (b, k, R, R~)
  tt x cp     c,g -> t;  t,a -> c                          (b, k, R, R~)
  cp x tt     c,x -> t;  t,f -> c                          (b, k, R, R~)
  cp x cp     f,a -> t;  c * t (Hadamard on the bond)      (b, k, R, R~)

The program is static (strings), so it participates in the jit cache key
and each (op_family, in_family, order, tiling) compiles exactly once.
`plan_carry_sweep` additionally budgets VMEM — operator cores per k-tile,
input cores per batch-tile, the carry/temp peak, and the `(TB, TK)` output
block — and shrinks the batch tile first (TK=128 keeps k on the lane axis),
then the k tile, mirroring the dense project planner.
"""
from __future__ import annotations

import dataclasses

from ..ops import (MAX_ORDER, VMEM_BUDGET_BYTES, _lane_tile, _pow2_at_most,
                   validate_pipeline)

_FAMILIES = ("tt", "cp")


def _require_family(name: str, value: str) -> None:
    if value not in _FAMILIES:
        raise ValueError(f"unknown {name} {value!r}; expected {_FAMILIES}")


def _carry_program(op_family: str, in_family: str, order: int) -> tuple:
    """The einsum carry program for one (operator, input) family pairing.

    Step letters are local to each spec: b batch, k sketch row, d the mode
    being contracted, u/v the operator TT bond (in/out), e/f the input TT
    bond (in/out), r the operator CP component, p the input CP component.
    Operator operands use the squeezed kernel layouts
    (`ops.tt_cores_squeezed` / `op.factors`); input operands the squeezed
    batched layouts (TT: (B, d1, R~), (B, R~, d, R~), (B, R~, dN); CP:
    (B, d, R~) with weights folded into factor 0).
    """
    _require_family("operator family", op_family)
    _require_family("input family", in_family)
    if not 2 <= order <= MAX_ORDER:
        raise ValueError(
            f"carry-sweep kernels need 2 <= order <= {MAX_ORDER}, "
            f"got {order}")
    steps: list[tuple] = []
    last = order - 1
    if op_family == "tt" and in_family == "tt":
        steps.append(("c", "kdu,bde->bkue", "g0", "x0"))
        for n in range(1, last):
            steps.append(("t", "bkue,kudv->bkedv", "c", f"g{n}"))
            steps.append(("c", "bkedv,bedf->bkvf", "t", f"x{n}"))
        steps.append(("t", "bkue,kud->bked", "c", f"g{last}"))
        steps.append(("c", "bked,bed->bk", "t", f"x{last}"))
    elif op_family == "tt" and in_family == "cp":
        steps.append(("c", "kdu,bdp->bkup", "g0", "x0"))
        for n in range(1, last):
            steps.append(("t", "bkup,kudv->bkpdv", "c", f"g{n}"))
            steps.append(("c", "bkpdv,bdp->bkvp", "t", f"x{n}"))
        steps.append(("t", "bkup,kud->bkpd", "c", f"g{last}"))
        steps.append(("c", "bkpd,bdp->bk", "t", f"x{last}"))
    elif op_family == "cp" and in_family == "tt":
        steps.append(("c", "kdr,bde->bkre", "g0", "x0"))
        for n in range(1, last):
            steps.append(("t", "bkre,bedf->bkrdf", "c", f"x{n}"))
            steps.append(("c", "bkrdf,kdr->bkrf", "t", f"g{n}"))
        steps.append(("t", "bkre,bed->bkrd", "c", f"x{last}"))
        steps.append(("c", "bkrd,kdr->bk", "t", f"g{last}"))
    else:  # cp x cp: per-mode Hadamard on the (r, p) bond
        steps.append(("c", "kdr,bdp->bkrp", "g0", "x0"))
        for n in range(1, last):
            steps.append(("t", "kdr,bdp->bkrp", f"g{n}", f"x{n}"))
            steps.append(("c", "bkrp,bkrp->bkrp", "c", "t"))
        steps.append(("t", "kdr,bdp->bkrp", f"g{last}", f"x{last}"))
        steps.append(("c", "bkrp,bkrp->bk", "c", "t"))
    return tuple(steps)


@dataclasses.dataclass(frozen=True)
class CarryPlan:
    """A fully-resolved carry-sweep schedule for one structured launch.

    `program` is the static einsum step tuple (`_carry_program`) the kernel
    in `carry.py` executes verbatim. `vmem_bytes` is the accounted
    per-instance footprint at the chosen `(tk, tb)` tiles.
    """

    op_family: str
    in_family: str
    k: int
    b: int
    dims: tuple[int, ...]
    r_op: int
    r_in: int
    tk: int
    tb: int
    program: tuple
    vmem_bytes: int
    pipeline: str = "serial"

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def grid(self) -> tuple[int, ...]:
        """Grid for the padded problem: k-tile OUTERMOST (the operator
        cores — indexed only by ik — stay VMEM-resident while the whole
        batch of structured inputs streams through), batch tile inner.
        Under pipeline='double' the batch axis moves inside the kernel
        (double-buffered input-core tiles), so the launch grid is (nk,)."""
        nk = -(-self.k // self.tk)
        if self.pipeline == "double":
            return (nk,)
        return (nk, -(-self.b // self.tb))

    @property
    def carry_bytes(self) -> int:
        """Peak bytes of the carried bond state for the FULL problem —
        b * k * R_op * R_in floats, the `(B, k, R_op·R_in)` carry that
        replaces the dense path's (B, k, d2..dN) sweep intermediates."""
        return 4 * self.b * self.k * self.r_op * self.r_in


def _core_elems(family: str, dims: tuple[int, ...], rank: int) -> int:
    """Per-row (k or batch) element count of a squeezed core/factor list."""
    if family == "tt":
        if len(dims) == 1:
            return dims[0]
        return (dims[0] * rank + sum(rank * d * rank for d in dims[1:-1])
                + rank * dims[-1])
    return rank * sum(dims)


def plan_carry_sweep(op_family: str, in_family: str, k: int, b: int,
                     dims: tuple[int, ...], r_op: int, r_in: int, *,
                     budget: int = VMEM_BUDGET_BYTES,
                     pipeline: str = "serial") -> CarryPlan:
    """Plan a carry-sweep kernel launch for static order N = len(dims).

    Accounts every per-instance VMEM buffer — the per-k-tile operator
    cores, the per-batch-tile input cores, the carry + temp peak of the
    sweep (both live simultaneously inside a step), and the `(TB, TK)`
    output block — and shrinks tiles until the footprint fits `budget`,
    batch tile first (TK=128 keeps k on the lane axis; the cores the k-tile
    pins in VMEM are what the whole schedule exists to keep resident).

    `pipeline='double'` (the double-buffered kernel) accounts a SECOND
    slot of the per-batch-tile input cores plus the full `(B, TK)` output
    block the in-kernel batch sweep writes through.
    """
    dims = tuple(int(d) for d in dims)
    program = _carry_program(op_family, in_family, len(dims))  # validates
    validate_pipeline(pipeline)
    r_op, r_in = max(1, int(r_op)), max(1, int(r_in))
    tk = _lane_tile(k)
    tb = _pow2_at_most(max(1, b), 8)
    op_elems = _core_elems(op_family, dims, r_op)
    in_elems = _core_elems(in_family, dims, r_in)
    # largest per-mode temp: the mode axis d is live between the two steps
    # of a mode update for every pairing EXCEPT cp x cp, whose temp is the
    # modeless (b, k, r, p) Hadamard operand
    temp_d = 1 if (op_family, in_family) == ("cp", "cp") else max(dims)

    def footprint(tk: int, tb: int) -> int:
        carry = tb * tk * r_op * r_in
        temp = tb * tk * r_op * r_in * temp_d
        if pipeline == "double":
            # second input-core slot + the full-batch output block the
            # in-kernel sweep writes tile by tile
            out = -(-b // tb) * tb * tk
            extra = tb * in_elems
        else:
            out = tb * tk
            extra = 0
        return 4 * (tk * op_elems + tb * in_elems + carry + temp + out
                    + extra)

    for axis in ("tb", "tk"):
        while footprint(tk, tb) > budget:
            if axis == "tb" and tb > 1:
                tb //= 2
            elif axis == "tk" and tk > 8:
                tk //= 2
            else:
                break
    return CarryPlan(op_family=op_family, in_family=in_family, k=k, b=b,
                     dims=dims, r_op=r_op, r_in=r_in, tk=tk, tb=tb,
                     program=program, vmem_bytes=footprint(tk, tb),
                     pipeline=pipeline)


def struct_hbm_bytes(plan: CarryPlan) -> int:
    """Grid-accurate analytic HBM traffic of one carry-sweep launch.

    Follows the BlockSpec index maps in `carry.py`: operator cores are
    indexed only by the outermost k-tile axis (fetched once each), input
    cores by the batch axis (re-streamed once per k-tile), and each
    `(TB, TK)` output block is written exactly once.
    """
    nk = -(-plan.k // plan.tk)
    op_bytes = 4 * plan.k * _core_elems(plan.op_family, plan.dims, plan.r_op)
    in_bytes = 4 * plan.b * _core_elems(plan.in_family, plan.dims, plan.r_in)
    out_bytes = 4 * plan.b * plan.k
    return op_bytes + nk * in_bytes + out_bytes


__all__ = ["CarryPlan", "plan_carry_sweep", "struct_hbm_bytes"]
