"""Compressed-domain sketching subsystem: batched structured-input (TT/CP)
projections via carry-sweep Pallas kernels.

The paper's headline efficiency claim is that f_TT(R)/f_CP(R) "can be
applied efficiently when the inputs are low rank tensors given in the CP
or TT format" — this package is that regime's hot path. All FOUR
(operator, input) pairings — TT x TT, TT x CP, CP x TT, CP x CP — share one
carry-sweep schedule at any order 2..MAX_ORDER, batched over the inputs in
ONE launch (replacing the retired order-3-only, unbatched `tt_dot`):

  plan.py  — `plan_carry_sweep` / `CarryPlan`: the einsum carry program +
             VMEM-budgeted (tk, tb) tiles + the (k-outermost, batch) grid.
  carry.py — the Pallas kernel executing the program verbatim.
  ref.py   — order-generic batched einsum oracles (also the XLA path).
  ops.py   — `struct_project`: layout/padding/jit wrapper, single + batched.

Inputs arrive as `repro.core.formats` containers (`TTTensor` / `CPTensor`
or the batched `BatchedTTTensor` / `BatchedCPTensor`); `rp.project` routes
them here under the standard backend policy.
"""
from .ops import STRUCT_TYPES, struct_project, struct_rank
from .plan import CarryPlan, plan_carry_sweep, struct_hbm_bytes
from . import ref

__all__ = ["CarryPlan", "STRUCT_TYPES", "plan_carry_sweep", "ref",
           "struct_hbm_bytes", "struct_project", "struct_rank"]
