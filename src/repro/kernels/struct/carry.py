"""Pallas TPU kernel: batched structured-input carry-sweep projection.

Executes the einsum carry program emitted by `plan.plan_carry_sweep`
verbatim, for all four (operator, input) family pairings at any static
order 2..MAX_ORDER — the compressed-domain replacement for the retired
order-3-only `tt_dot` kernel.

Schedule:

* grid = (k/TK, B/TB) — k-tile OUTERMOST: the operator cores' block index
  depends only on ik, so one k-tile's cores are fetched once and stay
  VMEM-resident while every batch tile of structured inputs streams
  through them (the same core-residency argument as the dense projection
  sweep, with the batch of inputs taking the place of the dense bucket
  stream). The input cores' index depends only on ib.
* No accumulation axis: unlike the dense sweep there is no d1 grid axis —
  every mode is contracted in full inside the instance, carrying the
  (TB, TK, R_op·R_in) bond state between steps — so each (TB, TK) output
  block is written exactly once.
* TK=128 puts k on the lane axis; every carry step is then a TK-batched
  small contraction (MXU for the bond updates, VPU for the CPxCP
  Hadamard). The JLT 1/sqrt(k) scaling is FUSED into the epilogue.

Padding contract (enforced by `ops.struct_project`): the k axis of every
operator core is zero-padded to TK (zero rows project to zero and are
sliced away), the batch axis of every input core to TB (zero cores
contribute zero rows). Bond/mode axes are never tiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _carry_kernel(*refs, program, n_op, scale):
    op_refs = refs[:n_op]
    x_refs = refs[n_op:-1]
    o_ref = refs[-1]
    env = {}

    def operand(name):
        if name in env:                       # 'c' or 't'
            return env[name]
        idx = int(name[1:])
        return (op_refs[idx] if name[0] == "g" else x_refs[idx])[...]

    for dst, spec, a, b in program:
        env[dst] = jnp.einsum(spec, operand(a), operand(b),
                              preferred_element_type=jnp.float32)
    o_ref[...] = env["c"] * scale             # (TB, TK)


def _imap2(*pattern):
    """Index map over the 2-axis (ik, ib) grid: select an axis by position
    or pin 0 (`None`) — the block stays put along that operand axis."""
    def f(i0, i1):
        prog = (i0, i1)
        return tuple(prog[p] if p is not None else 0 for p in pattern)
    return f


@functools.partial(jax.jit, static_argnames=("n_op", "program", "tk", "tb",
                                             "scale", "interpret"))
def carry_sweep_project(*cores: jnp.ndarray, n_op: int, program,
                        tk: int, tb: int, scale: float,
                        interpret: bool) -> jnp.ndarray:
    """ONE launch projecting a whole batch of structured inputs.

    cores = (*op_cores, *in_cores): op cores lead with the (padded) k axis,
    input cores lead with the (padded) batch axis; `n_op` splits the two
    groups. Requires k % tk == 0 and B % tb == 0. Returns (B, k) float32.
    """
    op_cores, in_cores = cores[:n_op], cores[n_op:]
    k = op_cores[0].shape[0]
    b = in_cores[0].shape[0]
    assert len(op_cores) == len(in_cores), (len(op_cores), len(in_cores))
    assert k % tk == 0 and b % tb == 0, (k, tk, b, tb)
    grid = (k // tk, b // tb)
    in_specs = [pl.BlockSpec((tk,) + g.shape[1:],
                             _imap2(0, *([None] * (g.ndim - 1))))
                for g in op_cores]
    in_specs += [pl.BlockSpec((tb,) + x.shape[1:],
                              _imap2(1, *([None] * (x.ndim - 1))))
                 for x in in_cores]
    return pl.pallas_call(
        functools.partial(_carry_kernel, program=program, n_op=n_op,
                          scale=scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, tk), _imap2(1, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(*cores)
