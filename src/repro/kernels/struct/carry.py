"""Pallas TPU kernel: batched structured-input carry-sweep projection.

Executes the einsum carry program emitted by `plan.plan_carry_sweep`
verbatim, for all four (operator, input) family pairings at any static
order 2..MAX_ORDER — the compressed-domain replacement for the retired
order-3-only `tt_dot` kernel.

Schedule:

* grid = (k/TK, B/TB) — k-tile OUTERMOST: the operator cores' block index
  depends only on ik, so one k-tile's cores are fetched once and stay
  VMEM-resident while every batch tile of structured inputs streams
  through them (the same core-residency argument as the dense projection
  sweep, with the batch of inputs taking the place of the dense bucket
  stream). The input cores' index depends only on ib.
* No accumulation axis: unlike the dense sweep there is no d1 grid axis —
  every mode is contracted in full inside the instance, carrying the
  (TB, TK, R_op·R_in) bond state between steps — so each (TB, TK) output
  block is written exactly once.
* TK=128 puts k on the lane axis; every carry step is then a TK-batched
  small contraction (MXU for the bond updates, VPU for the CPxCP
  Hadamard). The JLT 1/sqrt(k) scaling is FUSED into the epilogue.

Padding contract (enforced by `ops.struct_project`): the k axis of every
operator core is zero-padded to TK (zero rows project to zero and are
sliced away), the batch axis of every input core to TB (zero cores
contribute zero rows). Bond/mode axes are never tiled.

`carry_sweep_project_pipelined` is the DOUBLE-BUFFERED variant (plan
`pipeline='double'`): grid = (k/TK,) with the batch axis swept by an
in-kernel fori_loop — the per-batch-tile input cores are prefetched into a
second VMEM slot with explicit `pltpu.make_async_copy` DMAs while the
current batch tile's carry program runs, so input transfers overlap the
bond updates. Operator cores keep their BlockSpec residency per k-tile;
the `(B, TK)` output block is written one batch tile at a time. The
planner accounts the second input slot and the full-batch output block
(`plan.plan_carry_sweep(pipeline='double')`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _carry_kernel(*refs, program, n_op, scale):
    op_refs = refs[:n_op]
    x_refs = refs[n_op:-1]
    o_ref = refs[-1]
    env = {}

    def operand(name):
        if name in env:                       # 'c' or 't'
            return env[name]
        idx = int(name[1:])
        return (op_refs[idx] if name[0] == "g" else x_refs[idx])[...]

    for dst, spec, a, b in program:
        env[dst] = jnp.einsum(spec, operand(a), operand(b),
                              preferred_element_type=jnp.float32)
    o_ref[...] = env["c"] * scale             # (TB, TK)


def _imap2(*pattern):
    """Index map over the 2-axis (ik, ib) grid: select an axis by position
    or pin 0 (`None`) — the block stays put along that operand axis."""
    def f(i0, i1):
        prog = (i0, i1)
        return tuple(prog[p] if p is not None else 0 for p in pattern)
    return f


@functools.partial(jax.jit, static_argnames=("n_op", "program", "tk", "tb",
                                             "scale", "interpret"))
def carry_sweep_project(*cores: jnp.ndarray, n_op: int, program,
                        tk: int, tb: int, scale: float,
                        interpret: bool) -> jnp.ndarray:
    """ONE launch projecting a whole batch of structured inputs.

    cores = (*op_cores, *in_cores): op cores lead with the (padded) k axis,
    input cores lead with the (padded) batch axis; `n_op` splits the two
    groups. Requires k % tk == 0 and B % tb == 0. Returns (B, k) float32.
    """
    op_cores, in_cores = cores[:n_op], cores[n_op:]
    k = op_cores[0].shape[0]
    b = in_cores[0].shape[0]
    assert len(op_cores) == len(in_cores), (len(op_cores), len(in_cores))
    assert k % tk == 0 and b % tb == 0, (k, tk, b, tb)
    grid = (k // tk, b // tb)
    in_specs = [pl.BlockSpec((tk,) + g.shape[1:],
                             _imap2(0, *([None] * (g.ndim - 1))))
                for g in op_cores]
    in_specs += [pl.BlockSpec((tb,) + x.shape[1:],
                              _imap2(1, *([None] * (x.ndim - 1))))
                 for x in in_cores]
    return pl.pallas_call(
        functools.partial(_carry_kernel, program=program, n_op=n_op,
                          scale=scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, tk), _imap2(1, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(*cores)


def _carry_pipelined_kernel(*refs, program, n_op, scale, nb, tb, in_shapes):
    op_refs = refs[:n_op]
    x_hbm = refs[n_op:-1]                 # full input cores, manual DMA
    o_ref = refs[-1]                      # (B, TK) block for this k-tile

    def body(sems, **bufs):
        xs = [bufs[f"x{j}"] for j in range(len(x_hbm))]

        def dma(j, slot, i):
            return pltpu.make_async_copy(
                x_hbm[j].at[pl.ds(i * tb, tb)], xs[j].at[slot],
                sems.at[j, slot])

        for j in range(len(x_hbm)):       # warm-up: batch tile 0, slot 0
            dma(j, 0, 0).start()

        def step(i, carry):
            slot = jax.lax.rem(i, 2)
            nxt = jax.lax.rem(i + 1, 2)

            @pl.when(i + 1 < nb)
            def _prefetch():              # next tile streams during compute
                for j in range(len(x_hbm)):
                    dma(j, nxt, i + 1).start()

            for j in range(len(x_hbm)):
                dma(j, slot, i).wait()
            env = {}

            def operand(name):
                if name in env:           # 'c' or 't'
                    return env[name]
                idx = int(name[1:])
                return (op_refs[idx][...] if name[0] == "g"
                        else xs[idx][slot])

            for dst, spec, a, b in program:
                env[dst] = jnp.einsum(spec, operand(a), operand(b),
                                      preferred_element_type=jnp.float32)
            o_ref[pl.ds(i * tb, tb), :] = env["c"] * scale
            return carry

        jax.lax.fori_loop(0, nb, step, 0)

    pl.run_scoped(body,
                  sems=pltpu.SemaphoreType.DMA((len(x_hbm), 2)),
                  **{f"x{j}": pltpu.VMEM((2, tb) + shp, jnp.float32)
                     for j, shp in enumerate(in_shapes)})


@functools.partial(jax.jit, static_argnames=("n_op", "program", "tk", "tb",
                                             "scale", "interpret"))
def carry_sweep_project_pipelined(*cores: jnp.ndarray, n_op: int, program,
                                  tk: int, tb: int, scale: float,
                                  interpret: bool) -> jnp.ndarray:
    """Double-buffered carry sweep: same contraction, overlapped streams.

    Identical contract to `carry_sweep_project`, laid out as grid = (k/TK,)
    with the batch axis swept by an in-kernel fori_loop: the input cores
    live in `memory_space=ANY` and are double-buffered into VMEM scratch
    by explicit DMAs, prefetching batch tile i+1 while tile i's carry
    program runs against the k-tile-resident operator cores.
    """
    op_cores, in_cores = cores[:n_op], cores[n_op:]
    k = op_cores[0].shape[0]
    b = in_cores[0].shape[0]
    assert len(op_cores) == len(in_cores), (len(op_cores), len(in_cores))
    assert k % tk == 0 and b % tb == 0, (k, tk, b, tb)
    in_specs = [pl.BlockSpec((tk,) + g.shape[1:],
                             _imap1(0, *([None] * (g.ndim - 1))))
                for g in op_cores]
    in_specs += [pl.BlockSpec(memory_space=pltpu.ANY) for _ in in_cores]
    return pl.pallas_call(
        functools.partial(_carry_pipelined_kernel, program=program,
                          n_op=n_op, scale=scale, nb=b // tb, tb=tb,
                          in_shapes=tuple(x.shape[1:] for x in in_cores)),
        grid=(k // tk,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b, tk), _imap1(None, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(*cores)


def _imap1(*pattern):
    """Index map over the 1-axis (ik,) pipelined grid."""
    def f(i0):
        prog = (i0,)
        return tuple(prog[p] if p is not None else 0 for p in pattern)
    return f
