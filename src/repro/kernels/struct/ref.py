"""Pure-jnp oracles for the carry-sweep kernels: batched structured-input
projections for all four (operator, input) family pairings, order-generic
(N >= 2). Deliberately straightforward einsum chains — the Pallas kernels
must match these to ~1e-5 in f32, and these must match the dense path
(`op.project(x.full())`) exactly up to accumulation order.

Layouts match the kernel layouts:
  TT-RP cores      g1 (k, d1, R),  interior (k, R, d_n, R),  gN (k, R, dN)
  CP-RP factors    f_n (k, d_n, R)
  TT input cores   x1 (B, d1, R~), interior (B, R~, d_n, R~), xN (B, R~, dN)
  CP input factors a_n (B, d_n, R~)   (weights already folded into a_1)

The 1/sqrt(k) JLT scaling is applied by `ops.struct_project`, NOT here
(kernels and refs compute the raw contraction so accumulation error is
comparable).
"""
from __future__ import annotations

import jax.numpy as jnp


def tt_tt_ref(op_cores, in_cores) -> jnp.ndarray:
    """y[b, i] = < <<G_i^1..G_i^N>>, <<X_b^1..X_b^N>> >, carry (b,k,R,R~)."""
    c = jnp.einsum("kdu,bde->bkue", op_cores[0], in_cores[0])
    for g, x in zip(op_cores[1:-1], in_cores[1:-1]):
        t = jnp.einsum("bkue,kudv->bkedv", c, g)
        c = jnp.einsum("bkedv,bedf->bkvf", t, x)
    t = jnp.einsum("bkue,kud->bked", c, op_cores[-1])
    return jnp.einsum("bked,bed->bk", t, in_cores[-1])


def tt_cp_ref(op_cores, in_factors) -> jnp.ndarray:
    """TT operator x CP-format input; carry (b, k, R, R~)."""
    c = jnp.einsum("kdu,bdp->bkup", op_cores[0], in_factors[0])
    for g, a in zip(op_cores[1:-1], in_factors[1:-1]):
        t = jnp.einsum("bkup,kudv->bkpdv", c, g)
        c = jnp.einsum("bkpdv,bdp->bkvp", t, a)
    t = jnp.einsum("bkup,kud->bkpd", c, op_cores[-1])
    return jnp.einsum("bkpd,bdp->bk", t, in_factors[-1])


def cp_tt_ref(op_factors, in_cores) -> jnp.ndarray:
    """CP operator x TT-format input; carry (b, k, R, R~)."""
    c = jnp.einsum("kdr,bde->bkre", op_factors[0], in_cores[0])
    for f, x in zip(op_factors[1:-1], in_cores[1:-1]):
        t = jnp.einsum("bkre,bedf->bkrdf", c, x)
        c = jnp.einsum("bkrdf,kdr->bkrf", t, f)
    t = jnp.einsum("bkre,bed->bkrd", c, in_cores[-1])
    return jnp.einsum("bkrd,kdr->bk", t, op_factors[-1])


def cp_cp_ref(op_factors, in_factors) -> jnp.ndarray:
    """CP operator x CP-format input: per-mode Hadamard on the (r, p) bond."""
    c = jnp.einsum("kdr,bdp->bkrp", op_factors[0], in_factors[0])
    for f, a in zip(op_factors[1:-1], in_factors[1:-1]):
        c = c * jnp.einsum("kdr,bdp->bkrp", f, a)
    t = jnp.einsum("kdr,bdp->bkrp", op_factors[-1], in_factors[-1])
    return jnp.einsum("bkrp,bkrp->bk", c, t)


REFS = {("tt", "tt"): tt_tt_ref, ("tt", "cp"): tt_cp_ref,
        ("cp", "tt"): cp_tt_ref, ("cp", "cp"): cp_cp_ref}
