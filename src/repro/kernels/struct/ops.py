"""Public wrappers around the carry-sweep kernels: layout + padding + jit.

`struct_project(op, x)` projects structured input(s) — `TTTensor`,
`CPTensor`, or their batched containers — with a TT or CP operator in ONE
kernel launch, covering all four (operator, input) family pairings at any
order 2..MAX_ORDER. The wrapper:

  * normalizes the input to a batched container (a single tensor becomes a
    B=1 batch; the batch axis is stripped again on return),
  * converts to the kernel layouts (squeezed TT boundary bonds on both the
    operator and the input; CP weights folded into the first factor — a
    scalar reweighting of one factor, exact by multilinearity),
  * pads the operator's k axis to the k tile and the input's batch axis to
    the batch tile (zero rows/items are inert and sliced away),
  * plans the sweep (`plan.plan_carry_sweep`) and launches
    `carry.carry_sweep_project` with the fused 1/sqrt(k) epilogue.

With `use_kernel=False` (or for orders outside kernel support) the same
layouts run through the batched einsum oracles in `ref.py` — the XLA
reference path `rp.project(..., backend='xla')` uses for batched
structured inputs. Order-1 operators fall back to the dense path (a
1-core TT/CP "tensor" is its own densification).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.cp_rp import CPRP
from repro.core.formats import (STRUCT_TYPES, BatchedCPTensor,
                                BatchedTTTensor, CPTensor, TTTensor)
from repro.core.tt_rp import TTRP

from ..ops import _pad_axis, kernel_order_supported, tt_cores_squeezed
from . import ref
from .carry import carry_sweep_project, carry_sweep_project_pipelined
from .plan import plan_carry_sweep


def _as_batched(x):
    """-> (in_family, batched container, was_batched)."""
    if isinstance(x, TTTensor):
        return "tt", BatchedTTTensor(tuple(c[None] for c in x.cores)), False
    if isinstance(x, CPTensor):
        w = None if x.weights is None else x.weights[None]
        return "cp", BatchedCPTensor(tuple(f[None] for f in x.factors),
                                     w), False
    if isinstance(x, BatchedTTTensor):
        return "tt", x, True
    if isinstance(x, BatchedCPTensor):
        return "cp", x, True
    raise TypeError(f"not a structured input: {type(x).__name__}")


def _in_operands(in_family: str, xb) -> tuple[jnp.ndarray, ...]:
    """Kernel layout of the batched input: TT boundary bonds squeezed /
    CP weights folded into factor 0."""
    if in_family == "tt":
        cores = xb.cores
        if len(cores) == 1:
            return (cores[0][:, 0, :, 0],)
        return ((cores[0][:, 0, :, :],) + tuple(cores[1:-1])
                + (cores[-1][:, :, :, 0],))
    factors = xb.factors
    if xb.weights is not None:
        factors = (factors[0] * xb.weights[:, None, :],) + tuple(factors[1:])
    return factors


def struct_rank(x) -> int:
    """Structural rank of a (batched) TT/CP input: max bond rank for TT
    (interior bonds are what the carry holds), component count for CP."""
    if isinstance(x, (TTTensor, BatchedTTTensor)):
        return max(x.ranks)
    return x.rank


def struct_project(op, x, *, interpret: bool = True,
                   use_kernel: bool = True,
                   pipeline: str = "serial") -> jnp.ndarray:
    """Project structured input(s) with a TT/CP operator, never densifying.

    x: TTTensor / CPTensor -> (k,); BatchedTTTensor / BatchedCPTensor with
    batch B -> (B, k) — ONE carry-sweep launch for the whole batch.
    `pipeline='double'` selects the double-buffered carry sweep
    (`carry.carry_sweep_project_pipelined`); same result bitwise intent,
    fp32-tolerance equivalent in practice.
    """
    if not isinstance(op, (TTRP, CPRP)):
        raise TypeError(f"struct_project needs a TT/CP operator, got "
                        f"{type(op).__name__}")
    op_family = "tt" if isinstance(op, TTRP) else "cp"
    in_family, xb, batched = _as_batched(x)
    if tuple(xb.dims) != tuple(op.in_dims):
        raise ValueError(f"input dims {tuple(xb.dims)} != operator in_dims "
                         f"{tuple(op.in_dims)}")
    k, b = op.k, xb.batch
    if op.order < 2:
        # a 1-core structured tensor IS dense; project it as such
        y = op.project(xb.full().reshape(b, *op.in_dims))
        return y if batched else y[0]
    op_cores = tt_cores_squeezed(op) if op_family == "tt" else op.factors
    in_cores = _in_operands(in_family, xb)
    ref_fn = ref.REFS[(op_family, in_family)]
    if not use_kernel or not kernel_order_supported(op.order):
        y = ref_fn(op_cores, in_cores) / jnp.sqrt(jnp.asarray(k, jnp.float32))
        return y if batched else y[0]
    plan = plan_carry_sweep(op_family, in_family, k, b, op.in_dims,
                            op.rank, struct_rank(xb), pipeline=pipeline)
    op_pad = tuple(_pad_axis(g, 0, plan.tk) for g in op_cores)
    in_pad = tuple(_pad_axis(c, 0, plan.tb) for c in in_cores)
    kernel = (carry_sweep_project_pipelined if plan.pipeline == "double"
              else carry_sweep_project)
    y = kernel(*op_pad, *in_pad, n_op=len(op_pad),
               program=plan.program, tk=plan.tk, tb=plan.tb,
               scale=1.0 / math.sqrt(k), interpret=interpret)
    y = y[:b, :k]
    return y if batched else y[0]


__all__ = ["STRUCT_TYPES", "struct_project", "struct_rank"]
