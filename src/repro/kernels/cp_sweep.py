"""Pallas TPU kernels: order-N batched dense-input CP projection + adjoint.

y[n,i] = scale * sum_r <f1[i,:,r] o f2[i,:,r] o ... o fN[i,:,r], x[n]> and
its adjoint — the CP counterparts of `tt_sweep.py`, sharing the planner
(`ops.plan_contraction`) and the grid machinery (`_sweep.py`): k-tile
outermost for project (factors VMEM-resident across the batch), k-tile
innermost for reconstruct (partials accumulate in the revisited output
block), batch grid axis, fused JLT scaling.

The CP sweep is cheaper per mode than TT (rank vectors instead of R x R
transfer cores) and its rank carry never alternates bonds — the planner's
einsum program keeps a single 'r' index through the whole sweep. For the
adjoint, the trailing factors fold into the transfer block
m[i,r,d2..dN] = f2[i,d2,r] * ... * fN[i,dN,r] (rank-wise outer product; the
first program step is the (k,dN,R)->(k,R,dN) layout transpose).

Factor layout is `op.factors` as-is: f_n (k, d_n, R).
"""
from __future__ import annotations

import jax.numpy as jnp

from ._sweep import sweep_project, sweep_reconstruct


def cp_sweep_project(x: jnp.ndarray, *factors: jnp.ndarray, steps,
                     tk: int = 128, tb: int = 4, ba: int = 8,
                     scale: float = 1.0,
                     interpret: bool = True) -> jnp.ndarray:
    """Batched order-N CP contraction; x (B, d1, ..., dN), f_n (k, d_n, R).

    Requires k%tk==0, B%tb==0, d1%ba==0; `scale` is fused into the
    epilogue. Returns (B, k) float32.
    """
    return sweep_project(x, *factors, steps=steps, tk=tk, tb=tb, ba=ba,
                         scale=scale, interpret=interpret)


def cp_sweep_reconstruct(y: jnp.ndarray, *factors: jnp.ndarray, steps,
                         tk: int = 32, tb: int = 4, ba: int = 8,
                         scale: float = 1.0,
                         interpret: bool = True) -> jnp.ndarray:
    """Batched order-N CP adjoint; y (B, k), f_n (k, d_n, R).

    `scale` is fused — pass 1/sqrt(k_logical). Returns (B, d1, ..., dN)
    float32.
    """
    trail = tuple(int(f.shape[1]) for f in factors[1:])
    return sweep_reconstruct(y, *factors, steps=steps, trail=trail, tk=tk,
                             tb=tb, ba=ba, scale=scale, interpret=interpret)
