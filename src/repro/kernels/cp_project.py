"""Pallas TPU kernel: dense-input CP random projection (order 3).

y[i] = sum_r <f1[i,:,r] o f2[i,:,r] o f3[i,:,r], x>  — same grid/accumulation
skeleton as tt_project.py (k tiled to lanes, leading mode streamed, output
block revisited for partial sums). The CP contraction is cheaper per mode
(rank vectors instead of rank x rank transfer matrices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cp_project3_kernel(x_ref, f1_ref, f2_ref, f3_ref, o_ref):
    ia = pl.program_id(1)
    x = x_ref[...]                                    # (BA, d2, d3)
    f3 = f3_ref[...]                                  # (TK, d3, R)
    z = jnp.einsum("abc,kcr->kabr", x, f3, preferred_element_type=jnp.float32)
    f2 = f2_ref[...]                                  # (TK, d2, R)
    v = jnp.einsum("kabr,kbr->kar", z, f2, preferred_element_type=jnp.float32)
    f1 = f1_ref[...]                                  # (TK, BA, R)
    y = jnp.einsum("kar,kar->k", v, f1, preferred_element_type=jnp.float32)

    @pl.when(ia == 0)
    def _init():
        o_ref[...] = y[:, None]

    @pl.when(ia != 0)
    def _acc():
        o_ref[...] += y[:, None]


@functools.partial(jax.jit, static_argnames=("tk", "ba", "interpret"))
def cp_project3(x: jnp.ndarray, f1: jnp.ndarray, f2: jnp.ndarray,
                f3: jnp.ndarray, *, tk: int = 128, ba: int = 8,
                interpret: bool = True) -> jnp.ndarray:
    """Raw contraction; x (d1,d2,d3); f_n (k, d_n, R). k%tk==0, d1%ba==0."""
    d1, d2, d3 = x.shape
    k, _, r = f1.shape
    assert f2.shape == (k, d2, r) and f3.shape == (k, d3, r)
    assert k % tk == 0 and d1 % ba == 0
    grid = (k // tk, d1 // ba)
    out = pl.pallas_call(
        _cp_project3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ba, d2, d3), lambda ik, ia: (ia, 0, 0)),
            pl.BlockSpec((tk, ba, r), lambda ik, ia: (ik, ia, 0)),
            pl.BlockSpec((tk, d2, r), lambda ik, ia: (ik, 0, 0)),
            pl.BlockSpec((tk, d3, r), lambda ik, ia: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tk, 1), lambda ik, ia: (ik, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x, f1, f2, f3)
    return out[:, 0]
