"""Pallas TPU kernel: batched dense-input CP random projection (order 3).

y[n,i] = scale * sum_r <f1[i,:,r] o f2[i,:,r] o f3[i,:,r], x[n]> — same
grid/accumulation skeleton as tt_project.py (k-tile outermost so the factors
stay VMEM-resident across the batch, batch and leading mode streamed, output
block revisited for partial sums over d1, JLT scale fused in the epilogue).
The CP contraction is cheaper per mode (rank vectors instead of rank x rank
transfer matrices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cp_project3_kernel(x_ref, f1_ref, f2_ref, f3_ref, o_ref, *, scale):
    ia = pl.program_id(2)
    x = x_ref[...]                                    # (TB, BA, d2, d3)
    f3 = f3_ref[...]                                  # (TK, d3, R)
    z = jnp.einsum("nabc,kcr->knabr", x, f3, preferred_element_type=jnp.float32)
    f2 = f2_ref[...]                                  # (TK, d2, R)
    v = jnp.einsum("knabr,kbr->knar", z, f2, preferred_element_type=jnp.float32)
    f1 = f1_ref[...]                                  # (TK, BA, R)
    y = jnp.einsum("knar,kar->nk", v, f1,
                   preferred_element_type=jnp.float32) * scale

    @pl.when(ia == 0)
    def _init():
        o_ref[...] = y

    @pl.when(ia != 0)
    def _acc():
        o_ref[...] += y


@functools.partial(jax.jit,
                   static_argnames=("tk", "tb", "ba", "scale", "interpret"))
def cp_project3(x: jnp.ndarray, f1: jnp.ndarray, f2: jnp.ndarray,
                f3: jnp.ndarray, *, tk: int = 128, tb: int = 4, ba: int = 8,
                scale: float = 1.0, interpret: bool = True) -> jnp.ndarray:
    """Batched contraction; x (B,d1,d2,d3); f_n (k,d_n,R). k%tk==0, B%tb==0,
    d1%ba==0. `scale` is fused into the epilogue. Returns (B, k) float32."""
    b, d1, d2, d3 = x.shape
    k, _, r = f1.shape
    assert f2.shape == (k, d2, r) and f3.shape == (k, d3, r)
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0
    grid = (k // tk, b // tb, d1 // ba)
    return pl.pallas_call(
        functools.partial(_cp_project3_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, ba, d2, d3), lambda ik, ib, ia: (ib, ia, 0, 0)),
            pl.BlockSpec((tk, ba, r), lambda ik, ib, ia: (ik, ia, 0)),
            pl.BlockSpec((tk, d2, r), lambda ik, ib, ia: (ik, 0, 0)),
            pl.BlockSpec((tk, d3, r), lambda ik, ib, ia: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tk), lambda ik, ib, ia: (ib, ik)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, f1, f2, f3)
