"""Pallas TPU kernel: batched TT-times-TT inner products (order 3).

y[i] = < <<G_i^1, G_i^2, G_i^3>>, <<X^1, X^2, X^3>> > for i in [k]: the
structured-input fast path of f_TT(R) (paper Sec. 4.1, O(k N d max(R,R~)^3)).

The transfer-matrix chain is tiny per step (R x Rx carries), so the TPU win
comes purely from batching k onto the lanes: the whole k-tile chain lives in
VMEM and every mode step is a (TK-batched) small matmul. Grid = (k/TK,);
all operands for a tile are loaded once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_dot3_kernel(x1_ref, x2_ref, x3_ref, g1_ref, g2_ref, g3_ref, o_ref):
    xa = x1_ref[...][0]                               # (d1, Rx)
    g1 = g1_ref[...]                                  # (TK, d1, R)
    t = jnp.einsum("kdr,de->kre", g1, xa,
                   preferred_element_type=jnp.float32)        # (TK, R, Rx)
    g2 = g2_ref[...]                                  # (TK, R, d2, R)
    x2 = x2_ref[...]                                  # (Rx, d2, Rx)
    tmp = jnp.einsum("kre,krds->keds", t, g2,
                     preferred_element_type=jnp.float32)      # (TK, Rx, d2, R)
    t = jnp.einsum("keds,edf->ksf", tmp, x2,
                   preferred_element_type=jnp.float32)        # (TK, R, Rx)
    g3 = g3_ref[...]                                  # (TK, R, d3)
    xc = x3_ref[...][:, :, 0]                         # (Rx, d3)
    y = jnp.einsum("ksf,ksd,fd->k", t, g3, xc,
                   preferred_element_type=jnp.float32)
    o_ref[...] = y[:, None]


@functools.partial(jax.jit, static_argnames=("tk", "interpret"))
def tt_dot3(x1: jnp.ndarray, x2: jnp.ndarray, x3: jnp.ndarray,
            g1: jnp.ndarray, g2: jnp.ndarray, g3: jnp.ndarray,
            *, tk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x1 (1,d1,Rx) x2 (Rx,d2,Rx) x3 (Rx,d3,1); g1 (k,d1,R) g2 (k,R,d2,R)
    g3 (k,R,d3). Raw contraction (no 1/sqrt k). k % tk == 0."""
    k, d1, r = g1.shape
    rx = x1.shape[2]
    d2, d3 = g2.shape[2], g3.shape[2]
    assert x1.shape == (1, d1, rx) and x2.shape == (rx, d2, rx)
    assert x3.shape == (rx, d3, 1) and k % tk == 0
    out = pl.pallas_call(
        _tt_dot3_kernel,
        grid=(k // tk,),
        in_specs=[
            pl.BlockSpec((1, d1, rx), lambda ik: (0, 0, 0)),
            pl.BlockSpec((rx, d2, rx), lambda ik: (0, 0, 0)),
            pl.BlockSpec((rx, d3, 1), lambda ik: (0, 0, 0)),
            pl.BlockSpec((tk, d1, r), lambda ik: (ik, 0, 0)),
            pl.BlockSpec((tk, r, d2, r), lambda ik: (ik, 0, 0, 0)),
            pl.BlockSpec((tk, r, d3), lambda ik: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tk, 1), lambda ik: (ik, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x1, x2, x3, g1, g2, g3)
    return out[:, 0]
