"""Pallas TPU kernel: batched CP adjoint reconstruction (order 3).

x_hat[n,a,b,c] = scale * sum_{i,r} y[n,i] f1[i,a,r] f2[i,b,r] f3[i,c,r]

Same grid/accumulation skeleton as tt_reconstruct.py: k-tile innermost so
per-k-tile partials accumulate in the revisited (TB, BA, d2, d3) output
block; the rank-r outer products of the two trailing factors are fused once
per instance into m[i,r,b,c] = f2[i,b,r] f3[i,c,r] and the rest is one large
(TB*BA, TK*R) x (TK*R, d2*d3) MXU contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cp_reconstruct3_kernel(y_ref, f1_ref, f2_ref, f3_ref, o_ref, *, scale):
    ik = pl.program_id(2)
    f2 = f2_ref[...]                                  # (TK, d2, R)
    f3 = f3_ref[...]                                  # (TK, d3, R)
    # rank-wise outer product of the trailing factors: (TK, R, d2, d3)
    m = jnp.einsum("kbr,kcr->krbc", f2, f3, preferred_element_type=jnp.float32)
    y = y_ref[...]                                    # (TB, TK)
    f1 = f1_ref[...]                                  # (TK, BA, R)
    h = jnp.einsum("nk,kar->nakr", y, f1, preferred_element_type=jnp.float32)
    out = jnp.einsum("nakr,krbc->nabc", h, m,
                     preferred_element_type=jnp.float32) * scale

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = out

    @pl.when(ik != 0)
    def _acc():
        o_ref[...] += out


@functools.partial(jax.jit,
                   static_argnames=("tk", "tb", "ba", "scale", "interpret"))
def cp_reconstruct3(y: jnp.ndarray, f1: jnp.ndarray, f2: jnp.ndarray,
                    f3: jnp.ndarray, *, tk: int = 32, tb: int = 4, ba: int = 8,
                    scale: float = 1.0,
                    interpret: bool = True) -> jnp.ndarray:
    """Batched adjoint; y (B,k); f_n (k,d_n,R). k%tk==0, B%tb==0, d1%ba==0.

    `scale` is fused — pass 1/sqrt(k_logical). Returns (B, d1, d2, d3) f32.
    """
    b, k = y.shape
    _, d1, r = f1.shape
    d2 = f2.shape[1]
    d3 = f3.shape[1]
    assert f1.shape == (k, d1, r) and f2.shape == (k, d2, r)
    assert f3.shape == (k, d3, r)
    assert k % tk == 0 and b % tb == 0 and d1 % ba == 0, (k, tk, b, tb, d1, ba)
    grid = (b // tb, d1 // ba, k // tk)
    return pl.pallas_call(
        functools.partial(_cp_reconstruct3_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tk), lambda ib, ia, ik: (ib, ik)),
            pl.BlockSpec((tk, ba, r), lambda ib, ia, ik: (ik, ia, 0)),
            pl.BlockSpec((tk, d2, r), lambda ib, ia, ik: (ik, 0, 0)),
            pl.BlockSpec((tk, d3, r), lambda ib, ia, ik: (ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ba, d2, d3),
                               lambda ib, ia, ik: (ib, ia, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d1, d2, d3), jnp.float32),
        interpret=interpret,
    )(y, f1, f2, f3)
