"""Pure-jnp oracles for the Pallas kernels. Deliberately straightforward
einsum chains, order-generic — the mode-sweep kernels must match these to
~1e-5 in f32 at any order N >= 2.

Layouts match the kernel layouts (`ops.tt_cores_squeezed` / `op.factors`):
  TT-RP cores:   g1 (k, d1, R), interior (k, R, d_n, R), gN (k, R, dN)
  CP-RP factors: f_n (k, d_n, R)
The 1/sqrt(k) JLT scaling is applied by ops.py, NOT here (kernels and refs
compute the raw contraction so accumulation error is comparable).
Structured-input oracles live in `struct/ref.py`.
"""
from __future__ import annotations

import jax.numpy as jnp

_MODES = "abcdefgh"


def tt_project_ref(x: jnp.ndarray, cores) -> jnp.ndarray:
    """y[i] = < <<G_i^1, ..., G_i^N>>, x >, unbatched x, squeezed cores."""
    order = len(cores)
    modes = _MODES[:order]
    z = jnp.einsum(f"{modes},ku{modes[-1]}->k{modes[:-1]}u", x, cores[-1])
    carry = "u"
    for i in range(order - 2, 0, -1):
        new = "v" if carry == "u" else "u"
        z = jnp.einsum(f"k{modes[:i + 1]}{carry},k{new}{modes[i]}{carry}"
                       f"->k{modes[:i]}{new}", z, cores[i])
        carry = new
    return jnp.einsum(f"ka{carry},ka{carry}->k", z, cores[0])


def cp_project_ref(x: jnp.ndarray, factors) -> jnp.ndarray:
    """y[i] = sum_r <f1[i,:,r] o ... o fN[i,:,r], x>, unbatched x."""
    order = len(factors)
    modes = _MODES[:order]
    z = jnp.einsum(f"{modes},k{modes[-1]}r->k{modes[:-1]}r", x, factors[-1])
    for i in range(order - 2, 0, -1):
        z = jnp.einsum(f"k{modes[:i + 1]}r,k{modes[i]}r->k{modes[:i]}r",
                       z, factors[i])
    return jnp.einsum("kar,kar->k", z, factors[0])


def tt_reconstruct_ref(y: jnp.ndarray, cores) -> jnp.ndarray:
    """x_hat[n,...] = sum_{i, bonds} y[n,i] g1[i,·] ... gN[i,·], y (B, k)."""
    w = jnp.einsum("nk,kar->nkar", y, cores[0])
    for g in cores[1:-1]:
        w = jnp.einsum("nk...r,krds->nk...ds", w, g)
    return jnp.einsum("nk...r,krd->n...d", w, cores[-1])


def cp_reconstruct_ref(y: jnp.ndarray, factors) -> jnp.ndarray:
    """x_hat[n,...] = sum_{i,r} y[n,i] f1[i,·,r] ... fN[i,·,r], y (B, k)."""
    w = jnp.einsum("nk,kar->nkar", y, factors[0])
    for f in factors[1:-1]:
        w = jnp.einsum("nk...r,kdr->nk...dr", w, f)
    return jnp.einsum("nk...r,kdr->n...d", w, factors[-1])
