"""Pure-jnp oracles for the Pallas kernels. Deliberately straightforward
einsum chains — the kernels must match these to ~1e-5 in f32.

Layouts match repro.core:
  TT-RP cores:  g1 (k, d1, R), g2 (k, R, d2, R), g3 (k, R, d3)   (order-3 case)
  CP-RP factors: f_n (k, d_n, R)
  TT input cores: x1 (1, d1, Rx), x2 (Rx, d2, Rx), x3 (Rx, d3, 1)
The 1/sqrt(k) JLT scaling is applied by ops.py, NOT here (kernels and refs
compute the raw contraction so accumulation error is comparable).
"""
from __future__ import annotations

import jax.numpy as jnp


def tt_project3_ref(x: jnp.ndarray, g1: jnp.ndarray, g2: jnp.ndarray,
                    g3: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_{abc,rs} g1[i,a,r] g2[i,r,b,s] g3[i,s,c] x[a,b,c]."""
    z = jnp.einsum("abc,ksc->kabs", x, g3)
    v = jnp.einsum("kabs,krbs->kar", z, g2)
    return jnp.einsum("kar,kar->k", v, g1)


def cp_project3_ref(x: jnp.ndarray, f1: jnp.ndarray, f2: jnp.ndarray,
                    f3: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_r <f1[i,:,r] o f2[i,:,r] o f3[i,:,r], x>."""
    z = jnp.einsum("abc,kcr->kabr", x, f3)
    v = jnp.einsum("kabr,kbr->kar", z, f2)
    return jnp.einsum("kar,kar->k", v, f1)


def tt_reconstruct3_ref(y: jnp.ndarray, g1: jnp.ndarray, g2: jnp.ndarray,
                        g3: jnp.ndarray) -> jnp.ndarray:
    """x_hat[n,a,b,c] = sum_{k,r,s} y[n,k] g1[k,a,r] g2[k,r,b,s] g3[k,s,c]."""
    w = jnp.einsum("nk,kar->nkar", y, g1)
    w = jnp.einsum("nkar,krbs->nkabs", w, g2)
    return jnp.einsum("nkabs,ksc->nabc", w, g3)


def cp_reconstruct3_ref(y: jnp.ndarray, f1: jnp.ndarray, f2: jnp.ndarray,
                        f3: jnp.ndarray) -> jnp.ndarray:
    """x_hat[n,a,b,c] = sum_{k,r} y[n,k] f1[k,a,r] f2[k,b,r] f3[k,c,r]."""
    w = jnp.einsum("nk,kar->nkar", y, f1)
    w = jnp.einsum("nkar,kbr->nkabr", w, f2)
    return jnp.einsum("nkabr,kcr->nabc", w, f3)


def tt_dot3_ref(x1: jnp.ndarray, x2: jnp.ndarray, x3: jnp.ndarray,
                g1: jnp.ndarray, g2: jnp.ndarray, g3: jnp.ndarray) -> jnp.ndarray:
    """Batched <TT_i, X_tt> via transfer matrices, order 3.

    x1 (1,d1,Rx) x2 (Rx,d2,Rx) x3 (Rx,d3,1); g as in tt_project3_ref.
    """
    xa = x1[0]                     # (d1, Rx)
    t = jnp.einsum("kdr,de->kre", g1, xa)            # (k, R, Rx)
    tmp = jnp.einsum("kre,krds->keds", t, g2)        # (k, Rx, d2, R)
    t = jnp.einsum("keds,edf->ksf", tmp, x2)         # (k, R, Rx)
    xc = x3[:, :, 0]               # (Rx, d3)
    return jnp.einsum("ksf,ksd,fd->k", t, g3, xc)
