"""LRU operator cache: (ProjectorSpec, seed) -> sampled RPOperator.

The paper's operators are a few small random cores FULLY determined by
(spec, PRNG seed) — `rp.make_projector` is deterministic in both — so a
cache hit means ZERO regeneration work and an evicted entry can always be
re-materialized bitwise-identical later. That makes an LRU keyed on the
declarative spec the entire "model registry" a sketch-serving deployment
needs: no weights on disk, no versioned artifacts, just specs.

`CacheStats` records hits / misses / evictions and the cumulative
regeneration time so the serving report can show what the cache saved.

Plans ride along: `plan_for(op, payloads)` resolves the `ExecutionPlan` a
coalesced tick will dispatch (via `rp.group_signature` — the same bucketed
shape `project_many` produces) and pins it next to the operator, so a
serve tick executes pre-planned and the engine can tag its span with the
`plan_id`. The plan itself lives in the rp layer's global plan cache;
pinning here only keeps it warm for the cached operators' lifetime.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import jax

from repro.rp import ProjectorSpec, RPOperator, make_projector


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    prewarmed: int = 0       # entries sampled by prewarm(), not by a get()
    regen_s: float = 0.0     # cumulative operator-sampling wall time

    @property
    def gets(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.gets if self.gets else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "prewarmed": self.prewarmed,
                "regen_s": self.regen_s, "hit_rate": self.hit_rate}


class OperatorCache:
    """LRU of sampled operators keyed on (ProjectorSpec, seed).

    `ProjectorSpec` is a frozen dataclass, so equality/hashing covers every
    field (family, k, dims, rank, dtype, backend) — two requests share an
    operator iff their declarative descriptions AND seed agree. Eviction is
    least-recently-USED (a `get` refreshes recency, hit or miss).
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: "OrderedDict[tuple, RPOperator]" = OrderedDict()
        self._plans: dict = {}   # plan_id -> ExecutionPlan, pinned warm

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        spec, seed = key
        return (spec, int(seed)) in self._entries

    def get(self, spec: ProjectorSpec, seed: int = 0) -> RPOperator:
        """The operator for (spec, seed): cached, or sampled-and-cached.

        A miss samples via `make_projector(spec, PRNGKey(seed))` and times
        it into `stats.regen_s`; determinism of the factory guarantees a
        re-materialized post-eviction operator equals the original bitwise.
        """
        key = (spec, int(seed))
        op = self._entries.get(key)
        if op is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return op
        self.stats.misses += 1
        t0 = time.perf_counter()
        op = make_projector(spec, jax.random.PRNGKey(int(seed)))
        self.stats.regen_s += time.perf_counter() - t0
        self._entries[key] = op
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return op

    def keys(self) -> list[tuple]:
        """Cached (spec, seed) keys, least-recently-used first."""
        return list(self._entries)

    def plan_for(self, op: RPOperator, payloads, *, backend: str = "auto"):
        """The `ExecutionPlan` a coalesced dispatch of `payloads` resolves.

        Takes the ALREADY-FETCHED operator (never calls `get` — planning
        must not perturb the hit/miss stats the serve report gates) and
        the raw lane payloads; `rp.group_signature` computes the exact
        bucketed shape `project_many` will dispatch, so the returned plan
        is the one the tick's execution hits in the rp plan cache. Pinned
        in `plans` by id so repeat lanes stay warm.
        """
        from repro import rp
        eplan = rp.plan_execution(
            op, rp.group_signature(op, payloads), backend=backend)
        self._plans[eplan.plan_id] = eplan
        return eplan

    @property
    def plans(self) -> dict:
        """plan_id -> pinned `ExecutionPlan` (see `plan_for`)."""
        return dict(self._plans)

    # -- restart warm-up: the cache's contents as a manifest of specs -----
    def manifest(self) -> list[dict]:
        """JSON-able registry of the cached operators, LRU-first.

        Each entry is {"spec": ProjectorSpec.to_dict(), "seed": int} — the
        operators themselves are never serialized; `make_projector` is
        deterministic, so the manifest is a complete description.
        """
        return [{"spec": spec.to_dict(), "seed": seed}
                for spec, seed in self._entries]

    def prewarm(self, manifest: list[dict]) -> int:
        """Re-materialize a `manifest()`'s operators bitwise-identical.

        Sampling counts into `stats.prewarmed` and `stats.regen_s`, NOT
        into misses — a prewarmed entry's first `get` is a hit, which is
        the point. Entries are inserted in manifest order (LRU-first), so
        recency survives the restart; already-cached keys just refresh.
        Returns the number of operators sampled.
        """
        sampled = 0
        for entry in manifest:
            spec = ProjectorSpec.from_dict(entry["spec"])
            key = (spec, int(entry["seed"]))
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            t0 = time.perf_counter()
            op = make_projector(spec, jax.random.PRNGKey(key[1]))
            self.stats.regen_s += time.perf_counter() - t0
            self.stats.prewarmed += 1
            sampled += 1
            self._entries[key] = op
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return sampled
