"""LRU operator cache: (ProjectorSpec, seed) -> sampled RPOperator.

The paper's operators are a few small random cores FULLY determined by
(spec, PRNG seed) — `rp.make_projector` is deterministic in both — so a
cache hit means ZERO regeneration work and an evicted entry can always be
re-materialized bitwise-identical later. That makes an LRU keyed on the
declarative spec the entire "model registry" a sketch-serving deployment
needs: no weights on disk, no versioned artifacts, just specs.

`CacheStats` records hits / misses / evictions and the cumulative
regeneration time so the serving report can show what the cache saved.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import jax

from repro.rp import ProjectorSpec, RPOperator, make_projector


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    regen_s: float = 0.0     # cumulative operator-sampling wall time

    @property
    def gets(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.gets if self.gets else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "regen_s": self.regen_s,
                "hit_rate": self.hit_rate}


class OperatorCache:
    """LRU of sampled operators keyed on (ProjectorSpec, seed).

    `ProjectorSpec` is a frozen dataclass, so equality/hashing covers every
    field (family, k, dims, rank, dtype, backend) — two requests share an
    operator iff their declarative descriptions AND seed agree. Eviction is
    least-recently-USED (a `get` refreshes recency, hit or miss).
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: "OrderedDict[tuple, RPOperator]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        spec, seed = key
        return (spec, int(seed)) in self._entries

    def get(self, spec: ProjectorSpec, seed: int = 0) -> RPOperator:
        """The operator for (spec, seed): cached, or sampled-and-cached.

        A miss samples via `make_projector(spec, PRNGKey(seed))` and times
        it into `stats.regen_s`; determinism of the factory guarantees a
        re-materialized post-eviction operator equals the original bitwise.
        """
        key = (spec, int(seed))
        op = self._entries.get(key)
        if op is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return op
        self.stats.misses += 1
        t0 = time.perf_counter()
        op = make_projector(spec, jax.random.PRNGKey(int(seed)))
        self.stats.regen_s += time.perf_counter() - t0
        self._entries[key] = op
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return op

    def keys(self) -> list[tuple]:
        """Cached (spec, seed) keys, least-recently-used first."""
        return list(self._entries)
