"""repro.serve — the sketch-serving engine (RP-as-a-service).

The JL guarantee (paper Thm 1) means a stored `(n_buckets, k)` sketch
preserves Euclidean distances, so nearest-neighbor and pairwise-similarity
queries are answered ENTIRELY in the compressed domain. This subsystem is
that workload as a serving layer on top of the kernel/dispatch stack:

  queue -> batcher -> one dispatch per tick -> sketch store -> retrieval

  * `DynamicBatcher`  — lane-keyed request queue with a max-batch /
    max-latency flush policy; heterogeneous in-flight requests (dense, TT,
    CP; rank- and length-ragged) coalesce so one tick is one
    `rp.project_many` kernel dispatch.
  * `OperatorCache`   — LRU keyed on (ProjectorSpec, seed); operators are a
    seed plus shapes, so a hit means zero regeneration (hit/miss/regen
    stats included).
  * `SketchStore`     — millions of stored k-vectors; brute-force-but-
    batched top-m retrieval via a matmul tile sweep, plus a pairwise
    endpoint, every answer carrying the Thm-1 distortion bound.
  * `SketchServer`    — the engine tying the above together (clock-explicit
    and deterministic; an async transport goes on top).
  * `synth_trace` / `replay` — the offline load generator reporting
    p50/p99 latency, batch occupancy, and cache hit-rate.

CLI driver: `python -m repro.launch.serve_rp`; quickstart:
`examples/serve_sketch.py`.
"""
from .batcher import DynamicBatcher, LaneKey, SketchRequest, structure_tag
from .cache import CacheStats, OperatorCache
from .config import ServeConfig
from .engine import SketchServer
from .loadgen import TraceEvent, replay, synth_trace
from .store import PairwiseResult, QueryResult, SketchStore

__all__ = [
    "CacheStats", "DynamicBatcher", "LaneKey", "OperatorCache",
    "PairwiseResult", "QueryResult", "ServeConfig", "SketchRequest",
    "SketchServer", "SketchStore", "TraceEvent", "replay", "structure_tag",
    "synth_trace",
]
