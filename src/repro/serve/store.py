"""Sketch store + JL similarity retrieval in the compressed domain.

The paper's Thm 1 makes a stored `(k,)` sketch a distance oracle: for any
two inputs, `f(x) - f(y) = f(x - y)` (the map is linear), and
`Var(||f(z)||^2) <= c/k * ||z||^4` with `c` the family's variance factor,
so by Chebyshev

    P( | ||f(x)-f(y)||^2 - ||x-y||^2 | >= eps * ||x-y||^2 ) <= c / (k eps^2)

i.e. with failure probability delta the squared distance between STORED
sketches estimates the true squared distance to relative error
`eps = sqrt(c / (k * delta))` — the distortion bound this store reports
alongside every result. Nearest-neighbor and pairwise-similarity queries
therefore never touch the original (possibly d^N-sized) inputs.

Retrieval is brute-force-but-batched: one `(B, k) @ (k, tile)` matmul per
tile of the store sweeps all n stored sketches (`query_tile` rows at a
time, bounding the distance intermediate), with a running top-m merge on
the host between tiles — the classic memory/recall-free baseline that JL
embeddings make cheap enough to serve millions of vectors.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.rp import ProjectorSpec


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Top-m retrieval answer with its JL error bar.

    ids   : (B, m) store ids, ascending sketch-space distance.
    dist2 : (B, m) SQUARED sketch-space distances (the JL-estimated
            squared Euclidean distances between the original inputs).
    eps   : relative error of `dist2` as an estimate of the true squared
            distance, each pair holding with failure probability <= delta
            (Thm-1 variance factor + Chebyshev; see module docstring).
    delta : the failure probability `eps` was computed at.
    """

    ids: np.ndarray
    dist2: np.ndarray
    eps: float
    delta: float

    @property
    def dist2_lo(self) -> np.ndarray:
        """Lower end of the per-pair true-squared-distance interval."""
        return self.dist2 / (1.0 + self.eps)

    @property
    def dist2_hi(self) -> np.ndarray:
        """Upper end; +inf when eps >= 1 (k too small for a two-sided bar)."""
        if self.eps >= 1.0:
            return np.full_like(self.dist2, np.inf)
        return self.dist2 / (1.0 - self.eps)


@dataclasses.dataclass(frozen=True)
class PairwiseResult:
    """Pairwise-distance answer (same fields/semantics as QueryResult)."""

    dist2: np.ndarray
    eps: float
    delta: float

    @property
    def dist2_lo(self) -> np.ndarray:
        return self.dist2 / (1.0 + self.eps)

    @property
    def dist2_hi(self) -> np.ndarray:
        if self.eps >= 1.0:
            return np.full_like(self.dist2, np.inf)
        return self.dist2 / (1.0 - self.eps)


class SketchStore:
    """Append-only store of `(k,)` sketches from ONE projector spec.

    One spec per store, on purpose: sketches from different operators live
    in unrelated embeddings and their mutual distances are meaningless —
    the serving engine keys ingestion on the store's spec. Rows are held in
    a growable (doubling) host array; matmul tiles move to the accelerator
    per sweep step.
    """

    def __init__(self, spec: ProjectorSpec, *, query_tile: int = 4096,
                 delta: float = 0.01):
        if query_tile < 1:
            raise ValueError(f"query_tile must be >= 1, got {query_tile}")
        if not 0.0 < delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        self.spec = spec
        self.k = spec.k
        self.query_tile = int(query_tile)
        self.delta = float(delta)
        # Thm-1 variance factor of the spec's family at its order/rank —
        # the c in eps = sqrt(c / (k delta)).
        self.var_factor = theory.variance_factor(
            spec.family, N=len(spec.dims), R=spec.rank, D=spec.input_size)
        self._data = np.empty((0, self.k), np.float32)
        self._norms2 = np.empty((0,), np.float32)
        self._n = 0
        self._dtype: np.dtype | None = None

    def __len__(self) -> int:
        return self._n

    def nbytes(self) -> int:
        """Resident sketch bytes (the 'millions of users' memory axis)."""
        return self._n * self.k * self._data.itemsize

    def eps_bound(self, delta: float | None = None) -> float:
        """Thm-1/Chebyshev relative error of squared distances at `delta`."""
        delta = self.delta if delta is None else delta
        if not 0.0 < delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        return math.sqrt(self.var_factor / (self.k * delta))

    # -- ingest ----------------------------------------------------------
    def add(self, sketches) -> np.ndarray:
        """Append `(B, k)` (or a single `(k,)`) sketches; returns their ids.

        The store's element dtype is fixed by the FIRST ingest; mixing
        dtypes afterwards is a typed error — silently upcasting would make
        stored distances incomparable across rows (and hide a producer
        regression), exactly the misuse the serve config errors guard.
        """
        arr = np.asarray(sketches)
        if arr.ndim == 1:
            arr = arr[None]
        if arr.ndim != 2 or arr.shape[1] != self.k:
            raise ValueError(
                f"sketches of shape {np.shape(sketches)} do not end in the "
                f"store's k = {self.k}")
        dt = np.dtype(arr.dtype)
        if self._dtype is None:
            self._dtype = dt
            self._data = self._data.astype(dt)
        elif dt != self._dtype:
            raise ValueError(
                f"mixed-dtype ingest: store holds {self._dtype.name} "
                f"sketches, got {dt.name}; re-sketch with a consistent "
                "dtype (one spec, one dtype per store)")
        b = arr.shape[0]
        if self._n + b > self._data.shape[0]:
            cap = max(2 * self._data.shape[0], self._n + b, 1024)
            grown = np.empty((cap, self.k), self._dtype)
            grown[:self._n] = self._data[:self._n]
            self._data = grown
            grown_n = np.empty((cap,), np.float32)
            grown_n[:self._n] = self._norms2[:self._n]
            self._norms2 = grown_n
        ids = np.arange(self._n, self._n + b)
        self._data[self._n:self._n + b] = arr
        self._norms2[self._n:self._n + b] = np.einsum(
            "bk,bk->b", arr, arr, dtype=np.float32)
        self._n += b
        return ids

    def get(self, ids) -> np.ndarray:
        """Stored sketches by id (view into the store)."""
        return self._data[:self._n][np.asarray(ids)]

    # -- retrieval -------------------------------------------------------
    def query(self, q, top_m: int, *, delta: float | None = None
              ) -> QueryResult:
        """Top-m nearest stored sketches for each query row.

        q     : one `(k,)` sketch or a `(B, k)` stack of them.
        top_m : results per query; must satisfy 1 <= top_m <= len(store)
                (a typed error otherwise — asking for more neighbors than
                the store holds is a caller bug, not a clamp).
        """
        if self._n == 0:
            raise ValueError("query on an empty store; ingest sketches "
                             "first")
        if not 1 <= top_m <= self._n:
            raise ValueError(
                f"top_m={top_m} out of range: store holds {self._n} "
                f"sketches (need 1 <= top_m <= {self._n})")
        q = np.asarray(q)
        squeeze = q.ndim == 1
        if squeeze:
            q = q[None]
        if q.ndim != 2 or q.shape[1] != self.k:
            raise ValueError(f"query of shape {q.shape} does not end in the "
                             f"store's k = {self.k}")
        q = q.astype(self._dtype, copy=False)
        qj = jnp.asarray(q)
        qn = np.einsum("bk,bk->b", q, q, dtype=np.float32)
        nb = q.shape[0]
        best_d = np.full((nb, top_m), np.inf, np.float32)
        best_i = np.full((nb, top_m), -1, np.int64)
        for start in range(0, self._n, self.query_tile):
            stop = min(start + self.query_tile, self._n)
            tile = self._data[start:stop]
            # ONE matmul per tile: (B, k) @ (k, tile) on the accelerator.
            dots = np.asarray(jnp.matmul(qj, jnp.asarray(tile.T)),
                              np.float32)
            d2 = qn[:, None] - 2.0 * dots + self._norms2[start:stop][None]
            cand_d = np.concatenate([best_d, d2], axis=1)
            cand_i = np.concatenate(
                [best_i, np.broadcast_to(np.arange(start, stop),
                                         (nb, stop - start))], axis=1)
            keep = np.argpartition(cand_d, top_m - 1, axis=1)[:, :top_m]
            best_d = np.take_along_axis(cand_d, keep, axis=1)
            best_i = np.take_along_axis(cand_i, keep, axis=1)
        order = np.argsort(best_d, axis=1, kind="stable")
        best_d = np.maximum(np.take_along_axis(best_d, order, axis=1), 0.0)
        best_i = np.take_along_axis(best_i, order, axis=1)
        if squeeze:
            best_d, best_i = best_d[0], best_i[0]
        delta = self.delta if delta is None else delta
        return QueryResult(ids=best_i, dist2=best_d,
                           eps=self.eps_bound(delta), delta=delta)

    def pairwise(self, ids_a, ids_b, *, delta: float | None = None
                 ) -> PairwiseResult:
        """Squared distances between stored sketch pairs, with error bars.

        ids_a / ids_b broadcast elementwise; each reported `dist2[i]`
        estimates the true squared distance of the ORIGINAL inputs to
        relative error `eps` (per pair, failure probability <= delta).
        """
        ids_a = np.asarray(ids_a)
        ids_b = np.asarray(ids_b)
        for ids in (ids_a, ids_b):
            if ids.size and (ids.min() < 0 or ids.max() >= self._n):
                raise ValueError(f"sketch id out of range [0, {self._n})")
        diff = (self._data[:self._n][ids_a].astype(np.float32)
                - self._data[:self._n][ids_b].astype(np.float32))
        d2 = np.einsum("...k,...k->...", diff, diff)
        delta = self.delta if delta is None else delta
        return PairwiseResult(dist2=d2, eps=self.eps_bound(delta),
                              delta=delta)
