"""Offline load generator: synthetic traces + deterministic replay.

`synth_trace` draws a Poisson-arrival request stream over a configurable
structure mix (dense / TT / CP payloads, rank- and length-ragged) and a
pool of (spec, seed) combinations — repeated specs are what exercise the
operator cache. `replay` drives a `SketchServer` through the trace on the
trace's own clock: arrivals are submitted at their timestamps, lanes flush
at `max_batch` or at their `flush_us` deadline (whichever first), and the
tail is drained at its deadlines — so the reported p50/p99 latencies are
the deterministic queueing latencies of the flush policy, while `wall_s`
separately records the real compute time of the replay.

Everything is seeded (numpy generator for arrivals/mix/ragged vectors,
jax keys for tensor payloads): the same arguments produce the same trace,
the same batches, the same sketches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.formats import random_cp, random_tt
from repro.rp import ProjectorSpec

from .engine import SketchServer


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: at trace-clock time `t_us`, sketch `payload` under
    (spec, seed)."""

    t_us: float
    payload: Any
    spec: ProjectorSpec
    seed: int = 0


def synth_trace(n_requests: int, specs: Sequence[tuple[ProjectorSpec, int]],
                *, mix: tuple[float, float, float] = (1.0, 1.0, 1.0),
                mean_gap_us: float = 200.0, ranks: tuple[int, ...] = (2, 3, 4),
                seed: int = 0) -> list[TraceEvent]:
    """A seeded synthetic request trace.

    specs       : pool of (ProjectorSpec, seed) pairs, cycled uniformly at
                  random — a singleton pool is the repeated-spec trace the
                  cache-hit-rate acceptance criterion measures.
    mix         : relative weights of (dense, tt, cp) payload structures.
    mean_gap_us : mean of the exponential inter-arrival gap (Poisson
                  arrivals on the trace clock).
    ranks       : TT/CP input ranks, cycled — rank-RAGGED on purpose, the
                  batcher's lane coalescing pads them exactly.
    Dense payloads alternate full `dims`-shaped tensors with ragged SHORT
    flat vectors (zero-padded downstream), covering every coercion path.
    """
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    if not specs:
        raise ValueError("specs pool is empty")
    w = np.asarray(mix, np.float64)
    if w.shape != (3,) or (w < 0).any() or w.sum() == 0:
        raise ValueError(f"mix must be 3 non-negative weights, got {mix}")
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    gaps = rng.exponential(mean_gap_us, size=n_requests)
    t = np.cumsum(gaps)
    kinds = rng.choice(3, size=n_requests, p=w / w.sum())
    which = rng.integers(0, len(specs), size=n_requests)
    events: list[TraceEvent] = []
    for i in range(n_requests):
        spec, op_seed = specs[which[i]]
        sub = jax.random.fold_in(key, i)
        rank = int(ranks[i % len(ranks)])
        if kinds[i] == 1:
            payload: Any = random_tt(sub, spec.dims, rank)
        elif kinds[i] == 2:
            payload = random_cp(sub, spec.dims, rank)
        elif i % 2 == 0:
            payload = jax.random.normal(sub, spec.dims)
        else:
            # ragged short flat vector (zero-pad downstream is exact).
            # Drawn with numpy: a jax.random.normal would compile a fresh
            # threefry kernel PER UNIQUE LENGTH — a compile storm in the
            # trace generator itself.
            size = max(1, spec.input_size - int(rng.integers(
                0, max(1, spec.input_size // 4))))
            payload = rng.standard_normal(size).astype(np.float32)
        events.append(TraceEvent(t_us=float(t[i]), payload=payload,
                                 spec=spec, seed=op_seed))
    return events


def replay(server: SketchServer, trace: Sequence[TraceEvent]) -> dict:
    """Drive `server` through `trace` on the trace clock; return the report.

    Between consecutive arrivals every flush DEADLINE that falls in the gap
    fires at its exact time (max-latency policy); full lanes flush at the
    arrival instant (max-batch policy); the tail drains at its deadlines.
    The report is `server.stats()` plus the wall-clock compute time.
    """
    t_wall = time.perf_counter()
    for ev in sorted(trace, key=lambda e: e.t_us):
        while True:
            deadline = server.batcher.next_deadline()
            if deadline is None or deadline > ev.t_us:
                break
            if server.tick(deadline) == 0:      # defensive: never spin
                break
        server.submit(ev.payload, ev.spec, seed=ev.seed, now=ev.t_us)
        while server.batcher.ready(ev.t_us):
            server.tick(ev.t_us)
    last = max((e.t_us for e in trace), default=0.0)
    server.drain(last)
    report = server.stats()
    report["wall_s"] = time.perf_counter() - t_wall
    report["n_trace"] = len(trace)
    return report
