"""Request queue + dynamic batch assembler for the sketch-serving engine.

Requests arrive one at a time (dense tensors / flat vectors, `TTTensor`s,
`CPTensor`s — possibly rank-ragged / length-ragged) and are queued into
LANES keyed by `(spec, seed, structure)`. Everything inside one lane
coalesces into ONE `rp.project_many` dispatch — ragged flat lengths
zero-pad, ragged TT/CP ranks zero-pad exactly (`core.formats.stack_ragged_*`)
— so a batcher TICK flushes exactly one lane and costs exactly one kernel
dispatch, which `rp.dispatch_stats()` can assert end-to-end.

Flush policy (the `ServeConfig` knobs):
  * max-batch  — a lane that reaches `max_batch` requests is ready;
  * max-latency — a lane whose OLDEST request has waited `flush_us`
    (trace-clock) microseconds is ready even when short.
`next_batch` serves the ready lane with the oldest head (FIFO across
lanes), preferring fullness only as a tiebreak — tail latency wins over
occupancy when both policies fire at once.

The clock is EXPLICIT (`now` in microseconds, floats): the batcher never
reads wall time, so traces replay deterministically and tests/benchmarks
control latency outcomes exactly.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

from repro.rp import ProjectorSpec
from repro.rp.plan import structure_tag  # noqa: F401  (lane key = plan tag)

from .config import ServeConfig


@dataclasses.dataclass
class SketchRequest:
    """One in-flight sketching request.

    Filled in by the engine on completion: `sketch` (the (k,) result),
    `t_done`, and `store_id` when the sketch was ingested into the store.
    """

    rid: int
    payload: Any
    spec: ProjectorSpec
    seed: int = 0
    t_submit: float = 0.0
    t_done: float | None = None
    sketch: Any = None
    store_id: int | None = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency_us(self) -> float:
        if self.t_done is None:
            raise ValueError(f"request {self.rid} is not done yet")
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class LaneKey:
    spec: ProjectorSpec
    seed: int
    structure: str


class DynamicBatcher:
    """Lane-keyed FIFO queues with a max-batch / max-latency flush policy."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self._lanes: dict[LaneKey, deque[SketchRequest]] = {}

    # -- queueing --------------------------------------------------------
    def submit(self, req: SketchRequest) -> LaneKey:
        key = LaneKey(req.spec, int(req.seed), structure_tag(req.payload))
        self._lanes.setdefault(key, deque()).append(req)
        return key

    def pending(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def lanes(self) -> int:
        return len(self._lanes)

    # -- flush policy ----------------------------------------------------
    def _lane_ready(self, q: deque, now: float) -> bool:
        # NB: `now >= t_submit + flush_us`, the SAME float expression
        # `next_deadline` returns — writing it as `now - t_submit >=
        # flush_us` can round the other way, leaving a lane not-ready at
        # its own deadline (an infinite replay loop).
        return (len(q) >= self.cfg.max_batch
                or now >= q[0].t_submit + self.cfg.flush_us)

    def ready(self, now: float) -> bool:
        return any(self._lane_ready(q, now) for q in self._lanes.values())

    def next_deadline(self) -> float | None:
        """Earliest instant at which some lane becomes latency-ready.

        The trace replayer advances its clock to this between arrivals, so
        idle queues still flush at `t_submit + flush_us` — None when empty.
        """
        heads = [q[0].t_submit for q in self._lanes.values() if q]
        if not heads:
            return None
        return min(heads) + self.cfg.flush_us

    def next_batch(self, now: float, *, force: bool = False
                   ) -> tuple[LaneKey, list[SketchRequest]] | None:
        """Pop one tick's batch: up to `max_batch` requests from ONE lane.

        Serves the ready lane with the oldest head request (FIFO fairness
        across lanes; lane fullness breaks ties). `force=True` flushes the
        oldest lane even before its deadline — the end-of-trace drain.
        Returns None when nothing is (or, under force, nothing at all is)
        queued.
        """
        candidates = [(key, q) for key, q in self._lanes.items()
                      if q and (force or self._lane_ready(q, now))]
        if not candidates:
            return None
        key, q = min(candidates,
                     key=lambda kq: (kq[1][0].t_submit, -len(kq[1])))
        batch = [q.popleft() for _ in range(min(len(q), self.cfg.max_batch))]
        if not q:
            del self._lanes[key]
        return key, batch
