"""Serving-engine configuration with typed, `python -O`-surviving checks.

One frozen dataclass carries every knob of the sketch-serving pipeline
(queue -> batcher -> dispatch -> store): the dynamic batcher's flush policy
(`max_batch` / `flush_us`), the LRU operator-cache capacity, the backend
policy handed to `rp.project_many`, and the similarity endpoint's tile
size and confidence level. Misuse raises `ValueError` naming the knob —
never a bare assert, matching the PR-5 `parse_compress_flag` style — so a
bad production flag fails loudly even under `python -O`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the sketch-serving engine.

    max_batch      : flush a lane as soon as it holds this many requests
                     (the batch the one-per-tick dispatch carries).
    flush_us       : max-latency flush — a lane whose OLDEST request has
                     waited this many (trace-clock) microseconds flushes
                     even when short of `max_batch`. The knob trades tail
                     latency against batch occupancy.
    cache_capacity : LRU operator-cache entries ((ProjectorSpec, seed)
                     keys; a hit skips operator regeneration entirely).
    backend        : `repro.rp` backend policy for the per-tick dispatch.
    ingest         : add completed sketches (of the store's own spec) to
                     the sketch store so they become retrievable.
    query_tile     : stored-sketch rows per matmul tile of the similarity
                     sweep (bounds the (B, tile) distance intermediate).
    delta          : default failure probability of the Thm-1/Chebyshev
                     distortion bound reported next to query results.
    stats_window   : completed requests the latency percentiles in
                     `SketchServer.stats()` are computed over (last-N).
                     All-time percentiles let a long healthy prefix mask a
                     tail regression — after 10^6 fast requests, a slow
                     phase needs >1% of the TOTAL trace to move the
                     all-time p99 at all; a windowed p99 reflects it
                     within `stats_window` requests.
    """

    max_batch: int = 16
    flush_us: float = 2_000.0
    cache_capacity: int = 8
    backend: str = "auto"
    ingest: bool = True
    query_tile: int = 4096
    delta: float = 0.01
    stats_window: int = 256

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if not self.flush_us > 0:
            raise ValueError(
                f"flush window flush_us must be > 0 (got {self.flush_us}); "
                "a non-positive window would flush every request alone and "
                "defeat batching")
        if self.cache_capacity < 1:
            raise ValueError(f"cache_capacity must be >= 1, got "
                             f"{self.cache_capacity}")
        # the one backend check lives in the plan layer (local import:
        # serve must stay importable without pulling rp eagerly at
        # class-definition time)
        from repro.rp.plan import validate_backend
        validate_backend(self.backend)
        if self.query_tile < 1:
            raise ValueError(f"query_tile must be >= 1, got "
                             f"{self.query_tile}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if self.stats_window < 1:
            raise ValueError(
                f"stats_window must be >= 1, got {self.stats_window}; the "
                "latency percentiles need at least one completed request "
                "in their window")
