"""The sketch-serving engine: queue -> batcher -> one dispatch -> store.

`SketchServer` ties the subsystem together: requests enter through
`submit`, the `DynamicBatcher` coalesces them into lanes, and every `tick`
flushes ONE lane through `rp.project_many` — exactly one kernel dispatch
per tick, with the operator fetched from the LRU `OperatorCache` (a hit
skips regeneration entirely). Completed sketches whose spec matches the
attached `SketchStore`'s are ingested, making them immediately queryable
through the JL similarity endpoints (`query` / `pairwise`).

The engine is synchronous and clock-explicit (`now` in trace-clock
microseconds): the load generator / trace replayer owns time, so latency
percentiles are a deterministic function of the trace and the flush
policy. An async front-end is a transport detail on top of `submit`/`tick`.
"""
from __future__ import annotations

import collections

import numpy as np

from repro import obs, rp
from repro.core.formats import CPTensor, TTTensor

from .batcher import DynamicBatcher, SketchRequest
from .cache import OperatorCache
from .config import ServeConfig
from .store import PairwiseResult, QueryResult, SketchStore


class SketchServer:
    """RP-as-a-service: continuously batched sketching + JL retrieval."""

    def __init__(self, cfg: ServeConfig | None = None,
                 store: SketchStore | None = None):
        self.cfg = cfg if cfg is not None else ServeConfig()
        self.batcher = DynamicBatcher(self.cfg)
        self.cache = OperatorCache(self.cfg.cache_capacity)
        self.store = store
        self.done: list[SketchRequest] = []
        self.ticks = 0
        self.occupancy: list[float] = []
        self._next_rid = 0
        # last-N completed-request latencies: what stats() percentiles are
        # computed over (all-time percentiles let a long healthy prefix
        # mask a fresh tail regression — see ServeConfig.stats_window)
        self._lat_window: collections.deque[float] = collections.deque(
            maxlen=self.cfg.stats_window)

    # -- intake ----------------------------------------------------------
    def submit(self, payload, spec: rp.ProjectorSpec, *, seed: int = 0,
               now: float = 0.0) -> SketchRequest:
        """Queue one payload for sketching under (spec, seed).

        Structured payloads are validated against the spec's dims HERE —
        failing at submit time with a typed error beats poisoning a whole
        batch at dispatch time.
        """
        if isinstance(payload, (TTTensor, CPTensor)):
            if tuple(payload.dims) != tuple(spec.dims):
                raise rp.FormatMismatchError(
                    f"{type(payload).__name__} payload dims "
                    f"{tuple(payload.dims)} != spec dims {tuple(spec.dims)}")
        req = SketchRequest(rid=self._next_rid, payload=payload, spec=spec,
                            seed=seed, t_submit=float(now))
        self._next_rid += 1
        self.batcher.submit(req)
        return req

    # -- the serving loop ------------------------------------------------
    def tick(self, now: float, *, force: bool = False) -> int:
        """Flush one lane: ONE `rp.project_many` dispatch. Returns #served."""
        got = self.batcher.next_batch(now, force=force)
        if got is None:
            return 0
        key, batch = got
        with obs.span("serve.tick", batch=len(batch),
                      family=key.spec.family, k=key.spec.k,
                      structure=key.structure, seed=key.seed,
                      tick=self.ticks) as sp:
            op = self.cache.get(key.spec, key.seed)
            # pre-plan the coalesced dispatch: same group signature
            # project_many buckets on, so the tick executes pre-planned
            # (a plan-cache hit) and the trace joins to the exact route
            eplan = self.cache.plan_for(op, [r.payload for r in batch],
                                        backend=self.cfg.backend)
            sp.set(plan=eplan.plan_id, route=eplan.route)
            mon = obs.get_distortion()
            x_norm2 = None
            if mon is not None:
                # squared input norms BEFORE payloads are dropped; dense
                # payloads only (zero-padding downstream is norm-exact),
                # structured ones would need a densify just to be graded
                x_norm2 = [None if isinstance(r.payload, (TTTensor, CPTensor))
                           else float(np.sum(np.square(
                               np.asarray(r.payload, np.float64))))
                           for r in batch]
            ys = rp.project_many(op, [r.payload for r in batch],
                                 backend=self.cfg.backend)
            self.ticks += 1
            self.occupancy.append(len(batch) / self.cfg.max_batch)
            ingest = (self.store is not None and self.cfg.ingest
                      and key.spec == self.store.spec)
            ids = self.store.add(np.asarray(ys)) if ingest else None
            delay_hist = obs.histogram("serve/queue_delay_us")
            for i, req in enumerate(batch):
                req.sketch = ys[i]
                req.t_done = float(now)
                if ids is not None:
                    req.store_id = int(ids[i])
                req.payload = None  # the engine's point: drop the original
                self._lat_window.append(req.latency_us)
                delay_hist.observe(req.latency_us)
                if mon is not None and x_norm2[i] is not None:
                    mon.observe_norms(
                        key.spec.family, len(key.spec.dims), key.spec.k,
                        x_norm2[i],
                        float(np.sum(np.square(
                            np.asarray(ys[i], np.float64)))),
                        rank=key.spec.rank)
            self.done.extend(batch)
            obs.counter("serve/requests_done").inc(len(batch))
            return len(batch)

    def drain(self, now: float) -> int:
        """Flush everything still queued (end of trace). Returns #served.

        Advances the clock lane by lane to each flush DEADLINE (so drained
        requests still pay the latency the policy promises), never earlier
        than `now`.
        """
        served = 0
        while self.batcher.pending():
            deadline = self.batcher.next_deadline()
            t = max(float(now), deadline if deadline is not None else now)
            n = self.tick(t, force=True)
            if n == 0:      # defensive: force=True always pops when pending
                break
            served += n
        return served

    # -- retrieval (straight to the store; no batching needed: a query is
    # -- one tiled matmul sweep, not a kernel dispatch) -------------------
    def query(self, q, top_m: int, *, delta: float | None = None
              ) -> QueryResult:
        if self.store is None:
            raise ValueError("this server has no sketch store attached")
        return self.store.query(q, top_m, delta=delta)

    def pairwise(self, ids_a, ids_b, *, delta: float | None = None
                 ) -> PairwiseResult:
        if self.store is None:
            raise ValueError("this server has no sketch store attached")
        return self.store.pairwise(ids_a, ids_b, delta=delta)

    # -- restart warm-up -------------------------------------------------
    def save_manifest(self, path) -> int:
        """Write the operator cache's registry (spec dicts + seeds) to
        `path` as JSON — no operator bytes. Returns #entries written."""
        import json
        import pathlib

        entries = self.cache.manifest()
        pathlib.Path(path).write_text(
            json.dumps({"version": 1, "entries": entries}, indent=1))
        return len(entries)

    def prewarm(self, source) -> int:
        """Warm the operator cache from a `save_manifest` file (or an
        already-loaded manifest list): every operator is regenerated
        bitwise-identical from its (spec, seed), so the first request per
        lane after a restart hits instead of paying regeneration. Returns
        the number of operators sampled."""
        if isinstance(source, (list, tuple)):
            return self.cache.prewarm(list(source))
        import json
        import pathlib

        doc = json.loads(pathlib.Path(source).read_text())
        entries = doc.get("entries") if isinstance(doc, dict) else doc
        if not isinstance(entries, list):
            raise ValueError(
                f"prewarm manifest {source} has no 'entries' list")
        return self.cache.prewarm(entries)

    # -- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        """Serving report: latency percentiles, occupancy, cache stats.

        `p50_us`/`p99_us` are WINDOWED — computed over the last
        `cfg.stats_window` completed requests, not all-time — so a tail
        regression late in a long replay shows up instead of being
        averaged away by the healthy prefix (`stats_window_n` reports how
        many requests the window currently holds).
        """
        lat = np.asarray(self._lat_window, np.float64)
        out = {
            "requests_done": len(self.done),
            "pending": self.batcher.pending(),
            "ticks": self.ticks,
            "occupancy_mean": float(np.mean(self.occupancy))
            if self.occupancy else 0.0,
            "p50_us": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "p99_us": float(np.percentile(lat, 99)) if lat.size else 0.0,
            "stats_window": self.cfg.stats_window,
            "stats_window_n": int(lat.size),
            "cache": self.cache.stats.as_dict(),
        }
        if self.store is not None:
            out["store_size"] = len(self.store)
            out["store_bytes"] = self.store.nbytes()
        return out
