"""Tensorized-RP gradient compression with error feedback.

The paper's map f_TT(R) / f_CP(R) gives an oblivious linear sketch whose
adjoint is an unbiased reconstruction (E[vec(S_i)vec(S_i)^T] = I). That makes
it a drop-in gradient compressor for the SLOW cross-pod axis:

  worker w:  p_w = g_w + e_w                   (error feedback)
             y_w = Sketch_t(p_w)               (k floats per 1M-float bucket)
             h_w = Unsketch_t(y_w)             (ONE adjoint pass per worker)
  network:   g_hat = mean_w h_w                (== Unsketch_t(mean_w y_w) by
                                                linearity of the adjoint)
  worker w:  e_w'  = p_w - h_w                 (local residual)

All workers regenerate the operator from fold_in(key, step) — the operator
itself (O(kNdR^2) floats) never crosses the network; the paper's memory bound
is exactly why the whole operator fits in VMEM/cache. NOTE the tradeoff in
the default mean_w h_w formulation (SketchCompressor(sync='local-mean')): it
halves per-worker adjoint compute (one unsketch instead of two), but the
sync point is a mean of DENSE reconstructions rather than of (buckets, k)
sketches. On a bandwidth-bound cross-pod link prefer sync='sketch-mean',
which restores the formulation that syncs y = mean_w y_w (~D/k times fewer
wire bytes) at the cost of every worker redundantly computing Unsketch_t(y);
`_metrics` reports `sketch_bytes` for THAT formulation's wire cost. Topology: params are
FSDP-sharded *within* a pod and replicated *across* pods (DiLoCo-style
DDP-of-FSDP), so the pod axis syncs via this compressed all-reduce.

Two formulations of the cross-pod sync coexist:

  * `compress_collective` — the REAL collective: a `shard_map` manual over
    the pod axis (auto over the rest) whose only cross-pod traffic is one
    `lax.pmean` (of the (buckets, k) sketches under sync='sketch-mean', of
    the dense reconstructions under 'local-mean'). This is what
    launch/steps.py wires into the train step on pod meshes.
  * `compress_per_pod` — the pure-pjit simulation of the same math via a
    leading npod dim (vmap(spmd_axis_name)); kept as the reference the
    collective is equivalence-tested against.

Fidelity/convergence are exercised in tests/benchmarks (CPU, small meshes);
the dry-run lowers the same code on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.formats import BatchedCPTensor, BatchedTTTensor
from repro.core.sketch import PytreeSketcher, SketchConfig, _is_struct_leaf


def _balanced_pow2_dims(elems: int, order: int) -> tuple[int, ...]:
    """Tensorize a power-of-two bucket into `order` balanced pow2 modes.

    Spreads the exponent as evenly as possible, larger modes first —
    order=3 over the default 2^20 bucket reproduces the classic
    (128, 128, 64); order=4 gives (32, 32, 32, 32).
    """
    if order < 1:
        raise ValueError(f"order must be a positive integer, got {order}")
    e = elems.bit_length() - 1
    if elems <= 0 or (1 << e) != elems:
        raise ValueError(
            f"order= without dims= needs a power-of-two bucket, got {elems}")
    base, extra = divmod(e, order)
    if base == 0:
        raise ValueError(f"order={order} is too high for a {elems}-element "
                         "bucket (a mode would collapse to 1)")
    return tuple(1 << (base + (1 if i < extra else 0)) for i in range(order))


_FLAG_KEYS = ("dims", "k", "rank", "order")


def parse_compress_flag(flag: str) -> SketchConfig:
    """'<family>:k=4096,rank=2[,dims=128x128x64][,order=4]' -> SketchConfig.

    `family` is any registered repro.rp family ('tt', 'cp', 'gaussian',
    'sparse', ...); SketchConfig validates it against the registry.
    `order=N` without `dims=` tensorizes the default bucket into N balanced
    power-of-two modes (the order-N kernel path: same bucket/compression,
    smaller operator); with `dims=` it just cross-checks len(dims) == N.

    Unknown or malformed keys raise `ValueError` naming the bad key and the
    accepted set — a misspelled `rnak=4` must not silently ship the default
    rank to a production launch.
    """
    family, _, rest = flag.partition(":")
    kw: dict[str, Any] = {"family": family}
    order: int | None = None
    if rest:
        for part in rest.split(","):
            key, eq, val = part.partition("=")
            if not eq:
                raise ValueError(
                    f"malformed part {part!r} in compress flag {flag!r}: "
                    f"expected key=value with key in {_FLAG_KEYS}")
            if key not in _FLAG_KEYS:
                raise ValueError(
                    f"unknown key {key!r} in compress flag {flag!r}; "
                    f"accepted keys: {', '.join(_FLAG_KEYS)}")
            if key == "dims":
                dims = tuple(int(x) for x in val.split("x"))
                kw["dims"] = dims
                kw["bucket_elems"] = 1
                for d in dims:
                    kw["bucket_elems"] *= d
            elif key in ("k", "rank"):
                kw[key] = int(val)
            else:  # "order"
                order = int(val)
    if order is not None:
        if "dims" in kw:
            if len(kw["dims"]) != order:
                raise ValueError(
                    f"order={order} contradicts dims="
                    f"{'x'.join(map(str, kw['dims']))} (order "
                    f"{len(kw['dims'])})")
        else:
            elems = SketchConfig.__dataclass_fields__["bucket_elems"].default
            kw["dims"] = _balanced_pow2_dims(elems, order)
            kw["bucket_elems"] = elems
    return SketchConfig(**kw)


@dataclasses.dataclass
class SketchCompressor:
    cfg: SketchConfig
    pod_axis: str | None = None     # lax axis name inside shard_map
    base_key: int = 0x5EED
    # Cross-pod sync formulation for compress_per_pod (equal by linearity):
    #   'local-mean'  — ONE adjoint pass per pod; the sync point is the
    #                   pod-mean of the dense local reconstructions (cheapest
    #                   compute, dense bytes on the pod axis);
    #   'sketch-mean' — sync the (buckets, k) sketch-mean (k-sized bytes on
    #                   the wire), then every pod redundantly unsketches it
    #                   (second adjoint pass). Prefer when the pod link is
    #                   bandwidth-bound.
    sync: str = "local-mean"
    # Wire dtype of the cross-pod collective in `compress_collective`:
    #   'fp32' — the reference: pmean of float32 payloads;
    #   'int8' — scaled-int8 payloads + float32 scales on the wire
    #            (`rp.quantize_for_psum`): per-bucket-row absmax scales for
    #            'sketch-mean' (~4x fewer HLO-measured all-reduce bytes),
    #            per-leaf scalar scales for 'local-mean'. The quantization
    #            error lands in the synced estimate and is absorbed by the
    #            NEXT step's error feedback like any other sketch error; it
    #            is bounded by s/2 per element with s the shared scale.
    wire: str = "fp32"
    # Explicit bucket-axis layout for the sketcher (the sharded-engine path):
    # `mesh` + `bucket_spec` (a PartitionSpec whose first entry names the
    # mesh axes for the (n_buckets, ...) dim) replace the legacy global
    # `_constrain_buckets` guess. launch/steps.py fills these from
    # launch/sharding.py::bucket_specs; None keeps single-host behavior.
    mesh: Any = dataclasses.field(default=None, compare=False)
    bucket_spec: Any = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        if self.sync not in ("local-mean", "sketch-mean"):
            raise ValueError(f"unknown sync mode {self.sync!r}; expected "
                             "'local-mean' or 'sketch-mean'")
        if self.wire not in ("fp32", "int8"):
            raise ValueError(f"unknown wire dtype {self.wire!r}; expected "
                             "'fp32' or 'int8'")
    # (structure-key, sketcher) memo — the tree structure is fixed across
    # steps, so the flatten + family/registry validation in PytreeSketcher
    # runs once instead of on every compress/compress_per_pod trace.
    _sk_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @staticmethod
    def _leaf_memo_key(leaf):
        if _is_struct_leaf(leaf):
            # structured leaves key on the CONTAINER contract the sketcher
            # validates (type, dims, bucket count, dtype) — not on the
            # flattened core/factor shapes, which vary with the input rank
            # even though the sketcher bookkeeping is rank-independent
            nb = leaf.batch if isinstance(
                leaf, (BatchedTTTensor, BatchedCPTensor)) else 1
            return (type(leaf).__name__, tuple(leaf.dims), nb,
                    jnp.dtype(leaf.dtype).name)
        return (tuple(leaf.shape), jnp.dtype(leaf.dtype).name)

    def _sketcher(self, tree, *, plain: bool = False) -> PytreeSketcher:
        """Memoized PytreeSketcher for `tree`. `plain=True` disables ALL
        bucket-layout constraints (explicit mesh/spec AND the legacy global
        hint) — required inside shard_map bodies, where any sharding
        constraint on a partially-manual mesh hard-crashes XLA's SPMD
        partitioner (sharding.IsManualSubgroup check)."""
        mesh = None if plain else self.mesh
        spec = None if plain else self.bucket_spec
        # flatten with the sketcher's own leaf predicate so the memo key
        # matches what PytreeSketcher validates (TT/CP containers are leaves)
        leaves, treedef = jax.tree_util.tree_flatten(
            tree, is_leaf=_is_struct_leaf)
        key = (treedef, tuple(self._leaf_memo_key(l) for l in leaves),
               mesh, spec, plain)
        if self._sk_cache is not None and self._sk_cache[0] == key:
            return self._sk_cache[1]
        sk = PytreeSketcher(self.cfg, tree, mesh=mesh, bucket_spec=spec,
                            constrain=not plain)
        self._sk_cache = (key, sk)
        return sk

    def init_state(self, params) -> dict:
        return {"residual": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def _key(self, step):
        key = jax.random.PRNGKey(self.base_key)
        if self.cfg.fresh_per_step:
            key = jax.random.fold_in(key, step)
        return key

    def compress(self, grads, state, *, step) -> tuple[Any, dict, dict]:
        """Single-worker roundtrip estimator (no comm): sketch -> unsketch
        with error feedback. Used on meshes without a pod axis."""
        sk = self._sketcher(grads)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads, state["residual"])
        alpha = self.cfg.shrinkage()
        y = sk.sketch(p, key)                           # (buckets, k)
        g_hat = jax.tree.map(lambda x: alpha * x, sk.unsketch(y, key))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype), g_hat, grads)
        return g_out, {"residual": new_residual}, self._metrics(sk, new_residual)

    def compress_per_pod(self, grads_pp, state, *, step):
        """Cross-pod compressed all-reduce, pure-pjit SIMULATION.

        The vmap(spmd_axis_name) formulation `compress_collective` replaces
        on real pod meshes — kept as the reference implementation the
        shard_map collective is equivalence-tested against.

        grads_pp / state['residual']: every leaf has a leading npod dim
        (produced by jax.vmap(..., spmd_axis_name='pod') so the dim is
        sharded over the pod mesh axis). Each pod runs ONE adjoint pass (its
        local unsketch, needed for the error-feedback residual anyway); by
        linearity of the adjoint, unsketch(mean_w y_w) == mean_w
        unsketch(y_w), so with the default sync='local-mean' the synced
        estimate is the pod-mean of the local reconstructions and the
        redundant second reconstruction of the old unsketch(y_mean)
        formulation is gone; sync='sketch-mean' keeps that formulation for
        bandwidth-bound pod links (see the `sync` field / module docstring
        for the compute-vs-bandwidth tradeoff).
        Returns (synced grads WITHOUT pod dim, new_state, metrics).
        """
        if self.wire != "fp32":
            raise ValueError(
                f"compress_per_pod is the pure-pjit reference and has no "
                f"collective to quantize; wire={self.wire!r} is a "
                "compress_collective feature — use wire='fp32' here")
        example = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:],
                                                              g.dtype),
                               grads_pp)
        sk = self._sketcher(example)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads_pp, state["residual"])
        alpha = self.cfg.shrinkage()
        y_pp = jax.vmap(lambda t: sk.sketch(t, key))(p)   # (npod, buckets, k)
        g_hat_local = jax.tree.map(
            lambda x: alpha * x,
            jax.vmap(lambda yy: sk.unsketch(yy, key))(y_pp))
        if self.sync == "local-mean":
            # == alpha * unsketch(mean(y_pp, 0)) by linearity, WITHOUT a
            # second adjoint pass; syncs dense bytes over the pod axis.
            g_hat = jax.tree.map(lambda gh: jnp.mean(gh, axis=0), g_hat_local)
        else:  # 'sketch-mean' (sync validated in __post_init__)
            y_mean = jnp.mean(y_pp, axis=0)       # k-sized wire bytes
            g_hat = jax.tree.map(lambda x: alpha * x,
                                 sk.unsketch(y_mean, key))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat_local)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype),
                             g_hat, example)
        return g_out, {"residual": new_residual}, self._pod_metrics(
            sk, new_residual)

    def compress_collective(self, grads_pp, state, *, step, mesh=None):
        """Cross-pod compressed all-reduce as a REAL `shard_map` collective.

        The production formulation of `compress_per_pod` (which simulates
        the pod axis with `jax.vmap(..., spmd_axis_name)`): leaves of
        `grads_pp` / `state['residual']` carry a leading npod dim laid out
        over the mesh's pod axis; the shard_map is MANUAL over that axis
        (`auto` over every other mesh axis, so FSDP/TP layouts inside the
        body stay with the partitioner). Each pod sees only its local
        slice, regenerates the operator from `fold_in(key, step)` — the
        operator itself NEVER crosses the network — sketches its error-fed
        gradient, and the only cross-pod collective is one `lax.pmean`:

          sync='sketch-mean' — pmean of the (n_buckets, k) sketches:
              n_buckets * k floats on the wire, every pod redundantly
              unsketches the mean (second adjoint pass);
          sync='local-mean'  — pmean of the dense local reconstructions:
              dense bytes on the wire, ONE adjoint pass per pod.

        `wire='int8'` replaces the float pmean with a scaled-int8 `psum`
        plus a small float32 scale sync (`rp.quantize_for_psum`): the
        payload shrinks 4x on the wire, the shared pod-max scale keeps the
        integer sum overflow-proof and the dequantized mean bitwise
        identical on every pod, and the quantization error is absorbed by
        the next step's error feedback. Requires npod <= 127.

        Equal to `compress_per_pod` to fp32 tolerance by linearity of the
        adjoint (wire='fp32'; int8 adds the bounded quantization error).
        Returns (synced grads WITHOUT the pod dim — replicated across pods
        —, new_state, metrics); metrics are computed OUTSIDE the shard_map
        so no extra scalar collectives dilute the wire-bytes claim.
        """
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            raise ValueError("compress_collective needs a mesh (pass mesh= "
                             "or construct SketchCompressor(mesh=...))")
        axis = self.pod_axis or "pod"
        if axis not in mesh.axis_names:
            raise ValueError(f"pod axis {axis!r} not in mesh axes "
                             f"{mesh.axis_names}")
        npod = mesh.shape[axis]
        for path, leaf in jax.tree_util.tree_flatten_with_path(grads_pp)[0]:
            # the body keeps local row 0 of each shard, so a leading dim
            # that is a LARGER multiple of npod would shard_map cleanly but
            # silently drop every other pod's gradient
            if leaf.shape[:1] != (npod,):
                raise ValueError(
                    f"grads_pp leaf {jax.tree_util.keystr(path)} has "
                    f"leading dim {leaf.shape[0] if leaf.ndim else None}, "
                    f"expected the pod-axis size {npod}; one row per pod")
        example = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:],
                                                              g.dtype),
                               grads_pp)
        # plain sketcher: inside the (partially) manual shard_map body the
        # bucket layout over the auto axes belongs to the partitioner — an
        # explicit NamedSharding constraint there trips an XLA SPMD
        # partitioner CHECK (IsManualSubgroup) and aborts the process
        sk = self._sketcher(example, plain=True)
        key = self._key(step)
        alpha = self.cfg.shrinkage()
        if self.wire == "int8" and npod > 127:
            raise ValueError(
                f"wire='int8' supports at most 127 pods (the overflow-proof "
                f"clip qmax = 127 // npod would be 0), got npod={npod}")
        # runtime import: rp.shard imports nothing from optim, no cycle
        from repro.rp.shard import dequantize_psum, quantize_for_psum

        def _mean_over_pods(x, *, per_row):
            """pmean(x) over the pod axis in the configured wire dtype."""
            if self.wire == "fp32":
                return jax.lax.pmean(x, axis)
            q, s = quantize_for_psum(x, axis, npod, per_row=per_row)
            return dequantize_psum(jax.lax.psum(q, axis), s, npod)

        def body(g_pp, e_pp):
            g = jax.tree.map(lambda a: a[0], g_pp)    # local (1, ...) slice
            e = jax.tree.map(lambda a: a[0], e_pp)
            p = jax.tree.map(lambda gg, ee: gg.astype(jnp.float32) + ee,
                             g, e)
            y = sk.sketch(p, key)                     # (n_buckets, k) local
            # the local adjoint pass is needed for the EF residual anyway
            h_local = jax.tree.map(lambda x: alpha * x, sk.unsketch(y, key))
            if self.sync == "sketch-mean":
                # the ONLY wire bytes: one scale per bucket row under int8
                y_mean = _mean_over_pods(y, per_row=True)
                g_hat = jax.tree.map(lambda x: alpha * x,
                                     sk.unsketch(y_mean, key))
            else:  # 'local-mean' (sync validated in __post_init__)
                g_hat = jax.tree.map(
                    lambda h: _mean_over_pods(h, per_row=False), h_local)
            resid = jax.tree.map(
                lambda pp, h: (pp - h.astype(jnp.float32))[None], p, h_local)
            g_out = jax.tree.map(lambda gh, gref: gh.astype(gref.dtype),
                                 g_hat, g)
            return g_out, resid

        pod_specs = jax.tree.map(lambda _: P(axis), grads_pp)
        res_specs = jax.tree.map(lambda _: P(axis), state["residual"])
        out_specs = (jax.tree.map(lambda _: P(), example), res_specs)
        f = shard_map(body, mesh=mesh,
                      in_specs=(pod_specs, res_specs), out_specs=out_specs,
                      check_rep=False,
                      auto=frozenset(mesh.axis_names) - {axis})
        g_out, new_residual = f(grads_pp, state["residual"])
        return g_out, {"residual": new_residual}, self._pod_metrics(
            sk, new_residual)

    def _pod_metrics(self, sk: PytreeSketcher, residual) -> dict:
        """Cross-pod metrics: the base set plus the per-step pod-link bytes
        of the ACTIVE (sync, wire) mode — sketch_bytes/dense_bytes alone
        describe the fp32 sketch-mean formulation and would misreport
        'local-mean' or int8 comm on dashboards."""
        metrics = self._metrics(sk, residual)
        wire = self.wire_bytes(sk)
        metrics["wire_bytes"] = jnp.asarray(wire, jnp.float32)
        # TRACE-TIME telemetry: under jit this runs once per compiled
        # variant, not once per step — the gauge is the analytic per-step
        # payload (a constant of the config), the counter tallies traces
        from repro import obs
        obs.gauge("rp/wire_bytes_per_step").set(float(wire))
        obs.counter("rp/collective_traces").inc()
        return metrics

    def wire_bytes(self, sk: PytreeSketcher) -> int:
        """Analytic per-step pod-link payload of `compress_collective` for
        the active (sync, wire) mode — read from the plan layer's wire
        ledger (`rp.collective_wire_bytes`), the single accounting the
        `perf/wire` bench rows and HLO byte checks gate against."""
        from repro.rp.plan import collective_wire_bytes
        return collective_wire_bytes(
            sync=self.sync, wire=self.wire,
            sketch_bytes=sk.sketch_bytes(), dense_bytes=sk.dense_bytes(),
            n_buckets=sk.n_buckets, n_leaves=len(sk._shapes))

    def _metrics(self, sk: PytreeSketcher, residual) -> dict:
        return {
            "sketch_bytes": jnp.asarray(sk.sketch_bytes(), jnp.float32),
            "dense_bytes": jnp.asarray(sk.dense_bytes(), jnp.float32),
            "residual_norm": jnp.sqrt(sum(
                jnp.sum(jnp.square(r)) for r in jax.tree.leaves(residual))),
        }

    def compression_ratio(self, params) -> float:
        return self._sketcher(params).compression_ratio()
