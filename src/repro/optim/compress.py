"""Tensorized-RP gradient compression with error feedback.

The paper's map f_TT(R) / f_CP(R) gives an oblivious linear sketch whose
adjoint is an unbiased reconstruction (E[vec(S_i)vec(S_i)^T] = I). That makes
it a drop-in gradient compressor for the SLOW cross-pod axis:

  worker w:  p_w = g_w + e_w                   (error feedback)
             y_w = Sketch_t(p_w)               (k floats per 1M-float bucket)
             h_w = Unsketch_t(y_w)             (ONE adjoint pass per worker)
  network:   g_hat = mean_w h_w                (== Unsketch_t(mean_w y_w) by
                                                linearity of the adjoint)
  worker w:  e_w'  = p_w - h_w                 (local residual)

All workers regenerate the operator from fold_in(key, step) — the operator
itself (O(kNdR^2) floats) never crosses the network; the paper's memory bound
is exactly why the whole operator fits in VMEM/cache. NOTE the tradeoff in
the default mean_w h_w formulation (SketchCompressor(sync='local-mean')): it
halves per-worker adjoint compute (one unsketch instead of two), but the
sync point is a mean of DENSE reconstructions rather than of (buckets, k)
sketches. On a bandwidth-bound cross-pod link prefer sync='sketch-mean',
which restores the formulation that syncs y = mean_w y_w (~D/k times fewer
wire bytes) at the cost of every worker redundantly computing Unsketch_t(y);
`_metrics` reports `sketch_bytes` for THAT formulation's wire cost. Topology: params are
FSDP-sharded *within* a pod and replicated *across* pods (DiLoCo-style
DDP-of-FSDP), so the pod axis syncs via this compressed all-reduce.

Fidelity/convergence are exercised in tests/benchmarks (CPU, small meshes);
the dry-run lowers the same code on the production mesh.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.sketch import PytreeSketcher, SketchConfig


def _balanced_pow2_dims(elems: int, order: int) -> tuple[int, ...]:
    """Tensorize a power-of-two bucket into `order` balanced pow2 modes.

    Spreads the exponent as evenly as possible, larger modes first —
    order=3 over the default 2^20 bucket reproduces the classic
    (128, 128, 64); order=4 gives (32, 32, 32, 32).
    """
    if order < 1:
        raise ValueError(f"order must be a positive integer, got {order}")
    e = elems.bit_length() - 1
    if elems <= 0 or (1 << e) != elems:
        raise ValueError(
            f"order= without dims= needs a power-of-two bucket, got {elems}")
    base, extra = divmod(e, order)
    if base == 0:
        raise ValueError(f"order={order} is too high for a {elems}-element "
                         "bucket (a mode would collapse to 1)")
    return tuple(1 << (base + (1 if i < extra else 0)) for i in range(order))


def parse_compress_flag(flag: str) -> SketchConfig:
    """'<family>:k=4096,rank=2[,dims=128x128x64][,order=4]' -> SketchConfig.

    `family` is any registered repro.rp family ('tt', 'cp', 'gaussian',
    'sparse', ...); SketchConfig validates it against the registry.
    `order=N` without `dims=` tensorizes the default bucket into N balanced
    power-of-two modes (the order-N kernel path: same bucket/compression,
    smaller operator); with `dims=` it just cross-checks len(dims) == N.
    """
    family, _, rest = flag.partition(":")
    kw: dict[str, Any] = {"family": family}
    order: int | None = None
    if rest:
        for part in rest.split(","):
            key, _, val = part.partition("=")
            if key == "dims":
                dims = tuple(int(x) for x in val.split("x"))
                kw["dims"] = dims
                kw["bucket_elems"] = 1
                for d in dims:
                    kw["bucket_elems"] *= d
            elif key in ("k", "rank"):
                kw[key] = int(val)
            elif key == "order":
                order = int(val)
    if order is not None:
        if "dims" in kw:
            if len(kw["dims"]) != order:
                raise ValueError(
                    f"order={order} contradicts dims="
                    f"{'x'.join(map(str, kw['dims']))} (order "
                    f"{len(kw['dims'])})")
        else:
            elems = SketchConfig.__dataclass_fields__["bucket_elems"].default
            kw["dims"] = _balanced_pow2_dims(elems, order)
            kw["bucket_elems"] = elems
    return SketchConfig(**kw)


@dataclasses.dataclass
class SketchCompressor:
    cfg: SketchConfig
    pod_axis: str | None = None     # lax axis name inside shard_map
    base_key: int = 0x5EED
    # Cross-pod sync formulation for compress_per_pod (equal by linearity):
    #   'local-mean'  — ONE adjoint pass per pod; the sync point is the
    #                   pod-mean of the dense local reconstructions (cheapest
    #                   compute, dense bytes on the pod axis);
    #   'sketch-mean' — sync the (buckets, k) sketch-mean (k-sized bytes on
    #                   the wire), then every pod redundantly unsketches it
    #                   (second adjoint pass). Prefer when the pod link is
    #                   bandwidth-bound.
    sync: str = "local-mean"

    def __post_init__(self):
        if self.sync not in ("local-mean", "sketch-mean"):
            raise ValueError(f"unknown sync mode {self.sync!r}; expected "
                             "'local-mean' or 'sketch-mean'")
    # (structure-key, sketcher) memo — the tree structure is fixed across
    # steps, so the flatten + family/registry validation in PytreeSketcher
    # runs once instead of on every compress/compress_per_pod trace.
    _sk_cache: tuple | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def _sketcher(self, tree) -> PytreeSketcher:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        key = (treedef, tuple(tuple(l.shape) for l in leaves),
               tuple(jnp.dtype(l.dtype).name for l in leaves))
        if self._sk_cache is not None and self._sk_cache[0] == key:
            return self._sk_cache[1]
        sk = PytreeSketcher(self.cfg, tree)
        self._sk_cache = (key, sk)
        return sk

    def init_state(self, params) -> dict:
        return {"residual": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def _key(self, step):
        key = jax.random.PRNGKey(self.base_key)
        if self.cfg.fresh_per_step:
            key = jax.random.fold_in(key, step)
        return key

    def compress(self, grads, state, *, step) -> tuple[Any, dict, dict]:
        """Single-worker roundtrip estimator (no comm): sketch -> unsketch
        with error feedback. Used on meshes without a pod axis."""
        sk = self._sketcher(grads)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads, state["residual"])
        alpha = self.cfg.shrinkage()
        y = sk.sketch(p, key)                           # (buckets, k)
        g_hat = jax.tree.map(lambda x: alpha * x, sk.unsketch(y, key))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype), g_hat, grads)
        return g_out, {"residual": new_residual}, self._metrics(sk, new_residual)

    def compress_per_pod(self, grads_pp, state, *, step):
        """Cross-pod compressed all-reduce, pure-pjit formulation.

        grads_pp / state['residual']: every leaf has a leading npod dim
        (produced by jax.vmap(..., spmd_axis_name='pod') so the dim is
        sharded over the pod mesh axis). Each pod runs ONE adjoint pass (its
        local unsketch, needed for the error-feedback residual anyway); by
        linearity of the adjoint, unsketch(mean_w y_w) == mean_w
        unsketch(y_w), so with the default sync='local-mean' the synced
        estimate is the pod-mean of the local reconstructions and the
        redundant second reconstruction of the old unsketch(y_mean)
        formulation is gone; sync='sketch-mean' keeps that formulation for
        bandwidth-bound pod links (see the `sync` field / module docstring
        for the compute-vs-bandwidth tradeoff).
        Returns (synced grads WITHOUT pod dim, new_state, metrics).
        """
        example = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:],
                                                              g.dtype),
                               grads_pp)
        sk = self._sketcher(example)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads_pp, state["residual"])
        alpha = self.cfg.shrinkage()
        y_pp = jax.vmap(lambda t: sk.sketch(t, key))(p)   # (npod, buckets, k)
        g_hat_local = jax.tree.map(
            lambda x: alpha * x,
            jax.vmap(lambda yy: sk.unsketch(yy, key))(y_pp))
        if self.sync == "local-mean":
            # == alpha * unsketch(mean(y_pp, 0)) by linearity, WITHOUT a
            # second adjoint pass; syncs dense bytes over the pod axis.
            g_hat = jax.tree.map(lambda gh: jnp.mean(gh, axis=0), g_hat_local)
        else:  # 'sketch-mean' (sync validated in __post_init__)
            y_mean = jnp.mean(y_pp, axis=0)       # k-sized wire bytes
            g_hat = jax.tree.map(lambda x: alpha * x,
                                 sk.unsketch(y_mean, key))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat_local)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype),
                             g_hat, example)
        metrics = self._metrics(sk, new_residual)
        # actual per-step cross-pod wire bytes of the ACTIVE sync mode —
        # sketch_bytes/dense_bytes alone describe the sketch-mean
        # formulation and would misreport 'local-mean' comm on dashboards.
        metrics["wire_bytes"] = jnp.asarray(
            sk.sketch_bytes() if self.sync == "sketch-mean"
            else sk.dense_bytes(), jnp.float32)
        return g_out, {"residual": new_residual}, metrics

    def _metrics(self, sk: PytreeSketcher, residual) -> dict:
        return {
            "sketch_bytes": jnp.asarray(sk.sketch_bytes(), jnp.float32),
            "dense_bytes": jnp.asarray(sk.dense_bytes(), jnp.float32),
            "residual_norm": jnp.sqrt(sum(
                jnp.sum(jnp.square(r)) for r in jax.tree.leaves(residual))),
        }

    def compression_ratio(self, params) -> float:
        return self._sketcher(params).compression_ratio()
