"""Tensorized-RP gradient compression with error feedback.

The paper's map f_TT(R) / f_CP(R) gives an oblivious linear sketch whose
adjoint is an unbiased reconstruction (E[vec(S_i)vec(S_i)^T] = I). That makes
it a drop-in gradient compressor for the SLOW cross-pod axis:

  worker w:  p_w = g_w + e_w                 (error feedback)
             y_w = Sketch_t(p_w)             (k floats per 1M-float bucket)
  network:   y   = mean_w y_w                (all-reduce of sketches ONLY)
  worker w:  g_hat  = Unsketch_t(y)          (shared PRNG -> same operator)
             e_w'   = p_w - Unsketch_t(y_w)  (local residual)

All workers regenerate the operator from fold_in(key, step) — the operator
itself (O(kNdR^2) floats) never crosses the network; the paper's memory bound
is exactly why the whole operator fits in VMEM/cache. Topology: params are
FSDP-sharded *within* a pod and replicated *across* pods (DiLoCo-style
DDP-of-FSDP), so the pod axis syncs via this compressed all-reduce.

Fidelity/convergence are exercised in tests/benchmarks (CPU, small meshes);
the dry-run lowers the same code on the production mesh.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.sketch import PytreeSketcher, SketchConfig


def parse_compress_flag(flag: str) -> SketchConfig:
    """'<family>:k=4096,rank=2[,dims=128x128x64]' -> SketchConfig.

    `family` is any registered repro.rp family ('tt', 'cp', 'gaussian',
    'sparse', ...); SketchConfig validates it against the registry.
    """
    family, _, rest = flag.partition(":")
    kw: dict[str, Any] = {"family": family}
    if rest:
        for part in rest.split(","):
            key, _, val = part.partition("=")
            if key == "dims":
                dims = tuple(int(x) for x in val.split("x"))
                kw["dims"] = dims
                kw["bucket_elems"] = 1
                for d in dims:
                    kw["bucket_elems"] *= d
            elif key in ("k", "rank"):
                kw[key] = int(val)
    return SketchConfig(**kw)


@dataclasses.dataclass
class SketchCompressor:
    cfg: SketchConfig
    pod_axis: str | None = None     # lax axis name inside shard_map
    base_key: int = 0x5EED

    def _sketcher(self, tree) -> PytreeSketcher:
        return PytreeSketcher(self.cfg, tree)

    def init_state(self, params) -> dict:
        return {"residual": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def _key(self, step):
        key = jax.random.PRNGKey(self.base_key)
        if self.cfg.fresh_per_step:
            key = jax.random.fold_in(key, step)
        return key

    def compress(self, grads, state, *, step) -> tuple[Any, dict, dict]:
        """Single-worker roundtrip estimator (no comm): sketch -> unsketch
        with error feedback. Used on meshes without a pod axis."""
        sk = self._sketcher(grads)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads, state["residual"])
        alpha = self.cfg.shrinkage()
        y = sk.sketch(p, key)                           # (buckets, k)
        g_hat = jax.tree.map(lambda x: alpha * x, sk.unsketch(y, key))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype), g_hat, grads)
        return g_out, {"residual": new_residual}, self._metrics(sk, new_residual)

    def compress_per_pod(self, grads_pp, state, *, step):
        """Cross-pod compressed all-reduce, pure-pjit formulation.

        grads_pp / state['residual']: every leaf has a leading npod dim
        (produced by jax.vmap(..., spmd_axis_name='pod') so the dim is
        sharded over the pod mesh axis). The ONLY cross-pod communication is
        the mean over that dim of the (buckets, k) sketches.
        Returns (synced grads WITHOUT pod dim, new_state, metrics).
        """
        example = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:],
                                                              g.dtype),
                               grads_pp)
        sk = self._sketcher(example)
        key = self._key(step)
        p = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads_pp, state["residual"])
        alpha = self.cfg.shrinkage()
        y_pp = jax.vmap(lambda t: sk.sketch(t, key))(p)   # (npod, buckets, k)
        y_mean = jnp.mean(y_pp, axis=0)                   # <- the all-reduce
        g_hat = jax.tree.map(lambda x: alpha * x,
                             sk.unsketch(y_mean, key))    # synced estimate
        g_hat_local = jax.tree.map(
            lambda x: alpha * x,
            jax.vmap(lambda yy: sk.unsketch(yy, key))(y_pp))
        new_residual = jax.tree.map(lambda pp, gh: pp - gh.astype(jnp.float32),
                                    p, g_hat_local)
        g_out = jax.tree.map(lambda gh, g: gh.astype(g.dtype),
                             g_hat, example)
        return g_out, {"residual": new_residual}, self._metrics(sk, new_residual)

    def _metrics(self, sk: PytreeSketcher, residual) -> dict:
        return {
            "sketch_bytes": jnp.asarray(sk.sketch_bytes(), jnp.float32),
            "dense_bytes": jnp.asarray(sk.dense_bytes(), jnp.float32),
            "residual_norm": jnp.sqrt(sum(
                jnp.sum(jnp.square(r)) for r in jax.tree.leaves(residual))),
        }

    def compression_ratio(self, params) -> float:
        return self._sketcher(params).compression_ratio()
