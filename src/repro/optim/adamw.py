"""AdamW in pure JAX (no optax in this environment): decoupled weight decay,
bias correction, f32 moment math regardless of storage dtype, global-norm
clipping. Moments stored in the policy dtype ('mixed' -> f32, 'lean' -> bf16).

`update_sketched` is the FUSED sketch-compressed step: instead of
compressor.compress (reconstruct kernel -> dense g_hat in HBM -> EF
residual pass) followed by `update` (three more dense read/write passes),
each dense leaf runs ONE `repro.kernels.fused_update_buckets` launch that
reconstructs the gradient tile-by-tile from the sketch and applies error
feedback and the AdamW math in the kernel epilogue — the dense
reconstruction never materializes in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32


def init_state(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def update(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics


def update_sketched(params, grads, ef_state, opt_state, lr,
                    cfg: AdamWConfig, *, compressor, interpret: bool = True):
    """Fused sketch-compressed AdamW step: one kernel launch per leaf.

    Semantically equal (to fp32 kernel tolerance) to the unfused chain

        g_hat, ef', _ = compressor.compress(grads, ef_state,
                                            step=opt_state['count'])
        p', opt', _   = update(params, g_hat, opt_state, lr, cfg)

    but the dense reconstruction g_hat never touches HBM: after the
    (unchanged) sketch launch, each dense leaf's buckets run ONE
    `repro.kernels.fused_update_buckets` launch whose epilogue applies
    error feedback and the AdamW moment/param math to every tile while
    its reconstruction is still in VMEM. The fused path also keeps the
    gradient estimate in float32 end to end (the unfused chain casts it
    through the gradient storage dtype between compress and update).

    Requires `cfg.clip_norm is None` and a dense-leaf tree — both
    enforced with typed errors. `compressor` is a
    `repro.optim.SketchCompressor` whose family must be TT/CP at a
    kernel-supported order (the fused kernel IS the reconstruct sweep).

    Returns (new_params, new_opt_state, new_ef_state, metrics).
    """
    if cfg.clip_norm is not None:
        raise ValueError(
            "update_sketched fuses the optimizer into the unsketch kernel "
            "and never materializes the dense gradient estimate, so a "
            "global-norm clip over it is unavailable; construct "
            "AdamWConfig(clip_norm=None) for the fused path")
    # function-level imports: optim must not depend on rp/kernels at module
    # scope (core <-> rp import cycle)
    from repro import rp
    from repro.core.sketch import _is_struct_leaf
    from repro.kernels import fused_update_buckets

    if any(_is_struct_leaf(leaf) for leaf in jax.tree_util.tree_leaves(
            grads, is_leaf=_is_struct_leaf)):
        raise ValueError(
            "update_sketched supports dense gradient leaves only: "
            "structured (TT/CP-format) leaves reconstruct through the "
            "carry-sweep route and do not map onto the fused bucket "
            "kernel; use compressor.compress + update for such trees")
    sk = compressor._sketcher(grads)
    key = compressor._key(opt_state["count"])
    op = compressor.cfg.operator(key)
    alpha = compressor.cfg.shrinkage()
    p_fed = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                         grads, ef_state["residual"])
    y = sk.sketch(p_fed, key)                       # (n_buckets, k)
    count = opt_state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    flat_w, treedef = jax.tree.flatten(params)
    flat_pe = jax.tree.leaves(p_fed)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_w, new_m, new_v, new_r = [], [], [], []
    off = 0
    fused_hbm = 0
    for pe, w, m, v, nb, size, shape in zip(
            flat_pe, flat_w, flat_m, flat_v, sk._nb, sk._sizes, sk._shapes):
        rp.count_kernel_dispatch(family=compressor.cfg.family,
                                 structure="fused-update",
                                 order=len(compressor.cfg.dims))
        fused_hbm += rp.plan_update(op, nb, fused=True).cost.hbm_bytes
        r_b, w_b, m_b, v_b = fused_update_buckets(
            op, y[off:off + nb],
            sk._leaf_to_buckets(pe, nb), sk._leaf_to_buckets(w, nb),
            sk._leaf_to_buckets(m, nb), sk._leaf_to_buckets(v, nb),
            lr, c1, c2, alpha=alpha, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, interpret=interpret)
        off += nb
        new_r.append(sk._leaf_from_buckets(r_b, size, shape, jnp.float32))
        new_w.append(sk._leaf_from_buckets(w_b, size, shape, w.dtype))
        new_m.append(sk._leaf_from_buckets(m_b, size, shape, m.dtype))
        new_v.append(sk._leaf_from_buckets(v_b, size, shape, v.dtype))
    unflatten = jax.tree.unflatten
    new_ef = {"residual": unflatten(treedef, new_r)}
    metrics = compressor._metrics(sk, new_ef["residual"])
    # the plan layer's analytic HBM ledger for the fused launches this
    # step issued (sum over leaves) — what the perf/fused bench row gates
    metrics["fused_hbm_bytes"] = jnp.asarray(fused_hbm, jnp.float32)
    return (unflatten(treedef, new_w),
            {"m": unflatten(treedef, new_m), "v": unflatten(treedef, new_v),
             "count": count},
            new_ef, metrics)
