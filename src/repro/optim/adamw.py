"""AdamW in pure JAX (no optax in this environment): decoupled weight decay,
bias correction, f32 moment math regardless of storage dtype, global-norm
clipping. Moments stored in the policy dtype ('mixed' -> f32, 'lean' -> bf16).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32


def init_state(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def update(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
