from . import adamw, schedule
from .adamw import AdamWConfig

__all__ = ["AdamWConfig", "adamw", "schedule"]
