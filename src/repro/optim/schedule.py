"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr: float, warmup_steps: int,
                       total_steps: int, final_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * step / max(1, warmup_steps)
    prog = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    del step
    return peak_lr
