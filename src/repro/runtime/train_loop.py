"""Fault-tolerant training loop: deterministic data fast-forward, async
checkpoints, watchdog, SIGTERM-safe shutdown, optional sketch telemetry.

Used by launch/train.py (CLI) and examples/; tests drive it with fault
injection to verify crash-restart recovers bit-identical state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpointer
from repro.data import DataConfig, SyntheticLM

from .resilience import FaultInjector, GracefulShutdown, Watchdog


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    async_ckpt: bool = True


def run(step_fn: Callable, state: Any, data: SyntheticLM, cfg: LoopConfig, *,
        injector: FaultInjector | None = None,
        log: Callable[[str], None] = print,
        on_metrics: Callable[..., None] | None = None) -> tuple[Any, int]:
    """Runs step_fn(state, batch)->(state, metrics) until total_steps.

    Resumes from the latest checkpoint in cfg.ckpt_dir if one exists; the
    data stream fast-forwards to the restored step (pure function of step).
    `on_metrics(step, metrics, state)` receives the LIVE post-step state —
    with donated input buffers, closing over the pre-loop state reads
    deleted arrays. Returns (final_state, final_step).
    """
    start = 0
    if cfg.ckpt_dir:
        latest = checkpointer.latest_step(cfg.ckpt_dir)
        if latest is not None:
            state, start = checkpointer.restore(cfg.ckpt_dir, state)
            log(f"[resume] restored step {start} from {cfg.ckpt_dir}")
    ck = (checkpointer.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts)
          if (cfg.ckpt_dir and cfg.async_ckpt) else None)
    wd = Watchdog()
    t_start = time.time()
    step = start
    with GracefulShutdown() as shutdown:
        for step in range(start, cfg.total_steps):
            if injector is not None:
                injector.maybe_crash(step)
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            wd.start_step()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            ev = wd.end_step(step)
            if ev is not None:
                log(f"[straggler] step {step}: {ev.dt:.3f}s "
                    f"(ema {ev.ema:.3f}s, z={ev.zscore:.1f})")
            if on_metrics is not None:
                on_metrics(step, metrics, state)
            if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                scal = {k: float(v) for k, v in metrics.items()
                        if hasattr(v, "shape") and v.shape == ()}
                log(f"step {step:6d} " + " ".join(
                    f"{k}={v:.5g}" for k, v in sorted(scal.items())))
            want_ckpt = cfg.ckpt_dir and (
                (step + 1) % cfg.ckpt_every == 0
                or step == cfg.total_steps - 1 or shutdown.requested)
            if want_ckpt:
                if ck is not None:
                    ck.save(step + 1, state)
                else:
                    checkpointer.save(cfg.ckpt_dir, step + 1, state,
                                      keep=cfg.keep_ckpts)
            if shutdown.requested:
                log(f"[shutdown] SIGTERM honored at step {step}")
                break
    if ck is not None:
        ck.wait()
    dt = time.time() - t_start
    log(f"[done] steps {start}..{step} in {dt:.1f}s "
        f"({len(wd.events)} straggler events)")
    return state, step + 1
