"""Fault-tolerant training loop: deterministic data fast-forward, verified
async checkpoints (with fallback to the newest checkpoint that passes its
integrity check), sketched error-feedback state, watchdog, SIGTERM-safe
shutdown, optional sketch telemetry.

Used by launch/train.py (CLI) and examples/; tests drive it with fault
injection to verify crash-restart recovers bit-identical state — including
through a corrupted newest checkpoint (restore falls back) and with the EF
residual persisted as a (seed, spec, sketch) record instead of its dense
bytes (`ef_codec`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.ckpt import checkpointer
from repro.data import SyntheticLM

from .resilience import FaultInjector, GracefulShutdown, Watchdog


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    async_ckpt: bool = True
    # recorded in every manifest's `extra` so `ckpt.resume_elastic` knows the
    # pod count the EF state was written with
    npod: int = 1
    # corruption handling on resume: verify checksums and fall back to the
    # newest checkpoint that passes (False restores blind, seed behavior)
    verify_restore: bool = True


def _to_save(state: Any, step: int, ef_codec) -> tuple[Any, dict]:
    """(tree to write, manifest extra) — EF leaves go as sketch records."""
    extra: dict = {}
    tree = state
    if ef_codec is not None and "ef" in state:
        tree = dict(state)
        tree["ef"] = ef_codec.encode(state["ef"], step=step)
        extra["sketched_ef"] = ef_codec.meta()
    return tree, extra


def run(step_fn: Callable, state: Any, data: SyntheticLM, cfg: LoopConfig, *,
        injector: FaultInjector | None = None,
        log: Callable[[str], None] = print,
        on_metrics: Callable[..., None] | None = None,
        ef_codec=None) -> tuple[Any, int]:
    """Runs step_fn(state, batch)->(state, metrics) until total_steps.

    Resumes from the newest VERIFIED checkpoint in cfg.ckpt_dir if one
    exists (a truncated array or flipped manifest byte in the newest one
    falls back to the previous verified checkpoint); the data stream
    fast-forwards to the restored step (pure function of step).
    `ef_codec` (a `repro.ckpt.SketchedTreeCodec` over state["ef"]) persists
    the error-feedback tree as a (seed, spec, sketch) record — nb*k floats
    on disk instead of the dense tensor — and reconstructs it
    deterministically on restore. `on_metrics(step, metrics, state)`
    receives the LIVE post-step state — with donated input buffers, closing
    over the pre-loop state reads deleted arrays. Returns
    (final_state, final_step).
    """
    start = 0
    if cfg.ckpt_dir:
        latest = checkpointer.latest_step(cfg.ckpt_dir)
        if latest is not None:
            example = state
            if ef_codec is not None and "ef" in state:
                example = dict(state)
                example["ef"] = ef_codec.record_shapes()
            restored, start = checkpointer.restore(
                cfg.ckpt_dir, example,
                verify_integrity=cfg.verify_restore, fallback=True)
            if ef_codec is not None and "ef" in state:
                restored["ef"] = ef_codec.decode(restored["ef"])
            state = restored
            if start != latest:
                log(f"[resume] newest checkpoint (step {latest}) failed "
                    f"verification; fell back to verified step {start}")
                obs.event("ckpt.fallback", step_requested=latest,
                          step_restored=start, dir=str(cfg.ckpt_dir))
            log(f"[resume] restored step {start} from {cfg.ckpt_dir}")
            obs.event("ckpt.resume", step=start, dir=str(cfg.ckpt_dir))
    ck = (checkpointer.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts)
          if (cfg.ckpt_dir and cfg.async_ckpt) else None)
    wd = Watchdog()
    t_start = time.time()
    step = start
    with GracefulShutdown() as shutdown:
        for step in range(start, cfg.total_steps):
            if injector is not None:
                injector.maybe_crash(step)
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            with obs.span("train.step", step=step):
                wd.start_step()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                ev = wd.end_step(step)
            if ev is not None:
                log(f"[straggler] step {step}: {ev.dt:.3f}s "
                    f"(ema {ev.ema:.3f}s, z={ev.zscore:.1f})")
                # the log string stays (operators grep for it); the event is
                # the machine-readable copy — a metrics event plus a trace
                # instant pinned at the offending step's timeline position
                obs.event("train.straggler", step=step, dt=ev.dt,
                          ema=ev.ema, zscore=ev.zscore)
            if on_metrics is not None:
                on_metrics(step, metrics, state)
            if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                scal = {k: float(v) for k, v in metrics.items()
                        if hasattr(v, "shape") and v.shape == ()}
                log(f"step {step:6d} " + " ".join(
                    f"{k}={v:.5g}" for k, v in sorted(scal.items())))
            want_ckpt = cfg.ckpt_dir and (
                (step + 1) % cfg.ckpt_every == 0
                or step == cfg.total_steps - 1 or shutdown.requested)
            if want_ckpt:
                tree, extra = _to_save(state, step + 1, ef_codec)
                extra["npod"] = cfg.npod
                if ck is not None:
                    ck.save(step + 1, tree, extra=extra)
                else:
                    checkpointer.save(cfg.ckpt_dir, step + 1, tree,
                                      keep=cfg.keep_ckpts, extra=extra)
            if shutdown.requested:
                log(f"[shutdown] SIGTERM honored at step {step}")
                break
    if ck is not None:
        ck.close()  # drain the in-flight save; a clean exit never drops it
    dt = time.time() - t_start
    log(f"[done] steps {start}..{step} in {dt:.1f}s "
        f"({len(wd.events)} straggler events)")
    return state, step + 1
