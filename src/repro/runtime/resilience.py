"""Fault-tolerance runtime: watchdog (straggler detection), signal-triggered
checkpointing, and a crash-restart harness with fault injection for tests.

At 1000+ node scale the failure model is: slow chips (stragglers), killed
hosts (preemption), and hard crashes. The mitigations here:
  * Watchdog — EMA + z-score over step wall-times; flags stragglers and
    (optionally) invokes a callback (real deployments: trigger re-shard or
    hot-spare swap; here: structured log events consumed by tests).
  * GracefulShutdown — SIGTERM/SIGINT => finish the current step, checkpoint,
    exit 0 (preemption-safe).
  * run_with_restarts — supervises a training function, restarting it from
    the latest checkpoint after crashes, up to a budget. The training fn gets
    a FaultInjector so tests can deterministically kill a step.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


@dataclasses.dataclass
class WatchdogEvent:
    step: int
    dt: float
    ema: float
    zscore: float


class Watchdog:
    def __init__(self, *, warmup: int = 5, z_thresh: float = 4.0,
                 on_straggler: Callable[[WatchdogEvent], None] | None = None):
        self.warmup = warmup
        self.z_thresh = z_thresh
        self.on_straggler = on_straggler
        self.ema = None
        self.var = 0.0
        self.n = 0
        self.events: list[WatchdogEvent] = []
        self._last = None

    def start_step(self) -> None:
        self._last = time.monotonic()

    def end_step(self, step: int) -> WatchdogEvent | None:
        assert self._last is not None, "start_step not called"
        dt = time.monotonic() - self._last
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return None
        alpha = 0.1
        dev = dt - self.ema
        self.var = (1 - alpha) * (self.var + alpha * dev * dev)
        self.ema += alpha * dev
        sd = max(self.var ** 0.5, 1e-9)
        z = dev / sd
        if self.n > self.warmup and z > self.z_thresh:
            ev = WatchdogEvent(step=step, dt=dt, ema=self.ema, zscore=z)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            return ev
        return None


class GracefulShutdown:
    """Context manager: converts SIGTERM/SIGINT into a `requested` flag the
    training loop checks once per step."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self.requested = False
        self._old = {}

    def _handler(self, signum, frame):
        del frame
        self.requested = True

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False


class FaultInjector:
    """Deterministic fault injection for restart tests."""

    def __init__(self, crash_at_steps: set[int] | None = None):
        self.crash_at_steps = set(crash_at_steps or ())
        self.fired: set[int] = set()

    def maybe_crash(self, step: int) -> None:
        if step in self.crash_at_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed: bool
    final_step: int
    history: list


def run_with_restarts(train_fn: Callable[..., int], *, max_restarts: int = 3,
                      injector: FaultInjector | None = None) -> RestartReport:
    """train_fn(injector) -> final step; must checkpoint internally and
    resume from its own latest checkpoint when re-invoked."""
    injector = injector or FaultInjector()
    history = []
    restarts = 0
    while True:
        try:
            final = train_fn(injector)
            return RestartReport(restarts=restarts, completed=True,
                                 final_step=final, history=history)
        except Exception as e:  # noqa: BLE001 — supervisor boundary
            history.append(repr(e))
            restarts += 1
            if restarts > max_restarts:
                return RestartReport(restarts=restarts, completed=False,
                                     final_step=-1, history=history)
