"""Fault-tolerance runtime: watchdog (straggler detection), signal-triggered
checkpointing, retry/backoff primitives, injectable I/O faults, and a
crash-restart supervisor.

At 1000+ node scale the failure model is: slow chips (stragglers), killed
hosts (preemption), hard crashes, and STORAGE faults — torn writes, bit
flips, failed renames, flaky/slow filesystems. The mitigations here:

  * Watchdog — EMA + z-score over step wall-times; flags stragglers and
    (optionally) invokes a callback (real deployments: trigger re-shard or
    hot-spare swap; here: structured log events consumed by tests).
  * GracefulShutdown — SIGTERM/SIGINT => finish the current step, checkpoint,
    exit 0 (preemption-safe).
  * retry_with_backoff — capped exponential backoff around a transient
    (retryable, by default OSError) operation; the checkpointer wraps every
    array write and the final atomic rename in it.
  * CheckpointIO / IOFaultInjector — the checkpointer's I/O surface as an
    injectable object. The injector deterministically produces the storage
    failure modes the verified-restore path must survive: transient write
    failures (retried), failed renames (retried), slow writes (straggler
    I/O), truncated arrays and flipped bytes (caught by checksums on
    restore, triggering fallback to the newest verified checkpoint).
  * run_with_restarts — supervises a training function, restarting it from
    the latest checkpoint after RETRYABLE crashes with capped exponential
    backoff between attempts, up to a budget; FATAL failures (by default
    ValueError/TypeError — misconfiguration and corruption-with-no-fallback
    don't fix themselves by rerunning) stop the supervisor immediately. The
    training fn gets a FaultInjector so tests can deterministically kill a
    step.
"""
from __future__ import annotations

import dataclasses
import pathlib
import signal
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class WatchdogEvent:
    step: int
    dt: float
    ema: float
    zscore: float


class Watchdog:
    """EMA + z-score straggler detector over per-step wall times.

    `start_step()` / `end_step(step)` bracket each training step; after
    `warmup` steps, a step whose duration sits more than `z_thresh` standard
    deviations above the EMA is recorded as a `WatchdogEvent` (and passed to
    `on_straggler` when set).
    """

    def __init__(self, *, warmup: int = 5, z_thresh: float = 4.0,
                 on_straggler: Callable[[WatchdogEvent], None] | None = None):
        self.warmup = warmup
        self.z_thresh = z_thresh
        self.on_straggler = on_straggler
        self.ema = None
        self.var = 0.0
        self.n = 0
        self.events: list[WatchdogEvent] = []
        self._last = None

    def start_step(self) -> None:
        self._last = time.monotonic()

    def end_step(self, step: int) -> WatchdogEvent | None:
        if self._last is None:
            raise ValueError("Watchdog.end_step called without start_step")
        dt = time.monotonic() - self._last
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return None
        alpha = 0.1
        dev = dt - self.ema
        # score against the PRE-update statistics: folding the sample into
        # the variance first bounds z at 1/sqrt((1-alpha)*alpha) ~ 3.33,
        # i.e. the spike inflates the very baseline it is measured against
        # and a z_thresh of 4 can never fire
        sd = max(self.var ** 0.5, 1e-9)
        z = dev / sd
        self.var = (1 - alpha) * (self.var + alpha * dev * dev)
        self.ema += alpha * dev
        if self.n > self.warmup and z > self.z_thresh:
            ev = WatchdogEvent(step=step, dt=dt, ema=self.ema, zscore=z)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            return ev
        return None


class GracefulShutdown:
    """Context manager: converts SIGTERM/SIGINT into a `requested` flag the
    training loop checks once per step (preemption-safe: the loop finishes
    the current step, checkpoints, and exits cleanly)."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self.requested = False
        self._old = {}

    def _handler(self, signum, frame):
        del frame
        self.requested = True

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False


# ---------------------------------------------------------------------------
# Retry / backoff
# ---------------------------------------------------------------------------

def backoff_delays(retries: int, *, base_delay: float = 0.05,
                   max_delay: float = 2.0) -> list[float]:
    """The capped exponential schedule retry_with_backoff sleeps through."""
    return [min(max_delay, base_delay * (2.0 ** i)) for i in range(retries)]


def retry_with_backoff(fn: Callable, *, retries: int = 3,
                       base_delay: float = 0.05, max_delay: float = 2.0,
                       retryable: tuple = (OSError,),
                       sleep: Callable[[float], None] = time.sleep,
                       on_retry: Callable | None = None):
    """Run `fn()`, retrying `retryable` exceptions with capped exponential
    backoff. Non-retryable exceptions propagate immediately; the last
    retryable one propagates after the budget is spent.

    `sleep` is injectable so tests assert the schedule without waiting it
    out; `on_retry(attempt, delay, exc)` observes each retry.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:
            attempt += 1
            if attempt > retries:
                raise
            delay = min(max_delay, base_delay * (2.0 ** (attempt - 1)))
            if on_retry is not None:
                on_retry(attempt, delay, e)
            sleep(delay)


# ---------------------------------------------------------------------------
# Injectable checkpoint I/O
# ---------------------------------------------------------------------------

class CheckpointIO:
    """The checkpointer's storage surface: array writes, the atomic rename,
    and a post-commit hook. Subclass to inject faults (IOFaultInjector) or
    to retarget storage (object stores, TensorStore) without touching the
    save logic."""

    def write_array(self, path, arr) -> None:
        np.save(path, arr)

    def rename(self, src, dst) -> None:
        pathlib.Path(src).rename(dst)

    def post_commit(self, final_dir) -> None:
        """Called once after the atomic rename lands; no-op by default."""


@dataclasses.dataclass
class IOFaultPlan:
    """Deterministic storage-fault schedule for IOFaultInjector.

    fail_writes      : first N write_array calls raise OSError (transient —
                       the checkpointer's retry loop should absorb them).
    fail_renames     : first N rename calls raise OSError.
    slow_write_s     : sleep this long before every write (straggler I/O).
    truncate_file    : after writing this file NAME, truncate it to
                       `truncate_to` bytes (a torn write: the crc32 catches
                       it on verify and restore falls back).
    flip_byte_in     : after writing this file NAME, XOR one byte at
                       `flip_offset` (negative = from end).
    corrupt_manifest : after the atomic rename, flip one byte inside the
                       committed manifest.json (the sha256 catches it).
    """

    fail_writes: int = 0
    fail_renames: int = 0
    slow_write_s: float = 0.0
    truncate_file: str | None = None
    truncate_to: int = 32
    flip_byte_in: str | None = None
    flip_offset: int = -1
    corrupt_manifest: bool = False


def flip_byte(path, offset: int = -1) -> None:
    """XOR one byte of `path` in place (deterministic bit-flip injection)."""
    path = pathlib.Path(path)
    blob = bytearray(path.read_bytes())
    blob[offset] ^= 0xFF
    path.write_bytes(bytes(blob))


class IOFaultInjector(CheckpointIO):
    """CheckpointIO that executes an IOFaultPlan. Each fault class fires the
    scheduled number of times and then behaves like the real IO, so a save
    under `fail_writes=2, retries>=2` succeeds after backoff while
    `fail_writes=retries+1` exhausts the budget and surfaces the OSError."""

    def __init__(self, plan: IOFaultPlan | None = None, **kw):
        self.plan = plan if plan is not None else IOFaultPlan(**kw)
        self.writes = 0
        self.renames = 0
        self.injected: list[str] = []

    def write_array(self, path, arr) -> None:
        if self.plan.slow_write_s:
            time.sleep(self.plan.slow_write_s)
        self.writes += 1
        if self.writes <= self.plan.fail_writes:
            self.injected.append(f"write-fail:{pathlib.Path(path).name}")
            raise OSError(f"injected transient write failure #{self.writes}")
        super().write_array(path, arr)
        name = pathlib.Path(path).name
        if self.plan.truncate_file == name:
            with open(path, "r+b") as f:
                f.truncate(self.plan.truncate_to)
            self.injected.append(f"truncate:{name}")
        if self.plan.flip_byte_in == name:
            flip_byte(path, self.plan.flip_offset)
            self.injected.append(f"flip:{name}")

    def rename(self, src, dst) -> None:
        self.renames += 1
        if self.renames <= self.plan.fail_renames:
            self.injected.append(f"rename-fail:{pathlib.Path(dst).name}")
            raise OSError(f"injected rename failure #{self.renames}")
        super().rename(src, dst)

    def post_commit(self, final_dir) -> None:
        if self.plan.corrupt_manifest:
            flip_byte(pathlib.Path(final_dir) / "manifest.json")
            self.injected.append("flip:manifest.json")
            self.plan = dataclasses.replace(self.plan, corrupt_manifest=False)


# ---------------------------------------------------------------------------
# Crash injection + restart supervisor
# ---------------------------------------------------------------------------

class FaultInjector:
    """Deterministic crash injection for restart tests: raises once per
    scheduled step (`maybe_crash` is called at the top of every training
    step), so a supervised run crashes exactly where the test plants it."""

    def __init__(self, crash_at_steps: set[int] | None = None):
        self.crash_at_steps = set(crash_at_steps or ())
        self.fired: set[int] = set()

    def maybe_crash(self, step: int) -> None:
        if step in self.crash_at_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed: bool
    final_step: int
    history: list
    fatal_error: str | None = None


# Failures a restart cannot fix: misconfiguration, shape drift, corruption
# with no verified fallback (ckpt.CheckpointError subclasses ValueError).
FATAL_DEFAULT = (ValueError, TypeError)


def run_with_restarts(train_fn: Callable[..., int], *, max_restarts: int = 3,
                      injector: FaultInjector | None = None,
                      fatal: tuple = FATAL_DEFAULT,
                      base_delay: float = 0.0, max_delay: float = 30.0,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> RestartReport:
    """Supervise `train_fn(injector) -> final step`, restarting after crashes.

    The training fn must checkpoint internally and resume from its own
    latest checkpoint when re-invoked. The supervisor distinguishes
    RETRYABLE failures (everything outside `fatal`; restarted with capped
    exponential backoff — `base_delay * 2^attempt`, capped at `max_delay`)
    from FATAL ones (`fatal` classes: the report carries `fatal_error` and
    no restart is attempted — a ValueError from a changed tree structure or
    an unrecoverable checkpoint re-raises identically forever). `base_delay`
    defaults to 0 so tests don't sleep; production supervisors pass e.g.
    `base_delay=1.0`.
    """
    injector = injector or FaultInjector()
    history = []
    restarts = 0
    while True:
        try:
            final = train_fn(injector)
            return RestartReport(restarts=restarts, completed=True,
                                 final_step=final, history=history)
        except fatal as e:
            history.append(repr(e))
            return RestartReport(restarts=restarts, completed=False,
                                 final_step=-1, history=history,
                                 fatal_error=repr(e))
        except Exception as e:  # noqa: BLE001 — supervisor boundary
            history.append(repr(e))
            restarts += 1
            if restarts > max_restarts:
                return RestartReport(restarts=restarts, completed=False,
                                     final_step=-1, history=history)
            if base_delay > 0:
                sleep(min(max_delay, base_delay * (2.0 ** (restarts - 1))))
