"""Tensor-train (TT) and CP tensor containers + the algebra the paper relies on.

Conventions (match the paper, Sec. 2.2):
  * TT core n has shape (r_{n-1}, d_n, r_n), with r_0 = r_N = 1.
  * CP factor n has shape (d_n, R); the tensor is sum_r a_r^1 ∘ ... ∘ a_r^N.

Everything here is pure JAX (jit/vmap/grad-compatible); containers are
registered pytrees so they flow through jax transformations unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TTTensor:
    """Tensor-train tensor  <<G^1, ..., G^N>>  with cores (r_{n-1}, d_n, r_n)."""

    cores: tuple[jnp.ndarray, ...]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return tuple(self.cores), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(cores=tuple(children))

    # -- structure -------------------------------------------------------
    @property
    def order(self) -> int:
        return len(self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(c.shape[1]) for c in self.cores)

    @property
    def ranks(self) -> tuple[int, ...]:
        """Bond ranks (r_0, ..., r_N) including boundary 1s."""
        return tuple(int(c.shape[0]) for c in self.cores) + (int(self.cores[-1].shape[2]),)

    @property
    def dtype(self):
        return self.cores[0].dtype

    def num_params(self) -> int:
        return sum(_prod(c.shape) for c in self.cores)

    # -- algebra -----------------------------------------------------------
    def full(self) -> jnp.ndarray:
        """Materialize the dense tensor (exponential memory; tests only)."""
        out = self.cores[0]  # (1, d1, r1)
        out = out.reshape(out.shape[1], out.shape[2])  # (d1, r1)
        for core in self.cores[1:]:
            r_in, d, r_out = core.shape
            out = jnp.tensordot(out, core, axes=[[-1], [0]])  # (..., d, r_out)
        return out.reshape(self.dims)

    def norm_squared(self) -> jnp.ndarray:
        """||T||_F^2 computed in O(N d R^4) without materializing."""
        return tt_inner(self, self)

    def scale(self, alpha) -> "TTTensor":
        """Multiply the tensor by a scalar (applied to the first core)."""
        return TTTensor((self.cores[0] * alpha,) + tuple(self.cores[1:]))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPTensor:
    """CP tensor  [[A^1, ..., A^N]]  with factors (d_n, R)."""

    factors: tuple[jnp.ndarray, ...]
    # Optional per-component weights (R,); None means all-ones.
    weights: jnp.ndarray | None = None

    def tree_flatten(self):
        if self.weights is None:
            return tuple(self.factors), ("noweights",)
        return tuple(self.factors) + (self.weights,), ("weights",)

    @classmethod
    def tree_unflatten(cls, aux, children):
        if aux[0] == "weights":
            return cls(factors=tuple(children[:-1]), weights=children[-1])
        return cls(factors=tuple(children), weights=None)

    @property
    def order(self) -> int:
        return len(self.factors)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(f.shape[0]) for f in self.factors)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[1])

    @property
    def dtype(self):
        return self.factors[0].dtype

    def num_params(self) -> int:
        n = sum(_prod(f.shape) for f in self.factors)
        if self.weights is not None:
            n += _prod(self.weights.shape)
        return n

    def full(self) -> jnp.ndarray:
        out = self.factors[0]  # (d1, R)
        if self.weights is not None:
            out = out * self.weights[None, :]
        for f in self.factors[1:]:
            # out: (prod(d..), R) -> (prod(d..)*d, R)
            out = jnp.einsum("pr,dr->pdr", out, f).reshape(-1, out.shape[-1])
        return out.sum(-1).reshape(self.dims)

    def norm_squared(self) -> jnp.ndarray:
        return cp_inner(self, self)

    def scale(self, alpha) -> "CPTensor":
        return CPTensor((self.factors[0] * alpha,) + tuple(self.factors[1:]), self.weights)

    def to_tt(self) -> TTTensor:
        """Exact CP -> TT conversion with bond rank == R (diagonal cores)."""
        R = self.rank
        cores = []
        for n, f in enumerate(self.factors):  # f: (d, R)
            if n == 0:
                w = f if self.weights is None else f * self.weights[None, :]
                cores.append(w.T[None, :, :].transpose(0, 2, 1))  # (1, d, R)
            elif n == len(self.factors) - 1:
                cores.append(f.T[:, :, None])  # (R, d, 1)
            else:
                # diag core: core[r, i, r'] = f[i, r] * delta(r, r')
                eye = jnp.eye(R, dtype=f.dtype)
                cores.append(jnp.einsum("dr,rs->rds", f, eye))
        return TTTensor(tuple(cores))


# ---------------------------------------------------------------------------
# Batched structured containers (the compressed-domain sketching subsystem's
# input format: B same-structure tensors sharing one leading batch axis, so
# a whole batch of TT/CP-format inputs projects in ONE kernel launch)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BatchedTTTensor:
    """A batch of B same-structure TT tensors; cores[n]: (B, r_{n-1}, d_n, r_n).

    Every tensor in the batch shares dims and bond ranks (a requirement of
    the carry-sweep kernels, whose BlockSpecs tile the leading batch axis).
    Build one with `stack` from a list of `TTTensor`s or directly from
    batched cores; `unstack` recovers the per-item tensors.
    """

    cores: tuple[jnp.ndarray, ...]

    def tree_flatten(self):
        return tuple(self.cores), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(cores=tuple(children))

    @classmethod
    def stack(cls, tensors: Sequence[TTTensor]) -> "BatchedTTTensor":
        first = tensors[0]
        for t in tensors[1:]:
            if t.dims != first.dims or t.ranks != first.ranks:
                raise ValueError(
                    f"cannot stack TT tensors with mismatched structure: "
                    f"{(t.dims, t.ranks)} != {(first.dims, first.ranks)}")
        return cls(tuple(jnp.stack([t.cores[n] for t in tensors])
                         for n in range(first.order)))

    def unstack(self) -> list[TTTensor]:
        return [TTTensor(tuple(c[i] for c in self.cores))
                for i in range(self.batch)]

    def __getitem__(self, i: int) -> TTTensor:
        return TTTensor(tuple(c[i] for c in self.cores))

    @property
    def batch(self) -> int:
        return int(self.cores[0].shape[0])

    @property
    def order(self) -> int:
        return len(self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(c.shape[2]) for c in self.cores)

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(int(c.shape[1]) for c in self.cores) + (
            int(self.cores[-1].shape[3]),)

    @property
    def dtype(self):
        return self.cores[0].dtype

    def num_params(self) -> int:
        return sum(_prod(c.shape) for c in self.cores)

    def full(self) -> jnp.ndarray:
        """Materialize the dense (B, *dims) batch (tests/small cases only)."""
        return jax.vmap(lambda *cs: TTTensor(cs).full())(*self.cores)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BatchedCPTensor:
    """A batch of B same-rank CP tensors; factors[n]: (B, d_n, R).

    Optional per-item component weights have shape (B, R); None means
    all-ones. See `BatchedTTTensor` for the stack/unstack contract.
    """

    factors: tuple[jnp.ndarray, ...]
    weights: jnp.ndarray | None = None

    def tree_flatten(self):
        if self.weights is None:
            return tuple(self.factors), ("noweights",)
        return tuple(self.factors) + (self.weights,), ("weights",)

    @classmethod
    def tree_unflatten(cls, aux, children):
        if aux[0] == "weights":
            return cls(factors=tuple(children[:-1]), weights=children[-1])
        return cls(factors=tuple(children), weights=None)

    @classmethod
    def stack(cls, tensors: Sequence[CPTensor]) -> "BatchedCPTensor":
        first = tensors[0]
        for t in tensors[1:]:
            if t.dims != first.dims or t.rank != first.rank:
                raise ValueError(
                    f"cannot stack CP tensors with mismatched structure: "
                    f"{(t.dims, t.rank)} != {(first.dims, first.rank)}")
        has_w = [t.weights is not None for t in tensors]
        if any(has_w) and not all(has_w):
            raise ValueError("cannot stack CP tensors mixing weighted and "
                             "unweighted components")
        factors = tuple(jnp.stack([t.factors[n] for t in tensors])
                        for n in range(first.order))
        weights = (jnp.stack([t.weights for t in tensors])
                   if all(has_w) else None)
        return cls(factors, weights)

    def unstack(self) -> list[CPTensor]:
        return [self[i] for i in range(self.batch)]

    def __getitem__(self, i: int) -> CPTensor:
        w = None if self.weights is None else self.weights[i]
        return CPTensor(tuple(f[i] for f in self.factors), w)

    @property
    def batch(self) -> int:
        return int(self.factors[0].shape[0])

    @property
    def order(self) -> int:
        return len(self.factors)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(f.shape[1]) for f in self.factors)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[2])

    @property
    def dtype(self):
        return self.factors[0].dtype

    def num_params(self) -> int:
        n = sum(_prod(f.shape) for f in self.factors)
        if self.weights is not None:
            n += _prod(self.weights.shape)
        return n

    def full(self) -> jnp.ndarray:
        """Materialize the dense (B, *dims) batch (tests/small cases only)."""
        if self.weights is None:
            return jax.vmap(lambda *fs: CPTensor(fs).full())(*self.factors)
        return jax.vmap(lambda *a: CPTensor(a[:-1], a[-1]).full())(
            *self.factors, self.weights)


# The canonical structured-container tuple: everything that dispatches to
# the compressed-domain (carry-sweep) projection path. Consumers (rp
# dispatch, the sketcher, kernels.struct) import THIS rather than
# hand-maintaining their own copies — a new container registers here once.
STRUCT_TYPES = (TTTensor, CPTensor, BatchedTTTensor, BatchedCPTensor)


# ---------------------------------------------------------------------------
# Rank-ragged coalescing: zero-pad structural ranks so SAME-dims tensors of
# DIFFERENT ranks stack into one batched container (the serving batcher's
# lane assembly — heterogeneous in-flight requests, one kernel dispatch).
# EXACT, not approximate: a zero-padded bond/component channel contributes a
# term with at least one zero factor to every entry of the full tensor, so
# `pad_*_rank(t, ...).full() == t.full()` bitwise up to the usual float
# contraction order.
# ---------------------------------------------------------------------------

def pad_tt_rank(t: TTTensor, ranks: Sequence[int]) -> TTTensor:
    """Zero-pad a TT tensor's INTERIOR bond ranks up to `ranks` (len N+1).

    Boundary ranks (r_0, r_N) must match the target exactly — `full()` and
    the kernels rely on them, and padding a boundary would change the
    tensor's meaning (extra outer slices), not embed it.
    """
    cur = t.ranks
    ranks = tuple(int(r) for r in ranks)
    if len(ranks) != t.order + 1:
        raise ValueError(f"target ranks {ranks} must have length "
                         f"order+1 = {t.order + 1}")
    if ranks[0] != cur[0] or ranks[-1] != cur[-1]:
        raise ValueError(f"cannot pad TT boundary ranks {cur[0], cur[-1]} "
                         f"to {ranks[0], ranks[-1]}")
    if any(r < c for r, c in zip(ranks, cur)):
        raise ValueError(f"target ranks {ranks} below current {cur}")
    cores = tuple(
        jnp.pad(c, ((0, ranks[n] - cur[n]), (0, 0),
                    (0, ranks[n + 1] - cur[n + 1])))
        for n, c in enumerate(t.cores))
    return TTTensor(cores)


def pad_cp_rank(t: CPTensor, rank: int) -> CPTensor:
    """Zero-pad a CP tensor's component rank up to `rank` (exact)."""
    if rank < t.rank:
        raise ValueError(f"target rank {rank} below current {t.rank}")
    if rank == t.rank:
        return t
    factors = tuple(jnp.pad(f, ((0, 0), (0, rank - t.rank)))
                    for f in t.factors)
    weights = (None if t.weights is None
               else jnp.pad(t.weights, (0, rank - t.rank)))
    return CPTensor(factors, weights)


def stack_ragged_tt(tensors: Sequence[TTTensor]) -> BatchedTTTensor:
    """Stack same-dims TT tensors of possibly DIFFERENT bond ranks.

    Interior ranks are zero-padded to the per-bond max (exact); dims (and
    boundary ranks) must agree — that is a structural mismatch no padding
    can hide, and raises a ValueError naming it.
    """
    first = tensors[0]
    for t in tensors[1:]:
        if t.dims != first.dims:
            raise ValueError(f"cannot coalesce TT tensors with mismatched "
                             f"dims: {t.dims} != {first.dims}")
    ranks = tuple(max(t.ranks[n] for t in tensors)
                  for n in range(first.order + 1))
    return BatchedTTTensor.stack([pad_tt_rank(t, ranks) for t in tensors])


def stack_ragged_cp(tensors: Sequence[CPTensor]) -> BatchedCPTensor:
    """Stack same-dims CP tensors of possibly DIFFERENT component ranks.

    Ranks are zero-padded to the max (exact). A mix of weighted and
    unweighted tensors is coalesced by materializing all-ones weights for
    the unweighted ones BEFORE padding (ones on real components, zeros on
    padded ones — exact either way).
    """
    first = tensors[0]
    for t in tensors[1:]:
        if t.dims != first.dims:
            raise ValueError(f"cannot coalesce CP tensors with mismatched "
                             f"dims: {t.dims} != {first.dims}")
    rank = max(t.rank for t in tensors)
    if any(t.weights is not None for t in tensors):
        tensors = [t if t.weights is not None
                   else CPTensor(t.factors, jnp.ones((t.rank,), t.dtype))
                   for t in tensors]
    return BatchedCPTensor.stack([pad_cp_rank(t, rank) for t in tensors])


# ---------------------------------------------------------------------------
# Random constructions
# ---------------------------------------------------------------------------

def random_tt(key, dims: Sequence[int], rank: int, *, norm: str | None = None,
              dtype=jnp.float32) -> TTTensor:
    """Gaussian random TT tensor with bond rank `rank`.

    norm='unit' rescales so that ||T||_F = 1 (used by the paper's experiments,
    which draw unit-norm rank-10 TT inputs).
    """
    N = len(dims)
    ranks = [1] + [rank] * (N - 1) + [1]
    keys = jax.random.split(key, N)
    cores = tuple(
        jax.random.normal(keys[n], (ranks[n], dims[n], ranks[n + 1]), dtype=dtype)
        for n in range(N)
    )
    t = TTTensor(cores)
    if norm == "unit":
        nrm = jnp.sqrt(t.norm_squared())
        t = t.scale(jnp.where(nrm > 0, 1.0 / nrm, 1.0))
    return t


def random_cp(key, dims: Sequence[int], rank: int, *, norm: str | None = None,
              dtype=jnp.float32) -> CPTensor:
    N = len(dims)
    keys = jax.random.split(key, N)
    factors = tuple(
        jax.random.normal(keys[n], (dims[n], rank), dtype=dtype) for n in range(N)
    )
    t = CPTensor(factors)
    if norm == "unit":
        nrm = jnp.sqrt(t.norm_squared())
        t = t.scale(jnp.where(nrm > 0, 1.0 / nrm, 1.0))
    return t


# ---------------------------------------------------------------------------
# Inner products (never materialize the dense tensor)
# ---------------------------------------------------------------------------

def tt_inner(a: TTTensor, b: TTTensor) -> jnp.ndarray:
    """<A, B> for TT tensors in O(N d R_a R_b (R_a + R_b))."""
    assert a.dims == b.dims, (a.dims, b.dims)
    # carry: (ra, rb)
    carry = jnp.ones((1, 1), dtype=a.dtype)
    for ca, cb in zip(a.cores, b.cores):
        # carry[ra, rb], ca[ra, d, ra'], cb[rb, d, rb'] -> carry'[ra', rb']
        tmp = jnp.einsum("ab,adc->bdc", carry, ca)  # (rb, d, ra')
        carry = jnp.einsum("bdc,bde->ce", tmp, cb)  # (ra', rb')
    return carry.reshape(())


def cp_inner(a: CPTensor, b: CPTensor) -> jnp.ndarray:
    """<A, B> for CP tensors in O(N d R_a R_b)."""
    assert a.dims == b.dims
    acc = jnp.ones((a.rank, b.rank), dtype=a.dtype)
    for fa, fb in zip(a.factors, b.factors):
        acc = acc * (fa.T @ fb)  # (Ra, Rb)
    wa = a.weights if a.weights is not None else jnp.ones((a.rank,), a.dtype)
    wb = b.weights if b.weights is not None else jnp.ones((b.rank,), b.dtype)
    return jnp.einsum("a,ab,b->", wa, acc, wb)


def tt_cp_inner(a: TTTensor, b: CPTensor) -> jnp.ndarray:
    """<TT, CP> in O(N d R_tt^2 R_cp)."""
    assert a.dims == b.dims
    # carry: (r_tt, R_cp)
    carry = jnp.ones((1, b.rank), dtype=a.dtype)
    for core, fac in zip(a.cores, b.factors):
        # carry[r, p] core[r, d, s] fac[d, p] -> (s, p)
        carry = jnp.einsum("rp,rds,dp->sp", carry, core, fac)
    w = b.weights if b.weights is not None else jnp.ones((b.rank,), b.dtype)
    return jnp.einsum("sp,p->", carry, w)  # s == 1


def dense_inner(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.vdot(a, b)


# ---------------------------------------------------------------------------
# TT-SVD: dense -> TT (used by benchmarks to tensorize real data)
# ---------------------------------------------------------------------------

def tt_svd(x: jnp.ndarray, max_rank: int) -> TTTensor:
    """Deterministic TT-SVD (Oseledets 2011) with rank cap. Small inputs only."""
    dims = x.shape
    N = len(dims)
    cores = []
    r_prev = 1
    mat = x.reshape(r_prev * dims[0], -1)
    for n in range(N - 1):
        u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
        r = min(max_rank, u.shape[1])
        u, s, vt = u[:, :r], s[:r], vt[:r, :]
        cores.append(u.reshape(r_prev, dims[n], r))
        mat = (s[:, None] * vt)
        r_prev = r
        if n < N - 2:
            mat = mat.reshape(r_prev * dims[n + 1], -1)
    cores.append(mat.reshape(r_prev, dims[-1], 1))
    return TTTensor(tuple(cores))


def tensorize(vec: jnp.ndarray, dims: Sequence[int]) -> jnp.ndarray:
    """Reshape a flat vector of size prod(dims) into an order-N tensor."""
    assert vec.size == _prod(dims), (vec.size, dims)
    return vec.reshape(tuple(dims))


def auto_dims(size: int, *, max_order: int = 4, align: int = 128) -> tuple[int, ...]:
    """Pick an MXU-friendly tensorization of a flat vector of `size` elements.

    Prefers factors that are multiples of `align` (TPU lane width). Falls back
    to a balanced integer factorization. Used by the gradient compressor to
    tensorize flat parameter buckets.
    """
    if size <= align:
        return (size,)
    # Greedy: peel off `align`-multiples.
    dims: list[int] = []
    rem = size
    while len(dims) < max_order - 1 and rem % align == 0 and rem > align:
        dims.append(align)
        rem //= align
    dims.append(rem)
    # Merge tail if it got tiny.
    dims = sorted(dims, reverse=True)
    return tuple(dims)


def pad_to_tensorizable(vec: jnp.ndarray, align: int = 128,
                        max_order: int = 4) -> tuple[jnp.ndarray, tuple[int, ...], int]:
    """Pad a flat vector so its length factorizes into aligned modes.

    Returns (padded_vec, dims, original_len).
    """
    n = vec.size
    padded = int(math.ceil(n / align) * align)
    dims = auto_dims(padded, max_order=max_order, align=align)
    if padded != n:
        vec = jnp.concatenate([vec, jnp.zeros((padded - n,), vec.dtype)])
    return vec, dims, n
