"""Dense Gaussian RP and very-sparse RP (Li, Hastie & Church 2006) baselines.

Both are implemented streaming-over-column-blocks so the k x D matrix is never
fully materialized for large D (the paper could not run them at high order for
exactly this reason — we keep the memory honest and report it). Each class
defines its random block via `_block_mat`; the shared project/reconstruct/
materialize streaming machinery lives in `_StreamedFlatRP` so the forward map
and its adjoint can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


class _StreamedFlatRP:
    """Streaming (k, D) linear map defined block-wise by `_block_mat(b, dtype)`.

    Subclasses provide `key`, `k`, `dim`, `block`, and `_block_mat`; this
    mixin derives the projection, the unbiased adjoint, and materialization
    from that single block definition.
    """

    @property
    def in_dims(self) -> tuple[int, ...]:
        """RPOperator protocol: flat-vector operator, a single mode."""
        return (self.dim,)

    def _n_blocks(self) -> int:
        return -(-self.dim // self.block)

    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        assert x.shape[-1] == self.dim
        n_blocks = self._n_blocks()
        pad = n_blocks * self.block - self.dim
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        xb = jnp.moveaxis(xp.reshape(x.shape[:-1] + (n_blocks, self.block)),
                          -2, 0)  # (n_blocks, *batch, block)

        def body(acc, args):
            b, xblk = args
            return acc + xblk @ self._block_mat(b, x.dtype), None

        init = jnp.zeros(x.shape[:-1] + (self.k,), x.dtype)
        out, _ = jax.lax.scan(body, init, (jnp.arange(n_blocks), xb))
        return out / jnp.sqrt(jnp.asarray(self.k, x.dtype))

    def reconstruct(self, y: jnp.ndarray, *,
                    chunk: int | None = None) -> jnp.ndarray:
        """Unbiased adjoint x_hat = A^T y / sqrt(k), streamed over blocks.

        `chunk` is accepted for protocol parity; streaming is governed by
        `block` (the k-sized intermediate never exceeds block * k floats).
        """
        del chunk
        assert y.shape == (self.k,), y.shape

        def body(_, b):
            return None, self._block_mat(b, y.dtype) @ y

        _, parts = jax.lax.scan(body, None, jnp.arange(self._n_blocks()))
        x = parts.reshape(-1)[: self.dim]
        return x / jnp.sqrt(jnp.asarray(self.k, y.dtype))

    def materialize(self) -> jnp.ndarray:
        """Dense (k, D) matrix — small-order cases only."""
        blocks = [self._block_mat(b, jnp.float32)
                  for b in range(self._n_blocks())]
        a = jnp.concatenate(blocks, axis=0)[: self.dim]
        return a.T / jnp.sqrt(jnp.asarray(self.k, a.dtype))

    def as_dense_matrix(self) -> jnp.ndarray:
        """RPOperator protocol alias of `materialize`."""
        return self.materialize()


@dataclasses.dataclass(frozen=True)
class GaussianRP(_StreamedFlatRP):
    """Classical JLT: y = A x / sqrt(k), A_ij ~ N(0, 1)."""

    key: jax.Array
    k: int
    dim: int
    block: int = 65536

    def num_params(self) -> int:
        return self.k * self.dim

    def _block_mat(self, b, dtype) -> jnp.ndarray:
        return jax.random.normal(jax.random.fold_in(self.key, b),
                                 (self.block, self.k), dtype=dtype)


@dataclasses.dataclass(frozen=True)
class VerySparseRP(_StreamedFlatRP):
    """Li et al. 2006: A_ij = +sqrt(s) w.p. 1/2s, 0 w.p. 1-1/s, -sqrt(s) w.p. 1/2s.

    Default s = sqrt(D) ("very sparse"), giving ~k*sqrt(D) expected nonzeros.
    E[A_ij^2] = 1, so y = A x / sqrt(k) is an expected isometry.
    """

    key: jax.Array
    k: int
    dim: int
    s: float | None = None
    block: int = 65536

    @property
    def sparsity(self) -> float:
        return float(self.s) if self.s is not None else math.sqrt(self.dim)

    def num_params(self) -> int:
        """Expected nonzeros (index+value storage in a real implementation)."""
        return int(self.k * self.dim / self.sparsity)

    def _block_mat(self, b, dtype) -> jnp.ndarray:
        s = self.sparsity
        kk = jax.random.fold_in(self.key, b)
        u = jax.random.uniform(kk, (self.block, self.k))
        sign = jnp.where(u < 0.5 / s, 1.0, jnp.where(u > 1.0 - 0.5 / s, -1.0, 0.0))
        return (sign * jnp.sqrt(s)).astype(dtype)
