"""f_TT(R): tensor-train random projection (paper Definition 1).

(f_TT(R)(X))_i = 1/sqrt(k) * < <<G_i^1, ..., G_i^N>>, X >,   i in [k]

with core entries drawn i.i.d. N(0, sigma_n^2) where sigma_n^2 = 1/sqrt(R) for
the boundary cores (n = 1, N) and 1/R for interior cores. The map is an
expected isometry (Thm 1) and a JLT for k ≳ eps^-2 (1+2/R)^N log^{2N}(m/delta)
(Thm 2).

Batched-core layout: cores[n] has shape (k, r_{n-1}, d_n, r_n), r_0 = r_N = 1.
All projection paths avoid materializing either the operator rows or the input:

  project(X)       dense input    O(k R d^N)        time, O(kNdR^2) operator mem
  project_tt(X)    TT(R~) input   O(k N d R R~ (R + R~))
  project_cp(X)    CP(R~) input   O(k N d R R~^2)... (carry k x R x R~)
  reconstruct(y)   adjoint        unbiased x_hat = sum_i y_i S_i / sqrt(k)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .formats import CPTensor, TTTensor, _prod


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TTRP:
    """A sampled TT random projection operator."""

    cores: tuple[jnp.ndarray, ...]  # cores[n]: (k, r_{n-1}, d_n, r_n)

    def tree_flatten(self):
        return tuple(self.cores), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(cores=tuple(children))

    # ------------------------------------------------------------------
    @property
    def k(self) -> int:
        return int(self.cores[0].shape[0])

    @property
    def order(self) -> int:
        return len(self.cores)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(c.shape[2]) for c in self.cores)

    @property
    def in_dims(self) -> tuple[int, ...]:
        """RPOperator protocol: input mode sizes (alias of `dims`)."""
        return self.dims

    @property
    def rank(self) -> int:
        return int(self.cores[0].shape[3]) if self.order > 1 else 1

    def num_params(self) -> int:
        return sum(_prod(c.shape) for c in self.cores)

    def row(self, i: int) -> TTTensor:
        """The i-th row of the implicit projection matrix, as a TT tensor."""
        return TTTensor(tuple(c[i] for c in self.cores))

    # ------------------------------------------------------------------
    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        """Project dense input(s). x: (*batch, d1, ..., dN) -> (*batch, k)."""
        N = self.order
        dims = self.dims
        assert x.shape[-N:] == dims, (x.shape, dims)
        scale = 1.0 / jnp.sqrt(jnp.asarray(self.k, x.dtype))
        if N == 1:
            g = self.cores[0][:, 0, :, 0]  # (k, d)
            return jnp.einsum("...d,kd->...k", x, g) * scale
        # right-to-left contraction; carry has axes (*batch, d1..d_{n}, k, r_n)
        gN = self.cores[-1][:, :, :, 0]  # (k, r, d)
        c = jnp.einsum("...d,krd->...kr", x, gN)
        for n in range(N - 2, 0, -1):
            c = jnp.einsum("...dkr,ksdr->...ks", c, self.cores[n])
        g1 = self.cores[0][:, 0, :, :]  # (k, d, r)
        y = jnp.einsum("...dkr,kdr->...k", c, g1)
        return y * scale

    def project_tt(self, x: TTTensor) -> jnp.ndarray:
        """Project an input given in TT format: O(k N d R R~ (R + R~))."""
        assert x.dims == self.dims, (x.dims, self.dims)
        k = self.k
        carry = jnp.ones((k, 1, 1), dtype=x.dtype)  # (k, r_rp, r_x)
        for g, xc in zip(self.cores, x.cores):
            # carry(k,a,b) g(k,a,d,s) xc(b,d,e) -> (k,s,e)
            tmp = jnp.einsum("kab,kads->kbds", carry, g)
            carry = jnp.einsum("kbds,bde->kse", tmp, xc)
        y = carry[:, 0, 0]
        return y / jnp.sqrt(jnp.asarray(k, y.dtype))

    def project_cp(self, x: CPTensor) -> jnp.ndarray:
        """Project an input given in CP format."""
        assert x.dims == self.dims
        k = self.k
        carry = jnp.ones((k, 1, x.rank), dtype=x.dtype)  # (k, r_rp, R_x)
        for g, f in zip(self.cores, x.factors):
            # carry(k,a,p) g(k,a,d,s) f(d,p) -> (k,s,p)
            tmp = jnp.einsum("kap,kads->kpds", carry, g)
            carry = jnp.einsum("kpds,dp->ksp", tmp, f)
        w = x.weights if x.weights is not None else jnp.ones((x.rank,), x.dtype)
        # the boundary carry is always (k, r_N = 1, R~): contract it directly
        assert carry.shape[1] == 1, carry.shape
        y = jnp.einsum("kp,p->k", carry[:, 0, :], w)
        return y / jnp.sqrt(jnp.asarray(k, y.dtype))

    def reconstruct(self, y: jnp.ndarray, *, chunk: int | None = None) -> jnp.ndarray:
        """Unbiased adjoint reconstruction x_hat = (1/sqrt k) sum_i y_i S_i.

        E[x_hat] = x when y = project(x) because E[vec(S) vec(S)^T] = I.
        `chunk` bounds the k-sized intermediate (memory O(chunk * d^{N-1} * R)).
        """
        k = self.k
        assert y.shape == (k,), y.shape
        scale = 1.0 / jnp.sqrt(jnp.asarray(k, y.dtype))

        def partial(cores, yc):
            # cores[0]: (kc, 1, d1, r); accumulate left-to-right keeping kc.
            w = jnp.einsum("k,kdr->kdr", yc, cores[0][:, 0, :, :])
            for g in cores[1:-1]:
                w = jnp.einsum("k...r,krds->k...ds", w, g)
            gN = cores[-1][:, :, :, 0]  # (kc, r, d)
            return jnp.einsum("k...r,krd->...d", w, gN)

        if self.order == 1:
            return jnp.einsum("k,kd->d", y, self.cores[0][:, 0, :, 0]) * scale

        if chunk is None or chunk >= k:
            return partial(self.cores, y) * scale

        n_chunks = -(-k // chunk)
        pad = n_chunks * chunk - k
        yp = jnp.pad(y, (0, pad))
        cores_p = [jnp.pad(c, ((0, pad),) + ((0, 0),) * 3) for c in self.cores]
        yb = yp.reshape(n_chunks, chunk)
        cb = [c.reshape((n_chunks, chunk) + c.shape[1:]) for c in cores_p]

        def body(carry, inp):
            yc = inp[0]
            cs = inp[1:]
            return carry + partial(cs, yc), None

        init = jnp.zeros(self.dims, y.dtype)
        out, _ = jax.lax.scan(body, init, tuple([yb] + cb))
        return out * scale

    def as_dense_matrix(self) -> jnp.ndarray:
        """Materialize the k x prod(dims) matrix (tests only)."""
        rows = jax.vmap(lambda *cs: TTTensor(cs).full().reshape(-1))(*self.cores)
        return rows / jnp.sqrt(jnp.asarray(self.k, rows.dtype))


def sample_tt_rp(key, dims: Sequence[int], k: int, rank: int,
                 dtype=jnp.float32) -> TTRP:
    """Draw f_TT(R) cores per Definition 1's variance schedule."""
    N = len(dims)
    ranks = [1] + [rank] * (N - 1) + [1]
    keys = jax.random.split(key, N)
    cores = []
    for n in range(N):
        if N == 1:
            var = 1.0  # classical Gaussian RP; R plays no role
        elif n == 0 or n == N - 1:
            var = 1.0 / jnp.sqrt(jnp.asarray(rank, jnp.float32))
        else:
            var = 1.0 / rank
        std = jnp.sqrt(jnp.asarray(var, dtype))
        cores.append(std * jax.random.normal(
            keys[n], (k, ranks[n], dims[n], ranks[n + 1]), dtype=dtype))
    return TTRP(tuple(cores))
