"""f_CP(R): CP random projection (paper Definition 2) and the TRP equivalence.

(f_CP(R)(X))_i = 1/sqrt(k) * < [[A_i^1, ..., A_i^N]], X >,   i in [k]

with factor entries i.i.d. N(0, (1/R)^(1/N)). Memory O(kNdR); JLT once
k ≳ eps^-2 3^(N-1) (1+2/R) log^{2N}(m/delta) (Thm 2) — exponentially worse in N
than f_TT(R), which the benchmarks reproduce.

Sun et al. (2018)'s TRP map is f_CP(1); their variance-reduced TRP(T) is
f_CP(R=T) up to the 1/sqrt(T) component scaling — `trp_project` implements
the row-wise Khatri-Rao form and tests assert exact equality.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .formats import CPTensor, TTTensor, _prod


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPRP:
    """A sampled CP random projection operator."""

    factors: tuple[jnp.ndarray, ...]  # factors[n]: (k, d_n, R)

    def tree_flatten(self):
        return tuple(self.factors), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(factors=tuple(children))

    @property
    def k(self) -> int:
        return int(self.factors[0].shape[0])

    @property
    def order(self) -> int:
        return len(self.factors)

    @property
    def dims(self) -> tuple[int, ...]:
        return tuple(int(f.shape[1]) for f in self.factors)

    @property
    def in_dims(self) -> tuple[int, ...]:
        """RPOperator protocol: input mode sizes (alias of `dims`)."""
        return self.dims

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[2])

    def num_params(self) -> int:
        return sum(_prod(f.shape) for f in self.factors)

    def row(self, i: int) -> CPTensor:
        return CPTensor(tuple(f[i] for f in self.factors))

    # ------------------------------------------------------------------
    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        """Dense input(s): (*batch, d1..dN) -> (*batch, k). O(k R d^N)."""
        N = self.order
        assert x.shape[-N:] == self.dims, (x.shape, self.dims)
        scale = 1.0 / jnp.sqrt(jnp.asarray(self.k, x.dtype))
        c = jnp.einsum("...d,kdr->...kr", x, self.factors[-1])
        for n in range(N - 2, -1, -1):
            c = jnp.einsum("...dkr,kdr->...kr", c, self.factors[n])
        return c.sum(-1) * scale

    def project_cp(self, x: CPTensor) -> jnp.ndarray:
        """CP-format input: O(k N d R R~)."""
        assert x.dims == self.dims
        carry = jnp.ones((self.k, self.rank, x.rank), dtype=x.dtype)
        for f, g in zip(self.factors, x.factors):
            carry = carry * jnp.einsum("kdr,dp->krp", f, g)
        w = x.weights if x.weights is not None else jnp.ones((x.rank,), x.dtype)
        y = jnp.einsum("krp,p->k", carry, w)
        return y / jnp.sqrt(jnp.asarray(self.k, y.dtype))

    def project_tt(self, x: TTTensor) -> jnp.ndarray:
        """TT-format input: carry (k, R, bond)."""
        assert x.dims == self.dims
        carry = jnp.ones((self.k, self.rank, 1), dtype=x.cores[0].dtype)
        for f, xc in zip(self.factors, x.cores):
            # carry(k,r,b) f(k,d,r) xc(b,d,e) -> (k,r,e)
            tmp = jnp.einsum("krb,bde->krde", carry, xc)
            carry = jnp.einsum("krde,kdr->kre", tmp, f)
        y = carry[:, :, 0].sum(-1)
        return y / jnp.sqrt(jnp.asarray(self.k, y.dtype))

    def reconstruct(self, y: jnp.ndarray, *, chunk: int | None = None) -> jnp.ndarray:
        """Unbiased adjoint x_hat = (1/sqrt k) sum_i y_i [[A_i^*]]."""
        k = self.k
        assert y.shape == (k,)
        scale = 1.0 / jnp.sqrt(jnp.asarray(k, y.dtype))

        def partial(facs, yc):
            w = jnp.einsum("k,kdr->kdr", yc, facs[0])
            for f in facs[1:-1]:
                w = jnp.einsum("k...r,kdr->k...dr", w, f)
            return jnp.einsum("k...r,kdr->...d", w, facs[-1])

        if self.order == 1:
            return jnp.einsum("k,kdr->d", y, self.factors[0]) * scale
        if chunk is None or chunk >= k:
            return partial(self.factors, y) * scale
        n_chunks = -(-k // chunk)
        pad = n_chunks * chunk - k
        yp = jnp.pad(y, (0, pad)).reshape(n_chunks, chunk)
        fb = [jnp.pad(f, ((0, pad), (0, 0), (0, 0))).reshape((n_chunks, chunk) + f.shape[1:])
              for f in self.factors]

        def body(carry, inp):
            return carry + partial(inp[1:], inp[0]), None

        init = jnp.zeros(self.dims, y.dtype)
        out, _ = jax.lax.scan(body, init, tuple([yp] + fb))
        return out * scale

    def as_dense_matrix(self) -> jnp.ndarray:
        rows = jax.vmap(lambda *fs: CPTensor(fs).full().reshape(-1))(*self.factors)
        return rows / jnp.sqrt(jnp.asarray(self.k, rows.dtype))


def sample_cp_rp(key, dims: Sequence[int], k: int, rank: int,
                 dtype=jnp.float32) -> CPRP:
    """Draw f_CP(R) factors per Definition 2: var = (1/R)^(1/N)."""
    N = len(dims)
    std = jnp.asarray((1.0 / rank) ** (1.0 / (2.0 * N)), dtype)
    keys = jax.random.split(key, N)
    factors = tuple(
        std * jax.random.normal(keys[n], (k, dims[n], rank), dtype=dtype)
        for n in range(N)
    )
    return CPRP(factors)


# ---------------------------------------------------------------------------
# TRP (Sun et al. 2018) — row-wise Khatri-Rao formulation, for the
# equivalence test  f_TRP == f_CP(1)  and  f_TRP(T) == f_CP(R=T).
# ---------------------------------------------------------------------------

def trp_project(factor_mats: Sequence[jnp.ndarray], x_vec: jnp.ndarray) -> jnp.ndarray:
    """f_TRP(X) = 1/sqrt(k) (A^1 ⊙ ... ⊙ A^N)^T vec(X).

    factor_mats[n]: (d_n, k); x_vec: flat input of size prod(d_n) in C-order
    (axis 1 varying slowest — matches CPTensor.full().reshape(-1)).
    """
    k = factor_mats[0].shape[1]
    # Khatri-Rao product, column-matching Kronecker. C-order: row index
    # i = i_1 * (d_2...d_N) + ... + i_N  -> kron in order 1..N.
    kr = factor_mats[0]
    for f in factor_mats[1:]:
        kr = jnp.einsum("pk,dk->pdk", kr, f).reshape(-1, k)
    return (kr.T @ x_vec) / jnp.sqrt(jnp.asarray(k, x_vec.dtype))


def trp_average(projections: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Variance-reduced TRP(T): scaled average (1/sqrt T) sum_t f^(t)(X)."""
    T = len(projections)
    return sum(projections) / jnp.sqrt(jnp.asarray(T, projections[0].dtype))
