"""Pytree sketching: tensorized RP over flat parameter/gradient buckets.

This is the systems integration of the paper: big flat vectors (gradients,
parameter deltas) are bucketed, each bucket is tensorized into an MXU-aligned
order-N tensor (`dims` may be any length — the mode-sweep kernels handle any
order >= 2, and higher order means smaller cores for the same bucket size:
TT/CP operator params scale with the SUM of the modes, not their product),
and projected with any registered `repro.rp` family —
f_TT(R) / f_CP(R) from the paper, or the gaussian/sparse baselines via
flat-vector dispatch. Because the operator is derived from a PRNG key,
distributed hosts regenerate it locally — the operator itself never crosses
the network (what else crosses depends on the consumer's sync formulation;
see optim/compress.py).

Used by:
  * optim/compress.py — error-feedback compressed cross-pod all-reduce,
  * SketchMonitor      — O(k) per-step parameter-drift telemetry.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from .formats import STRUCT_TYPES, BatchedCPTensor, BatchedTTTensor, _prod


def _is_struct_leaf(x) -> bool:
    """Pytree leaves the sketcher treats as already-compressed inputs: they
    are projected in the compressed domain (rp.project's carry-sweep route)
    rather than bucketized — their dims must equal SketchConfig.dims."""
    return isinstance(x, STRUCT_TYPES)


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    family: str = "tt"         # any registered repro.rp family
    k: int = 1024              # sketch size per bucket
    rank: int = 2              # R of the tensorized map
    bucket_elems: int = 128 * 128 * 64  # elements per bucket (1,048,576)
    # MXU-aligned tensorization; ANY length >= 1 (order-N buckets route
    # through the mode-sweep kernels; e.g. (32, 32, 32, 32) halves TT
    # operator memory vs (128, 128, 64) at the same bucket size)
    dims: tuple[int, ...] = (128, 128, 64)
    fresh_per_step: bool = True  # re-draw operator each step (EF-friendly)
    backend: str = "auto"      # repro.rp backend policy for projections
    fmt: dataclasses.InitVar[str | None] = None  # deprecated alias of family

    def __post_init__(self, fmt):
        if fmt is not None:
            warnings.warn("SketchConfig(fmt=...) is deprecated; use "
                          "family=...", DeprecationWarning, stacklevel=2)
            object.__setattr__(self, "family", fmt)
        if _prod(self.dims) != self.bucket_elems:
            # a typed error (not an assert): survives `python -O` and tells
            # the caller which knob to fix
            raise ValueError(
                f"prod(dims) = {_prod(self.dims)} for dims={self.dims} does "
                f"not equal bucket_elems={self.bucket_elems}; pass "
                f"bucket_elems={_prod(self.dims)} or retensorize dims to "
                "cover the bucket")
        from repro import rp  # function-level: core <-> rp import cycle
        rp.get_family(self.family)  # fail fast on unknown families

    # (fmt read-access is restored as a property after the class definition;
    # the dataclass captured the InitVar default before the override.)

    def spec(self):
        from repro import rp
        return rp.ProjectorSpec(family=self.family, k=self.k, dims=self.dims,
                                rank=self.rank, backend=self.backend)

    def shrinkage(self) -> float:
        """MMSE damping for the adjoint roundtrip x_hat = alpha * A^T A x.

        E||A^T A x||^2 ~= ||x||^2 (1 + c*D/k) with c the paper's Thm-1
        variance factor, so alpha* = 1/(1 + c*D/k). Without it the roundtrip
        is an EXPANSION for D > k/c and error feedback diverges; with it the
        compressor is (1-delta)-contractive, delta = alpha*.
        """
        from . import theory
        c = theory.variance_factor(self.family, N=len(self.dims),
                                   R=self.rank, D=self.bucket_elems)
        return 1.0 / (1.0 + c * self.bucket_elems / self.k)

    def operator(self, key):
        from repro import rp
        return rp.make_projector(self.spec(), key)

    def operator_params(self) -> int:
        from . import theory
        try:
            return theory.params_rp(self.family, self.k, self.dims, self.rank)
        except KeyError:
            # externally registered family: count a sampled instance
            return self.operator(jax.random.PRNGKey(0)).num_params()


# Deprecated read alias: cfg.fmt -> cfg.family.
SketchConfig.fmt = property(lambda self: self.family)


def _constrain_buckets(x):
    """LEGACY best-effort hint: shard the bucket dim over every available
    (non-manual) mesh axis from the global model-settings context — without
    this the ravel/concat path replicates the full flat gradient on every
    device at production scale. Sketchers constructed with an explicit
    `mesh`/`bucket_spec` (the sharded-engine path) never consult this."""
    from repro.models import settings as msettings  # runtime import: no cycle
    mesh = msettings.get().mesh
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    manual = msettings.get().manual_axes
    axes = tuple(a for a in mesh.axis_names if a not in manual)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or x.shape[0] % size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(axes, *([None] * (x.ndim - 1)))))


class PytreeSketcher:
    """Sketches a fixed-structure pytree bucket-wise, PER LEAF.

    Leaves may be dense arrays (bucketized and tensorized to `cfg.dims`) OR
    already-compressed `TTTensor` / `CPTensor` / `BatchedTTTensor` /
    `BatchedCPTensor` containers with dims == `cfg.dims`: structured leaves
    are sketched in the compressed domain (the carry-sweep kernel route —
    the paper's "project without densifying" claim as a sketcher feature)
    and reconstruct to dense unbiased estimates.

    Per-leaf (vs one global ravel/concat) matters at production scale: a
    concatenated 67B-param flat vector forces XLA to materialize a replicated
    copy per device; per-leaf buckets reshape each (already sharded) tensor
    locally. The same operator is shared across buckets and leaves (disjoint
    coordinates keep per-bucket estimates unbiased; sharing keeps operator
    memory O(kNdR^2) regardless of model size).

    Fidelity/compute scaling (why bucket_elems is a knob): at fixed
    compression ratio r = D/(nb*k), the per-bucket error c*Db/k = c*r is
    independent of bucket size, while sketch FLOPs = R*D*Db/r shrink linearly
    with smaller buckets — prefer the smallest MXU-aligned bucket that keeps
    k reasonable.

    Sharding: pass `mesh` (and optionally `bucket_spec`, a PartitionSpec
    whose first entry names the mesh axes for the bucket dim) to pin the
    `(n_buckets, ...)` bucket arrays to an explicit layout — the
    sharded-engine contract used by `rp.sketch_tree_sharded` and
    `SketchCompressor.compress_collective`. Without a mesh the sketcher
    falls back to the legacy `_constrain_buckets` global-settings hint.
    Per-leaf divisibility is checked at constrain time: a leaf whose bucket
    count the spec's axes do not divide stays unconstrained rather than
    erroring.
    """

    def __init__(self, cfg: SketchConfig, example_tree: Any, *,
                 mesh=None, bucket_spec=None, constrain: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.bucket_spec = bucket_spec
        # constrain=False disables ALL bucket-layout constraints, including
        # the legacy global-settings hint — required inside shard_map bodies
        # (compress_collective), where a with_sharding_constraint in a
        # partially-manual region aborts XLA even when it comes from the
        # ambient model-settings mesh rather than an explicit mesh=
        self.constrain = constrain
        leaves, treedef = jax.tree_util.tree_flatten(
            example_tree, is_leaf=_is_struct_leaf)
        self._treedef = treedef
        self._struct = [_is_struct_leaf(l) for l in leaves]
        self._shapes, self._sizes, self._dtypes, self._nb = [], [], [], []
        for leaf, is_struct in zip(leaves, self._struct):
            if is_struct:
                if tuple(leaf.dims) != tuple(cfg.dims):
                    raise ValueError(
                        f"structured leaf dims {tuple(leaf.dims)} != "
                        f"SketchConfig.dims {tuple(cfg.dims)}; tensorize "
                        "structured leaves to the sketch dims up front")
                nb = leaf.batch if isinstance(
                    leaf, (BatchedTTTensor, BatchedCPTensor)) else 1
                # a structured leaf IS its own bucket(s): one per batch item;
                # its dense estimate comes back in the leaf's own dtype,
                # like dense leaves
                self._shapes.append(((nb,) if nb > 1 else ()) + tuple(cfg.dims))
                self._sizes.append(nb * cfg.bucket_elems)
                self._dtypes.append(leaf.dtype)
                self._nb.append(nb)
            else:
                self._shapes.append(tuple(leaf.shape))
                self._sizes.append(int(_prod(leaf.shape)))
                self._dtypes.append(leaf.dtype)
                self._nb.append(
                    max(1, -(-self._sizes[-1] // cfg.bucket_elems)))
        self.n = sum(self._sizes)
        self.n_buckets = sum(self._nb)
        self.padded = self.n_buckets * cfg.bucket_elems

    # -- bucket-axis sharding --------------------------------------------
    def _constrain(self, x):
        """Pin the bucket dim of `x` to the explicit mesh/spec when the
        sketcher was constructed with one; legacy global hint otherwise;
        nothing at all when constrain=False (shard_map bodies)."""
        if not self.constrain:
            return x
        if self.mesh is None:
            return _constrain_buckets(x)
        # runtime import: no cycle — and reuse the shard module's spec
        # normalization so the pjit layout and the shard_map entry points
        # can never disagree on what an entry/axes-size means
        from repro.rp.shard import bucket_pspec, shard_entry
        from jax.sharding import NamedSharding, PartitionSpec
        spec = self.bucket_spec
        if spec is None:
            spec = bucket_pspec(self.mesh, x.shape[0])
        entry, _, size = shard_entry(self.mesh, spec)
        if size <= 1 or x.shape[0] % size:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh,
                             PartitionSpec(entry, *([None] * (x.ndim - 1)))))

    # -- per-leaf shaping -------------------------------------------------
    def _leaf_to_buckets(self, leaf, nb: int) -> jnp.ndarray:
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = nb * self.cfg.bucket_elems - flat.size
        if pad:
            # concatenate, NOT jnp.pad: a pad op inside a partially-manual
            # shard_map body (the compress_collective path) trips an XLA
            # SPMD-partitioner CHECK (hlo_sharding_util IsManualSubgroup)
            # and aborts the process; concatenate partitions cleanly
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        return self._constrain(flat.reshape((nb,) + self.cfg.dims))

    def _leaf_from_buckets(self, buckets, size: int, shape, dtype):
        return buckets.reshape(-1)[:size].reshape(shape).astype(dtype)

    # -- sketch / unsketch -----------------------------------------------
    def sketch(self, tree: Any, key, *, project_fn=None) -> jnp.ndarray:
        """tree -> (n_buckets, k) sketch (buckets concatenated over leaves).

        All buckets of a leaf go through ONE batched `rp.project` call — on
        the Pallas route that is a single kernel launch with a native batch
        grid axis (operator cores streamed once per k-tile, not once per
        bucket), instead of the old vmap of per-bucket launches.

        Structured (TT/CP-format) leaves never densify: each one is
        projected in the compressed domain by the carry-sweep route, a
        batched container counting one bucket per batch item — still ONE
        dispatch per leaf.

        `project_fn(op, buckets) -> (nb, k)` overrides the dense-bucket
        projection call (the sharded engine passes a shard_map-wrapping
        closure — `rp.sketch_tree_sharded`); structured leaves always take
        the plain single-dispatch route.
        """
        from repro import rp
        op = self.cfg.operator(key)
        if project_fn is None:
            def project_fn(o, buckets):
                return rp.project(o, buckets, backend=self.cfg.backend)
        flat_op = len(op.in_dims) == 1  # gaussian/sparse contract flat
        ys = []
        leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_struct_leaf)
        for leaf, nb, is_struct in zip(leaves, self._nb, self._struct):
            if is_struct:
                y = rp.project(op, leaf, backend=self.cfg.backend)
                ys.append(y.reshape(nb, self.cfg.k))
                continue
            buckets = self._leaf_to_buckets(leaf, nb)
            if flat_op:
                buckets = buckets.reshape(nb, -1)
            ys.append(project_fn(op, buckets))
        return jnp.concatenate(ys, axis=0)

    def unsketch(self, y: jnp.ndarray, key) -> Any:
        """(n_buckets, k) -> unbiased pytree estimate (same key as sketch).

        One batched `rp.reconstruct` per leaf — the Pallas adjoint kernels
        reconstruct every bucket of the leaf in a single launch. Structured
        leaves come back as DENSE unbiased estimates (`(*dims)` for a
        single tensor, `(B, *dims)` for a batched container): the adjoint
        of a sketch is a dense tensor, there is no exact TT/CP form to
        return to.
        """
        from repro import rp
        op = self.cfg.operator(key)
        out = []
        off = 0
        for nb, size, shape, dtype in zip(self._nb, self._sizes,
                                          self._shapes, self._dtypes):
            buckets = rp.reconstruct(op, self._constrain(y[off:off + nb]),
                                     backend=self.cfg.backend)
            out.append(self._leaf_from_buckets(buckets, size, shape, dtype))
            off += nb
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def roundtrip(self, tree: Any, key) -> tuple[Any, jnp.ndarray]:
        """Returns (reconstruction, sketch)."""
        y = self.sketch(tree, key)
        return self.unsketch(y, key), y

    # -- accounting -------------------------------------------------------
    def sketch_bytes(self) -> int:
        return self.n_buckets * self.cfg.k * 4

    def dense_bytes(self) -> int:
        return self.n * 4

    def compression_ratio(self) -> float:
        return self.dense_bytes() / max(1, self.sketch_bytes())


# ---------------------------------------------------------------------------
# Sketch-based telemetry: parameter drift / gradient norms at O(k) cost.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SketchMonitor:
    """Tracks ||theta_t - theta_{t-1}|| and ||theta_t|| through a fixed sketch.

    By the JL property the sketch-space norms are (1±eps)-faithful; the state
    is n_buckets*k floats regardless of model size (e.g. 64 KB for a 7B model
    with k=1024, 1 bucket stride sampling).
    """

    sketcher: PytreeSketcher
    key: jax.Array
    prev: jnp.ndarray | None = None

    def update(self, tree: Any) -> dict[str, jnp.ndarray]:
        y = self.sketcher.sketch(tree, self.key)
        norm = jnp.sqrt(jnp.sum(y * y))
        if self.prev is None:
            drift = jnp.zeros((), y.dtype)
        else:
            d = y - self.prev
            drift = jnp.sqrt(jnp.sum(d * d))
        self.prev = y
        return {"sketch_norm": norm, "sketch_drift": drift}
