"""Theorem 1/2 bounds and complexity formulas, used by tests and benchmarks.

All formulas are stated exactly as in the paper; `required_k_*` expose the
JL lower bounds with an explicit constant c (the paper's ≳ hides it).

Order-dependent TT-vs-CP comparison (the paper's headline, Sec. 4)
------------------------------------------------------------------
At input order N and rank R, the Thm-1 variance factors are

    TT: 3 (1 + 2/R)^{N-1} - 1        CP: 3^{N-1} (1 + 2/R) - 1

— identical at N = 2 (both reduce to 3(1+2/R) - 1), and diverging
exponentially for N >= 3: their ratio grows like (3 / (1 + 2/R))^{N-2},
so for any R > 1 every extra mode multiplies CP's variance disadvantage
by 3/(1+2/R) > 1 (`variance_ratio_cp_to_tt`). The Thm-2 embedding sizes
inherit the same ordering: `required_k_cp / required_k_tt` ~
(3 / (1 + 2/R))^{N-1}. This is exactly why the order-N kernel layer pays
off — tensorizing the same bucket into MORE, SMALLER modes shrinks the TT
operator (params O(kNdR^2) with d ~ D^{1/N}) while the TT bound degrades
only geometrically in N where CP's degrades like 3^N.
"""
from __future__ import annotations

import math


# ---------------------------------------------------------------------------
# Theorem 1 — variance bounds (the bracketed factor multiplying ||X||^4 / k)
# ---------------------------------------------------------------------------

def variance_factor_tt(N: int, R: int) -> float:
    """Var(||f_TT(R)(X)||^2) <= factor / k * ||X||_F^4."""
    return 3.0 * (1.0 + 2.0 / R) ** (N - 1) - 1.0


def variance_factor_cp(N: int, R: int) -> float:
    """Var(||f_CP(R)(X)||^2) <= factor / k * ||X||_F^4."""
    return 3.0 ** (N - 1) * (1.0 + 2.0 / R) - 1.0


def variance_factor_gaussian() -> float:
    """Classical Gaussian RP: Var = 2/k ||x||^4 (the N=1 specialization)."""
    return 2.0


def variance_factor_sparse(s: float) -> float:
    """Very-sparse RP (Li et al. 2006) worst case: E[a^4] = s gives
    Var(||y||^2) <= (2 + (s-3) sum x_j^4/||x||^4)/k ||x||^4 <= (s-1)/k ||x||^4."""
    return max(2.0, s - 1.0)


def variance_ratio_cp_to_tt(N: int, R: int) -> float:
    """Thm-1 bound ratio CP/TT at order N, rank R (module docstring).

    == 1 at N = 2 (and for R = 1 at any N, where the two maps coincide
    distribution-wise); grows ~ (3/(1+2/R))^{N-2} for R > 1 — the
    order-dependent advantage of TT the benchmarks chart.
    """
    return variance_factor_cp(N, R) / variance_factor_tt(N, R)


def variance_factor(family: str, *, N: int, R: int, D: int | None = None) -> float:
    """Thm-1 variance factor for any built-in family (per-family dispatch).

    Unknown (externally registered) families fall back to the Gaussian
    factor — conservative users should register a tighter bound here.
    """
    if family == "tt":
        return variance_factor_tt(N, R)
    if family == "cp":
        return variance_factor_cp(N, R)
    if family in ("sparse", "verysparse"):
        return variance_factor_sparse(math.sqrt(D) if D else 2.0)
    return variance_factor_gaussian()


# ---------------------------------------------------------------------------
# Theorem 2 — JL embedding-size lower bounds
# ---------------------------------------------------------------------------

def required_k_tt(eps: float, m: int, N: int, R: int, *, delta: float = 0.01,
                  c: float = 1.0) -> int:
    """k ≳ eps^-2 (1 + 2/R)^N log^{2N}(m / delta)."""
    return int(math.ceil(
        c * eps ** -2 * (1.0 + 2.0 / R) ** N * math.log(m / delta) ** (2 * N)))


def required_k_cp(eps: float, m: int, N: int, R: int, *, delta: float = 0.01,
                  c: float = 1.0) -> int:
    """k ≳ eps^-2 3^{N-1} (1 + 2/R) log^{2N}(m / delta)."""
    return int(math.ceil(
        c * eps ** -2 * 3.0 ** (N - 1) * (1.0 + 2.0 / R)
        * math.log(m / delta) ** (2 * N)))


def required_k_gaussian(eps: float, m: int, *, delta: float = 0.01,
                        c: float = 8.0) -> int:
    """Classical JL: k = O(eps^-2 log(m/delta))."""
    return int(math.ceil(c * eps ** -2 * math.log(m / delta)))


def concentration_bound_tt(k: int, eps: float, N: int, R: int,
                           *, K: float = 1.0) -> float:
    """Theorem 5 failure-probability upper bound (C = e^2)."""
    C = math.e ** 2
    expo = (math.sqrt(k) * eps) ** (1.0 / N) / (
        (3.0 * K) ** (1.0 / (2 * N)) * math.sqrt(1.0 + 2.0 / R))
    return C * math.exp(-expo)


# ---------------------------------------------------------------------------
# Memory / compute complexity (Sec. 1 & 3) — exact parameter counts
# ---------------------------------------------------------------------------

def params_tt_rp(k: int, dims, R: int) -> int:
    """k * (d_1 R + sum_middle R d R + d_N R); == O(kNdR^2)."""
    N = len(dims)
    if N == 1:
        return k * dims[0]
    total = dims[0] * R + dims[-1] * R
    for d in dims[1:-1]:
        total += R * d * R
    return k * total


def params_cp_rp(k: int, dims, R: int) -> int:
    """k * R * sum(d_n); == O(kNdR)."""
    return k * R * sum(dims)


def params_gaussian_rp(k: int, dims) -> int:
    out = k
    for d in dims:
        out *= d
    return out


def params_sparse_rp(k: int, dims, s: float | None = None) -> int:
    D = 1
    for d in dims:
        D *= d
    s = s if s is not None else math.sqrt(D)
    return int(k * D / s)


def params_rp(family: str, k: int, dims, R: int = 2) -> int:
    """Operator parameter count for any built-in family."""
    if family == "tt":
        return params_tt_rp(k, dims, R)
    if family == "cp":
        return params_cp_rp(k, dims, R)
    if family in ("gaussian", "dense"):
        return params_gaussian_rp(k, dims)
    if family in ("sparse", "verysparse"):
        return params_sparse_rp(k, dims)
    raise KeyError(f"no parameter formula for family {family!r}")


# FLOP estimates for the projection paths (multiply-adds x2), used by the
# kernel-level roofline analysis.

def flops_project_dense_tt(k: int, dims, R: int) -> int:
    N = len(dims)
    D = 1
    for d in dims:
        D *= d
    if N == 1:
        return 2 * k * D
    fl = 2 * k * R * D  # right-most contraction
    lead = D // dims[-1]
    for n in range(N - 2, 0, -1):
        lead //= dims[n]
        fl += 2 * k * R * R * lead * dims[n]
    fl += 2 * k * R * dims[0]
    return fl


def flops_project_tt_tt(k: int, dims, R: int, R_in: int) -> int:
    """TT operator applied to TT input: O(k N d R R~ (R + R~))."""
    fl = 0
    for d in dims:
        fl += 2 * k * d * R * R_in * (R + R_in)
    return fl


# ---------------------------------------------------------------------------
# Structured-input (compressed-domain) cost model — the carry-sweep path
# (`repro.kernels.struct`). Per-mode costs follow the einsum carry programs
# exactly; dividing the dense-path FLOPs by these gives the analytic speedup
# the benchmarks report next to measured wall-clock.
# ---------------------------------------------------------------------------

def flops_project_struct(op_family: str, in_family: str, k: int, dims,
                         R: int, R_in: int) -> int:
    """Carry-sweep FLOPs (x2 multiply-add) for one structured projection.

    Per mode of size d, the (operator, input) pairing costs:
      tt x tt : 2 k d R R~ (R + R~)   — two bond updates of the (R, R~) carry
      tt x cp : 2 k d R R~ (R + 1)    — CP input has no bond to re-expand
      cp x tt : 2 k d R R~ (R~ + 1)
      cp x cp : 2 k d R R~  (+ k R R~ Hadamard, kept: exact, not just O())
    vs the dense path's O(k R d^N) (`flops_project_dense_tt` / `_cp`) —
    compressed-domain projection replaces the d^N dependence with N·d.
    """
    if op_family not in ("tt", "cp") or in_family not in ("tt", "cp"):
        raise KeyError(f"no structured cost model for "
                       f"{op_family!r} x {in_family!r}")
    fl = 0
    for d in dims:
        if op_family == "tt" and in_family == "tt":
            fl += 2 * k * d * R * R_in * (R + R_in)
        elif op_family == "tt" and in_family == "cp":
            fl += 2 * k * d * R * R_in * (R + 1)
        elif op_family == "cp" and in_family == "tt":
            fl += 2 * k * d * R * R_in * (R_in + 1)
        else:
            fl += 2 * k * d * R * R_in + k * R * R_in
    return fl


def mem_carry_struct(k: int, R: int, R_in: int, *, batch: int = 1) -> int:
    """Peak carry-state bytes of the sweep: B * k * R * R~ f32 floats —
    the (B, k, R_op·R_in) bond state that replaces the dense path's
    (B, k, d_2..d_N) sweep intermediates (Iwen et al.'s memory argument)."""
    return 4 * batch * k * R * R_in


def struct_speedup(op_family: str, in_family: str, k: int, dims, R: int,
                   R_in: int) -> float:
    """Analytic dense-FLOPs / structured-FLOPs ratio for one projection.

    > 1 while the input's rank is low (the paper's regime: compressed-domain
    projection wins by ~d^{N-1} / (R~ (R + R~))); monotonically decreasing
    in R~, crossing below 1 once R~(R + R~) outgrows the dense contraction —
    the crossover `benchmarks/timing.py` reports per row.
    """
    dense = (flops_project_dense_tt(k, dims, R) if op_family == "tt"
             else flops_project_dense_cp(k, dims, R))
    return dense / flops_project_struct(op_family, in_family, k, dims,
                                        R, R_in)


def flops_project_dense_cp(k: int, dims, R: int) -> int:
    N = len(dims)
    D = 1
    for d in dims:
        D *= d
    fl = 2 * k * R * D
    lead = D
    for n in range(N - 2, -1, -1):
        lead //= dims[n + 1]
        fl += 2 * k * R * lead
    return fl
