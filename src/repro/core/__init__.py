"""repro.core — Tensorized Random Projections (Rakhshan & Rabusseau, AISTATS 2020).

Faithful implementation of the paper's two maps (Definitions 1 & 2) plus the
baselines it compares against and the sketching infrastructure built on top.

Deprecation note (one release): construct projectors through the unified
`repro.rp` API — `rp.make_projector(rp.ProjectorSpec(family=..., ...), key)`
— and project with `rp.project(op, x)`, which dispatches on input structure
(dense / flat / TTTensor / CPTensor) and routes dense inputs to the Pallas
kernels. The names re-exported here (`sample_tt_rp`, `sample_cp_rp`,
`GaussianRP`, `VerySparseRP`, and the per-format `project_tt`/`project_cp`
methods) remain importable as thin shims for existing code and tests.
"""
from .baselines import GaussianRP, VerySparseRP
from .cp_rp import CPRP, sample_cp_rp, trp_average, trp_project
from .formats import (STRUCT_TYPES, BatchedCPTensor, BatchedTTTensor,
                      CPTensor, TTTensor, auto_dims, cp_inner, dense_inner,
                      pad_cp_rank, pad_to_tensorizable, pad_tt_rank,
                      random_cp, random_tt, stack_ragged_cp, stack_ragged_tt,
                      tensorize, tt_cp_inner, tt_inner, tt_svd)
from .sketch import PytreeSketcher, SketchConfig, SketchMonitor
from .tt_rp import TTRP, sample_tt_rp
from . import theory

__all__ = [
    "BatchedCPTensor", "BatchedTTTensor", "STRUCT_TYPES",
    "CPRP", "CPTensor", "GaussianRP", "PytreeSketcher", "SketchConfig",
    "SketchMonitor", "TTRP", "TTTensor", "VerySparseRP", "auto_dims",
    "cp_inner", "dense_inner", "pad_cp_rank", "pad_to_tensorizable",
    "pad_tt_rank", "random_cp", "random_tt", "sample_cp_rp", "sample_tt_rp",
    "stack_ragged_cp", "stack_ragged_tt", "tensorize", "theory",
    "trp_average", "trp_project", "tt_cp_inner", "tt_inner", "tt_svd",
]
