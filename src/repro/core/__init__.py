"""repro.core — Tensorized Random Projections (Rakhshan & Rabusseau, AISTATS 2020).

Faithful implementation of the paper's two maps (Definitions 1 & 2) plus the
baselines it compares against and the sketching infrastructure built on top.
"""
from .baselines import GaussianRP, VerySparseRP
from .cp_rp import CPRP, sample_cp_rp, trp_average, trp_project
from .formats import (CPTensor, TTTensor, auto_dims, cp_inner, dense_inner,
                      pad_to_tensorizable, random_cp, random_tt, tensorize,
                      tt_cp_inner, tt_inner, tt_svd)
from .sketch import PytreeSketcher, SketchConfig, SketchMonitor
from .tt_rp import TTRP, sample_tt_rp
from . import theory

__all__ = [
    "CPRP", "CPTensor", "GaussianRP", "PytreeSketcher", "SketchConfig",
    "SketchMonitor", "TTRP", "TTTensor", "VerySparseRP", "auto_dims",
    "cp_inner", "dense_inner", "pad_to_tensorizable", "random_cp", "random_tt",
    "sample_cp_rp", "sample_tt_rp", "tensorize", "theory", "trp_average",
    "trp_project", "tt_cp_inner", "tt_inner", "tt_svd",
]
