"""Mini dry-run on an 8-device fake mesh: every family lowers+compiles a
train step AND a serve step with the production sharding rules; the roofline
extraction pipeline produces coherent numbers."""
import json

import pytest


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x22b",
                                  "mamba2-1.3b", "recurrentgemma-2b",
                                  "whisper-medium", "qwen2-vl-2b"])
def test_mini_dryrun_train_and_serve(subproc, arch):
    out = subproc(f"""
import jax
from repro.configs import get_config, reduced
from repro.launch import steps, roofline as rl
from repro.models import build_model
from repro.models.config import ShapeSpec
import dataclasses

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(reduced(get_config("{arch}")),
                          d_model=64, vocab=256)
model = build_model(cfg)
with mesh:
    bt = steps.build_train_step(model, mesh, ShapeSpec("t", 32, 8, "train"))
    ct = bt.fn.lower(*bt.args).compile()
    assert ct.cost_analysis() is not None
    bs = steps.build_serve_step(model, mesh, ShapeSpec("d", 64, 8, "decode"))
    cs = bs.fn.lower(*bs.args).compile()
coll = rl.parse_collectives(ct.as_text())
assert coll["link_bytes_per_device"] >= 0
print("MINIDRY_OK", "{arch}", int(coll["link_bytes_per_device"]))
""", devices=8, timeout=1200)
    assert "MINIDRY_OK" in out


def test_collective_parser_units():
    from repro.launch.roofline import parse_collectives
    hlo = '''
  %ag = bf16[32,128]{1,0} all-gather(%x), replica_groups=[4,4]<=[16], dimensions={0}
  %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[16,8]{1,0} reduce-scatter(%z), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = u32[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
'''
    out = parse_collectives(hlo)
    ag = out["per_type"]["all-gather"]
    assert ag["count"] == 1 and ag["bytes"] == 32 * 128 * 2
    assert abs(ag["traffic"] - 32 * 128 * 2 * 3 / 4) < 1e-6
    ar = out["per_type"]["all-reduce"]
    assert ar["bytes"] == 64 * 4
    assert abs(ar["traffic"] - 2 * 256 * 3 / 4) < 1e-6
    rs = out["per_type"]["reduce-scatter"]
    assert abs(rs["traffic"] - 16 * 8 * 4 * 7) < 1e-6
    cp = out["per_type"]["collective-permute"]
    assert cp["traffic"] == 16
