"""Per-arch smoke: reduced config forward/train-step on CPU, output shapes +
finite values; decode step shape/finiteness. One test per assigned arch."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions3"] = jnp.stack([pos, pos, pos])
        batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model)) * 0.1
        batch["patch_positions"] = jnp.tile(jnp.arange(cfg.num_patches), (B, 1))
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_and_decode(name):
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), name
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn), name
    # decode
    cache = model.init_cache(B, 64)
    kw = {}
    if cfg.mrope_sections:
        kw["positions3"] = jnp.zeros((3, B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache,
                                       jnp.ones((B,), jnp.int32),
                                       jnp.zeros((B,), jnp.int32), **kw)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), name
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, cache2)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_param_count_sane(name):
    """The FULL config's parameter count is within 25% of the advertised
    size (dry-run exercises the real tensors; this guards config typos)."""
    cfg = ARCHS[name]
    n = cfg.param_count()
    advertised = {
        "deepseek-67b": 67e9, "qwen1.5-110b": 111e9, "gemma2-9b": 9.2e9,
        "llama3.2-3b": 3.2e9, "arctic-480b": 482e9, "mixtral-8x22b": 141e9,
        "whisper-medium": 0.76e9, "recurrentgemma-2b": 2.7e9,
        "qwen2-vl-2b": 2.2e9, "mamba2-1.3b": 1.3e9,
    }[name]
    assert 0.6 < n / advertised < 1.45, (name, n, advertised)
