import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# `benchmarks` is a plain directory (run via `python -m benchmarks.run`);
# make it importable for tests that exercise the bench harness even when
# pytest was not launched from the repo root.
if REPO not in sys.path:
    sys.path.insert(0, REPO)


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run a python snippet in a fresh process with N fake XLA devices.

    Used by tests that need a multi-device mesh (the main process keeps the
    default single CPU device so ordinary tests stay fast).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\n--- stdout ---\n"
            f"{res.stdout[-4000:]}\n--- stderr ---\n{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture
def subproc():
    return run_subprocess


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop compiled XLA executables after each test module.

    Every jitted executable holds mmapped JIT code pages; across the full
    suite the process accumulates ~60k anonymous maps and crosses the
    kernel's vm.max_map_count (65530 by default), at which point the next
    backend_compile segfaults. Clearing per module keeps the peak bounded
    by the hungriest single module instead of the suite-wide sum.
    """
    yield
    import gc

    import jax

    jax.clear_caches()
    gc.collect()
