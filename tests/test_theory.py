"""Monte-Carlo validation of Theorem 1 (expected isometry + variance bounds)
and the qualitative Theorem 2 ordering (TT needs smaller k than CP at high
order)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_tt, sample_cp_rp, sample_tt_rp, theory

TRIALS = 200


def _norm_samples(sampler, dims, k, rank, x):
    keys = jax.random.split(jax.random.PRNGKey(7), TRIALS)

    def one(kk):
        return jnp.sum(sampler(kk, dims, k, rank).project(x) ** 2)

    return np.asarray(jax.lax.map(one, keys))


@pytest.mark.parametrize("fmt,dims,rank", [
    ("tt", (4, 4, 4), 2), ("tt", (3, 3, 3, 3), 5),
    ("cp", (4, 4, 4), 2), ("cp", (3, 3, 3, 3), 5),
])
def test_expected_isometry_and_variance_bound(fmt, dims, rank):
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    x = x / jnp.sqrt(jnp.sum(x * x))
    k = 32
    sampler = sample_tt_rp if fmt == "tt" else sample_cp_rp
    vals = _norm_samples(sampler, dims, k, rank, x)
    n = len(dims)
    bound = (theory.variance_factor_tt(n, rank) if fmt == "tt"
             else theory.variance_factor_cp(n, rank)) / k
    # E||f(x)||^2 = 1 within CLT noise
    se = vals.std() / np.sqrt(TRIALS)
    assert abs(vals.mean() - 1.0) < 5 * se + 0.02, (vals.mean(), se)
    # Var <= bound (allow MC slack upward, none needed downward)
    assert vals.var() <= bound * 1.35, (vals.var(), bound)


def test_gaussian_specialization():
    """N=1 recovers Var = 2/k ||x||^4 (paper Sec. 4)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    x = x / jnp.sqrt(jnp.sum(x * x))
    k = 16
    vals = _norm_samples(sample_tt_rp, (64,), k, 1, x)
    target = 2.0 / k
    assert abs(vals.var() - target) < 0.6 * target, (vals.var(), target)


def test_tt_beats_cp_at_high_order():
    """Thm 2 ordering: same budget, high order => TT distortion < CP.

    Note |ratio - 1| saturates at 1.0 when the projection collapses toward
    zero, which compresses the visible CP/TT gap; the variance statistic
    separates them much more sharply (see test below)."""
    dims = (3,) * 10
    k = 256
    x = random_tt(jax.random.PRNGKey(2), dims, 5, norm="unit")

    def stats(sampler, rank):
        keys = jax.random.split(jax.random.PRNGKey(9), 60)
        vals = [float(jnp.sum(sampler(kk, dims, k, rank).project_tt(x) ** 2))
                for kk in keys]
        d = [abs(v - 1.0) for v in vals]
        return np.mean(d), np.var(vals)

    d_tt, v_tt = stats(sample_tt_rp, 5)
    d_cp, v_cp = stats(sample_cp_rp, 5)
    assert d_tt < d_cp * 0.85, (d_tt, d_cp)
    assert v_tt < v_cp * 0.25, (v_tt, v_cp)


def test_variance_factor_monotonicity():
    # rank helps TT exponentially, CP only linearly (paper Sec. 4)
    assert theory.variance_factor_tt(10, 10) < theory.variance_factor_tt(10, 1) / 50
    r1, r10 = theory.variance_factor_cp(10, 1), theory.variance_factor_cp(10, 10)
    assert r1 / r10 < 3.0  # CP barely improves with rank


def test_required_k_ordering():
    for n in (3, 8, 16):
        assert (theory.required_k_tt(0.1, 100, n, 5)
                < theory.required_k_cp(0.1, 100, n, 5))


def test_struct_flop_model_and_crossover():
    """The compressed-domain cost model: structured projection beats dense
    by ~d^{N-1}/(R~(R+R~)) at low input rank, the speedup is monotonically
    DECREASING in the input rank, and it crosses below 1 once the carry
    outgrows the dense contraction — the analytic speedup the benchmark
    rows report."""
    k, dims, R = 128, (64, 64, 64), 2
    for op_family, in_family in (("tt", "tt"), ("tt", "cp"),
                                 ("cp", "tt"), ("cp", "cp")):
        sp = [theory.struct_speedup(op_family, in_family, k, dims, R, r)
              for r in (1, 2, 10, 40, 2000)]
        assert all(b < a for a, b in zip(sp, sp[1:])), (op_family, in_family,
                                                        sp)
        assert sp[0] > 1.0, (op_family, in_family, sp[0])   # paper's regime
        assert sp[-1] < 1.0, (op_family, in_family, sp[-1])  # crossover
    # FLOP ordering at equal ranks: the TTxTT carry pays both bonds, CPxCP
    # only the Hadamard — the interleaved pairings sit between
    f = {p: theory.flops_project_struct(*p, k, dims, 4, 4)
         for p in (("tt", "tt"), ("tt", "cp"), ("cp", "tt"), ("cp", "cp"))}
    assert f[("cp", "cp")] < f[("tt", "cp")] <= f[("tt", "tt")]
    assert f[("cp", "cp")] < f[("cp", "tt")] <= f[("tt", "tt")]
    # memory model: the carry is B*k*R*R~ floats, linear in every factor
    assert theory.mem_carry_struct(k, 2, 3, batch=4) == 4 * 4 * k * 2 * 3
    with pytest.raises(KeyError):
        theory.flops_project_struct("tucker", "tt", k, dims, 2, 2)


def test_order_dependent_tt_vs_cp_bound_ordering():
    """The paper's headline ordering, as documented in theory.py: the
    TT-vs-CP bound gap is 1 at N=2 (the maps' bounds coincide) and grows
    STRICTLY and geometrically with every extra mode for R > 1 — the
    prediction the order-N kernel layer / benchmark frontier charts."""
    for R in (2, 5, 10):
        assert abs(theory.variance_ratio_cp_to_tt(2, R) - 1.0) < 1e-12
        ratios = [theory.variance_ratio_cp_to_tt(n, R) for n in range(2, 7)]
        assert all(b > a for a, b in zip(ratios, ratios[1:])), (R, ratios)
        # geometric growth rate approaches 3/(1+2/R) per extra mode
        rate = ratios[-1] / ratios[-2]
        assert 1.0 < rate < 3.0 / (1.0 + 2.0 / R) + 1e-9, (R, rate)
    # R = 1: TT and CP draws coincide distribution-wise, bounds stay equal
    for n in (2, 4, 6):
        assert abs(theory.variance_ratio_cp_to_tt(n, 1) - 1.0) < 1e-12
    # the same ordering reaches the Thm-2 embedding sizes at higher order
    assert (theory.required_k_tt(0.1, 100, 5, 5)
            < theory.required_k_cp(0.1, 100, 5, 5))
