"""Unit tests for the paper's core: TT/CP formats and the two RP maps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CPRP, CPTensor, GaussianRP, TTTensor, VerySparseRP,
                        cp_inner, random_cp, random_tt, sample_cp_rp,
                        sample_tt_rp, tensorize, tt_cp_inner, tt_inner,
                        tt_svd, trp_average, trp_project)

KEY = jax.random.PRNGKey(0)
DIMS = (4, 5, 6)


def test_tt_norm_matches_dense():
    t = random_tt(KEY, DIMS, 3, norm="unit")
    np.testing.assert_allclose(float(t.norm_squared()),
                               float(jnp.sum(t.full() ** 2)), rtol=1e-5)
    np.testing.assert_allclose(float(t.norm_squared()), 1.0, rtol=1e-5)


def test_cp_norm_and_cross_inner():
    t = random_tt(KEY, DIMS, 3)
    c = random_cp(jax.random.PRNGKey(1), DIMS, 3)
    np.testing.assert_allclose(float(c.norm_squared()),
                               float(jnp.sum(c.full() ** 2)), rtol=1e-5)
    np.testing.assert_allclose(float(tt_cp_inner(t, c)),
                               float(jnp.vdot(t.full(), c.full())),
                               rtol=1e-4)


def test_cp_to_tt_exact():
    c = random_cp(KEY, DIMS, 4)
    np.testing.assert_allclose(np.asarray(c.to_tt().full()),
                               np.asarray(c.full()), rtol=1e-4, atol=1e-6)


def test_tt_svd_roundtrip():
    x = jax.random.normal(KEY, DIMS)
    t = tt_svd(x, max_rank=30)  # full rank => exact
    np.testing.assert_allclose(np.asarray(t.full()), np.asarray(x),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rank", [1, 2, 5])
def test_ttrp_matches_dense_matrix(rank):
    op = sample_tt_rp(jax.random.PRNGKey(2), DIMS, 64, rank)
    x = jax.random.normal(jax.random.PRNGKey(3), DIMS)
    a = op.as_dense_matrix()
    np.testing.assert_allclose(np.asarray(op.project(x)),
                               np.asarray(a @ x.reshape(-1)),
                               rtol=1e-4, atol=1e-5)


def test_ttrp_structured_inputs_agree():
    op = sample_tt_rp(jax.random.PRNGKey(2), DIMS, 64, 2)
    t = random_tt(KEY, DIMS, 4)
    c = random_cp(jax.random.PRNGKey(1), DIMS, 3)
    np.testing.assert_allclose(np.asarray(op.project_tt(t)),
                               np.asarray(op.project(t.full())),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.project_cp(c)),
                               np.asarray(op.project(c.full())),
                               rtol=1e-4, atol=1e-5)


def test_ttrp_reconstruct_is_adjoint():
    op = sample_tt_rp(jax.random.PRNGKey(2), DIMS, 64, 2)
    x = jax.random.normal(jax.random.PRNGKey(3), DIMS)
    y = op.project(x)
    a = op.as_dense_matrix()
    np.testing.assert_allclose(np.asarray(op.reconstruct(y)).reshape(-1),
                               np.asarray(a.T @ y), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.reconstruct(y, chunk=7)),
                               np.asarray(op.reconstruct(y)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rank", [1, 3])
def test_cprp_matches_dense_matrix(rank):
    op = sample_cp_rp(jax.random.PRNGKey(4), DIMS, 64, rank)
    x = jax.random.normal(jax.random.PRNGKey(3), DIMS)
    a = op.as_dense_matrix()
    np.testing.assert_allclose(np.asarray(op.project(x)),
                               np.asarray(a @ x.reshape(-1)),
                               rtol=1e-4, atol=1e-5)
    y = op.project(x)
    np.testing.assert_allclose(np.asarray(op.reconstruct(y)).reshape(-1),
                               np.asarray(a.T @ y), rtol=1e-4, atol=1e-5)


def test_cprp_structured_inputs_agree():
    op = sample_cp_rp(jax.random.PRNGKey(4), DIMS, 64, 3)
    t = random_tt(KEY, DIMS, 4)
    c = random_cp(jax.random.PRNGKey(1), DIMS, 3)
    np.testing.assert_allclose(np.asarray(op.project_cp(c)),
                               np.asarray(op.project(c.full())),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.project_tt(t)),
                               np.asarray(op.project(t.full())),
                               rtol=1e-4, atol=1e-5)


def test_trp_equals_cp1():
    """Sun et al.'s TRP is exactly f_CP(1) (paper Sec. 3)."""
    n = len(DIMS)
    k = 32
    fm = [jax.random.normal(jax.random.fold_in(KEY, i), (DIMS[i], k))
          for i in range(n)]
    x = jax.random.normal(jax.random.PRNGKey(3), DIMS)
    y_trp = trp_project(fm, x.reshape(-1))
    op = CPRP(tuple(f.T[:, :, None] for f in fm))
    np.testing.assert_allclose(np.asarray(op.project(x)), np.asarray(y_trp),
                               rtol=1e-4, atol=1e-5)


def test_trp_T_equals_cp_R():
    """TRP(T) (scaled average of T TRPs) == f_CP(R=T) (paper Sec. 3)."""
    n, k, T = len(DIMS), 16, 3
    x = jax.random.normal(jax.random.PRNGKey(3), DIMS)
    fms = [[jax.random.normal(jax.random.fold_in(KEY, 10 * t + i),
                              (DIMS[i], k)) for i in range(n)]
           for t in range(T)]
    y = trp_average([trp_project(fm, x.reshape(-1)) for fm in fms])
    scale = (1.0 / T) ** (1.0 / (2 * n))
    factors = tuple(
        scale * jnp.stack([fms[t][i].T for t in range(T)], axis=-1)
        for i in range(n))  # (k, d, T)
    op = CPRP(factors)
    np.testing.assert_allclose(np.asarray(op.project(x)), np.asarray(y),
                               rtol=1e-4, atol=1e-5)


def test_gaussian_rp_streaming_matches_materialized():
    g = GaussianRP(jax.random.PRNGKey(6), 64, 120, block=32)
    x = jax.random.normal(KEY, (120,))
    np.testing.assert_allclose(np.asarray(g.project(x)),
                               np.asarray(g.materialize() @ x),
                               rtol=1e-4, atol=1e-5)


def test_sparse_rp_expected_isometry():
    x = jax.random.normal(KEY, (120,))
    x = x / jnp.linalg.norm(x)
    vals = [float(jnp.sum(VerySparseRP(jax.random.PRNGKey(i), 256, 120,
                                       block=40).project(x) ** 2))
            for i in range(50)]
    assert abs(np.mean(vals) - 1.0) < 0.15, np.mean(vals)
