"""Checkpointing (atomicity, async, resharding) and fault-tolerance runtime
(watchdog, crash-restart with bit-exact resume)."""
import functools
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpointer
from repro.configs import ARCHS, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.runtime import train_loop
from repro.runtime.resilience import (FaultInjector, RestartReport, Watchdog,
                                      run_with_restarts)


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (17, 5)),
            "b": {"w": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                  "s": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    checkpointer.save(tmp_path, 7, t)
    restored, step = checkpointer.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_keep_gc(tmp_path):
    t = _tree()
    for s in range(6):
        checkpointer.save(tmp_path, s, t, keep=2)
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(10))


def test_no_partial_checkpoints_on_failure(tmp_path):
    class Boom:
        pass
    bad = {"x": Boom()}  # device_get will fail
    with pytest.raises(Exception):
        checkpointer.save(tmp_path, 1, bad)
    assert checkpointer.latest_step(tmp_path) is None
    assert not list(pathlib.Path(tmp_path).glob("step_*"))


def test_async_checkpointer(tmp_path):
    ck = checkpointer.AsyncCheckpointer(tmp_path, keep=2)
    t = _tree()
    ck.save(3, t)
    ck.wait()
    restored, step = checkpointer.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 3


def test_watchdog_flags_straggler():
    wd = Watchdog(warmup=2, z_thresh=3.0)
    for s in range(12):
        wd.start_step()
        time.sleep(0.02 if s != 9 else 0.2)
        wd.end_step(s)
    assert any(ev.step == 9 for ev in wd.events), wd.events


def test_crash_restart_resumes_exactly(tmp_path):
    """Train 30 steps with a crash at step 17; supervised restart must land
    on exactly the same final params as an uninterrupted run."""
    cfg = reduced(ARCHS["llama3.2-3b"])
    model = build_model(cfg)
    shape = ShapeSpec("t", 32, 4, "train")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lr_fn = functools.partial(schedule.constant, peak_lr=1e-3)

    def train(ckpt_dir, injector=None, steps=30):
        with mesh:
            bundle = steps_lib.build_train_step(model, mesh, shape,
                                                lr_fn=lr_fn)
            state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
            cfg_l = train_loop.LoopConfig(total_steps=steps,
                                          ckpt_dir=str(ckpt_dir),
                                          ckpt_every=5, log_every=1000,
                                          async_ckpt=False)
            state, final = train_loop.run(bundle.fn, state, data, cfg_l,
                                          injector=injector,
                                          log=lambda *_: None)
            return state, final

    # uninterrupted
    s_ref, _ = train(tmp_path / "ref")

    # crashing run under the restart supervisor
    inj = FaultInjector({17})
    holder = {}

    def attempt(injector):
        state, final = train(tmp_path / "crash", injector=injector)
        holder["state"] = state
        return final

    report = run_with_restarts(attempt, max_restarts=2, injector=inj)
    assert report.completed and report.restarts == 1, report
    ref_leaves = jax.tree.leaves(s_ref["params"])
    got_leaves = jax.tree.leaves(holder["state"]["params"])
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_elastic_restore_reshard(subproc):
    """Checkpoint written on a 1x1 mesh restores (re-sharded) onto 2x2."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile, functools
from repro.configs import ARCHS, reduced
from repro.launch import steps as steps_lib
from repro.launch import sharding as sh
from repro.ckpt import checkpointer
from repro.models import build_model
from repro.models.config import ShapeSpec
from jax.sharding import NamedSharding

cfg = reduced(ARCHS["llama3.2-3b"])
model = build_model(cfg)
d = tempfile.mkdtemp()
state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
checkpointer.save(d, 3, state)

mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
shapes = jax.eval_shape(lambda: state)
pspecs = sh.param_specs(cfg, model.param_axes(), mesh, shapes["params"])
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
restored, step = checkpointer.restore(
    d, shapes, shardings={"params": shardings,
                          "opt": {"m": shardings, "v": shardings,
                                  "count": None}})
assert step == 3
leaf = jax.tree.leaves(restored["params"])[0]
assert hasattr(leaf, "sharding"), type(leaf)
ref = jax.tree.leaves(state["params"])[0]
np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref))
print("ELASTIC_OK")
""", devices=4)
    assert "ELASTIC_OK" in out


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    d = SyntheticLM(cfg)
    b1 = d.batch(5)
    b2 = d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # union of shards == global batch
    s0 = d.batch(5, shard=0, num_shards=2)
    s1 = d.batch(5, shard=1, num_shards=2)
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
