"""Checkpointing (atomicity, integrity verification with fallback, async,
resharding, sketched-state records, elastic pod respec) and fault-tolerance
runtime (watchdog, retry/backoff, injected storage faults, crash-restart
with bit-exact resume)."""
import functools
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (SketchedTreeCodec, checkpointer, respec_pod_ef,
                        resume_elastic)
from repro.ckpt.checkpointer import CheckpointError, CorruptionError
from repro.configs import ARCHS, reduced
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.runtime import train_loop
from repro.runtime.resilience import (FaultInjector, IOFaultInjector,
                                      IOFaultPlan, RestartReport, Watchdog,
                                      backoff_delays, flip_byte,
                                      retry_with_backoff, run_with_restarts)


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (17, 5)),
            "b": {"w": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                  "s": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    checkpointer.save(tmp_path, 7, t)
    restored, step = checkpointer.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_keep_gc(tmp_path):
    t = _tree()
    for s in range(6):
        checkpointer.save(tmp_path, s, t, keep=2)
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(10))


def test_no_partial_checkpoints_on_failure(tmp_path):
    class Boom:
        pass
    bad = {"x": Boom()}  # device_get will fail
    with pytest.raises(Exception):
        checkpointer.save(tmp_path, 1, bad)
    assert checkpointer.latest_step(tmp_path) is None
    assert not list(pathlib.Path(tmp_path).glob("step_*"))


def test_async_checkpointer(tmp_path):
    ck = checkpointer.AsyncCheckpointer(tmp_path, keep=2)
    t = _tree()
    ck.save(3, t)
    ck.wait()
    restored, step = checkpointer.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 3


def test_watchdog_flags_straggler():
    wd = Watchdog(warmup=2, z_thresh=3.0)
    for s in range(12):
        wd.start_step()
        time.sleep(0.02 if s != 9 else 0.2)
        wd.end_step(s)
    assert any(ev.step == 9 for ev in wd.events), wd.events


def test_crash_restart_resumes_exactly(tmp_path):
    """Train 30 steps with a crash at step 17; supervised restart must land
    on exactly the same final params as an uninterrupted run."""
    cfg = reduced(ARCHS["llama3.2-3b"])
    model = build_model(cfg)
    shape = ShapeSpec("t", 32, 4, "train")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lr_fn = functools.partial(schedule.constant, peak_lr=1e-3)

    def train(ckpt_dir, injector=None, steps=30):
        with mesh:
            bundle = steps_lib.build_train_step(model, mesh, shape,
                                                lr_fn=lr_fn)
            state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
            cfg_l = train_loop.LoopConfig(total_steps=steps,
                                          ckpt_dir=str(ckpt_dir),
                                          ckpt_every=5, log_every=1000,
                                          async_ckpt=False)
            state, final = train_loop.run(bundle.fn, state, data, cfg_l,
                                          injector=injector,
                                          log=lambda *_: None)
            return state, final

    # uninterrupted
    s_ref, _ = train(tmp_path / "ref")

    # crashing run under the restart supervisor
    inj = FaultInjector({17})
    holder = {}

    def attempt(injector):
        state, final = train(tmp_path / "crash", injector=injector)
        holder["state"] = state
        return final

    report = run_with_restarts(attempt, max_restarts=2, injector=inj)
    assert report.completed and report.restarts == 1, report
    ref_leaves = jax.tree.leaves(s_ref["params"])
    got_leaves = jax.tree.leaves(holder["state"]["params"])
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_elastic_restore_reshard(subproc):
    """Checkpoint written on a 1x1 mesh restores (re-sharded) onto 2x2."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile, functools
from repro.configs import ARCHS, reduced
from repro.launch import steps as steps_lib
from repro.launch import sharding as sh
from repro.ckpt import checkpointer
from repro.models import build_model
from repro.models.config import ShapeSpec
from jax.sharding import NamedSharding

cfg = reduced(ARCHS["llama3.2-3b"])
model = build_model(cfg)
d = tempfile.mkdtemp()
state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
checkpointer.save(d, 3, state)

mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
shapes = jax.eval_shape(lambda: state)
pspecs = sh.param_specs(cfg, model.param_axes(), mesh, shapes["params"])
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
restored, step = checkpointer.restore(
    d, shapes, shardings={"params": shardings,
                          "opt": {"m": shardings, "v": shardings,
                                  "count": None}})
assert step == 3
leaf = jax.tree.leaves(restored["params"])[0]
assert hasattr(leaf, "sharding"), type(leaf)
ref = jax.tree.leaves(state["params"])[0]
np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref))
print("ELASTIC_OK")
""", devices=4)
    assert "ELASTIC_OK" in out


# ---------------------------------------------------------------------------
# integrity: verify / corruption detection / fallback restore
# ---------------------------------------------------------------------------

def test_verify_passes_and_detects_truncated_array(tmp_path):
    t = _tree()
    path = checkpointer.save(tmp_path, 3, t)
    manifest = checkpointer.verify(path)          # clean ckpt verifies
    assert manifest["step"] == 3 and manifest["integrity"]
    with open(path / "arr_0.npy", "r+b") as f:    # torn write
        f.truncate(40)
    with pytest.raises(CorruptionError, match="unreadable|truncated|drift"):
        checkpointer.verify(path)
    assert not checkpointer.is_verified(tmp_path, 3)


def test_verify_detects_flipped_array_byte_and_manifest_byte(tmp_path):
    t = _tree()
    path = checkpointer.save(tmp_path, 1, t)
    flip_byte(path / "arr_0.npy", -1)             # payload bit flip
    with pytest.raises(CorruptionError, match="checksum"):
        checkpointer.verify(path)
    path2 = checkpointer.save(tmp_path, 2, t)
    flip_byte(path2 / "manifest.json", -2)        # manifest tampering
    with pytest.raises(CorruptionError, match="manifest"):
        checkpointer.verify(path2)


def test_restore_falls_back_to_newest_verified(tmp_path):
    for s in (1, 2, 3):
        checkpointer.save(tmp_path, s, _tree(s), keep=10)
    flip_byte(tmp_path / "step_0000000003" / "arr_0.npy")
    assert checkpointer.newest_verified_step(tmp_path) == 2
    restored, step = checkpointer.restore(tmp_path,
                                          jax.eval_shape(lambda: _tree()))
    assert step == 2                              # fell back past corrupt 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), _tree(2), restored)
    # no fallback => the corruption surfaces as a typed error
    with pytest.raises(CorruptionError):
        checkpointer.restore(tmp_path, jax.eval_shape(lambda: _tree()),
                             step=3, fallback=False)
    # everything corrupt => CorruptionError even with fallback
    flip_byte(tmp_path / "step_0000000002" / "arr_1.npy")
    flip_byte(tmp_path / "step_0000000001" / "manifest.json")
    with pytest.raises(CorruptionError, match="no verifiable"):
        checkpointer.restore(tmp_path, jax.eval_shape(lambda: _tree()))


def test_corrupted_manifest_via_injector_falls_back(tmp_path):
    checkpointer.save(tmp_path, 5, _tree(5), keep=10)
    io = IOFaultInjector(IOFaultPlan(corrupt_manifest=True))
    checkpointer.save(tmp_path, 6, _tree(6), keep=10, io=io)
    assert "flip:manifest.json" in io.injected
    restored, step = checkpointer.restore(tmp_path,
                                          jax.eval_shape(lambda: _tree()))
    assert step == 5


def test_restore_typed_errors(tmp_path):
    checkpointer.save(tmp_path, 1, _tree())
    wrong_count = {"a": jax.ShapeDtypeStruct((17, 5), jnp.float32)}
    with pytest.raises(CheckpointError, match="tree structure"):
        checkpointer.restore(tmp_path, wrong_count)
    wrong_shape = jax.eval_shape(lambda: _tree())
    wrong_shape["a"] = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    with pytest.raises(CheckpointError, match="shape"):
        checkpointer.restore(tmp_path, wrong_shape)
    with pytest.raises(CheckpointError, match="shardings"):
        checkpointer.restore(tmp_path, jax.eval_shape(lambda: _tree()),
                             shardings={"a": None})
    # CheckpointError IS a ValueError (supervisors classify it as fatal)
    assert issubclass(CorruptionError, CheckpointError)
    assert issubclass(CheckpointError, ValueError)


def test_restore_validation_survives_python_O(tmp_path):
    """The restore-path checks are typed raises, not asserts: they must
    still fire under `python -O` (which strips assert statements)."""
    import subprocess
    import sys
    code = f"""
import jax, jax.numpy as jnp
from repro.ckpt import checkpointer
from repro.ckpt.checkpointer import CheckpointError
d = {str(tmp_path)!r}
t = {{"a": jnp.ones((3, 2)), "b": jnp.zeros((4,))}}
checkpointer.save(d, 1, t)
try:
    checkpointer.restore(d, {{"a": jax.ShapeDtypeStruct((3, 2), jnp.float32)}})
except CheckpointError as e:
    assert "tree structure" in str(e), e
else:
    raise SystemExit("n_arrays mismatch not caught under -O")
try:
    checkpointer.restore(d, {{"a": jax.ShapeDtypeStruct((9, 9), jnp.float32),
                             "b": jax.ShapeDtypeStruct((4,), jnp.float32)}})
except CheckpointError as e:
    assert "shape" in str(e), e
else:
    raise SystemExit("shape mismatch not caught under -O")
try:
    checkpointer.restore(d, jax.eval_shape(lambda: t), shardings={{"a": None}})
except CheckpointError as e:
    assert "shardings" in str(e), e
else:
    raise SystemExit("shardings-length mismatch not caught under -O")
print("O_SAFE_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "O_SAFE_OK" in res.stdout, (
        res.stdout, res.stderr)


# ---------------------------------------------------------------------------
# retry / backoff / injected I/O faults
# ---------------------------------------------------------------------------

def test_retry_with_backoff_schedule():
    slept, calls = [], {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise OSError("transient")
        return "ok"

    out = retry_with_backoff(flaky, retries=4, base_delay=0.1, max_delay=0.25,
                             sleep=slept.append)
    assert out == "ok" and calls["n"] == 4
    assert slept == [0.1, 0.2, 0.25]              # capped exponential
    assert backoff_delays(3, base_delay=0.1, max_delay=0.25) == slept
    # non-retryable errors propagate immediately, budget untouched
    with pytest.raises(KeyError):
        retry_with_backoff(lambda: (_ for _ in ()).throw(KeyError("x")),
                           sleep=slept.append)


def test_save_survives_transient_write_faults(tmp_path):
    io = IOFaultInjector(IOFaultPlan(fail_writes=2))
    checkpointer.save(tmp_path, 1, _tree(), io=io, base_delay=0.0)
    assert io.writes >= 2 + 1                     # 2 failures absorbed
    assert checkpointer.is_verified(tmp_path, 1)


def test_save_exhausted_rename_budget_raises_and_leaves_no_ckpt(tmp_path):
    io = IOFaultInjector(IOFaultPlan(fail_renames=5))
    with pytest.raises(OSError, match="injected rename"):
        checkpointer.save(tmp_path, 1, _tree(), io=io, retries=2,
                          base_delay=0.0)
    assert checkpointer.latest_step(tmp_path) is None
    assert not list(pathlib.Path(tmp_path).glob(".tmp_*"))  # tmp cleaned


def test_sweep_tmp_on_startup_and_save(tmp_path):
    orphan = pathlib.Path(tmp_path) / ".tmp_deadbeef"
    orphan.mkdir(parents=True)
    (orphan / "arr_0.npy").write_bytes(b"partial")
    ck = checkpointer.AsyncCheckpointer(tmp_path)  # startup sweep
    assert not orphan.exists()
    ck.close()
    orphan.mkdir()
    checkpointer.save(tmp_path, 1, _tree())        # save-time sweep
    assert not orphan.exists()


def test_async_error_fails_next_save_and_context_manager(tmp_path):
    io = IOFaultInjector(IOFaultPlan(fail_writes=50))  # > any retry budget
    ck = checkpointer.AsyncCheckpointer(tmp_path, io=io, retries=1)
    ck.save(1, _tree())
    ck._thread.join()                             # let the failure land
    with pytest.raises(OSError, match="injected"):
        ck.save(2, _tree())                       # fails THIS call
    ck.close()
    # context manager drains the in-flight save on clean exit
    with checkpointer.AsyncCheckpointer(tmp_path, keep=2) as ck2:
        ck2.save(3, _tree())
    assert checkpointer.is_verified(tmp_path, 3)
    # ... and surfaces a background failure on exit
    with pytest.raises(OSError, match="injected"):
        with checkpointer.AsyncCheckpointer(
                tmp_path, io=IOFaultInjector(IOFaultPlan(fail_writes=50)),
                retries=1) as ck3:
            ck3.save(4, _tree())
            ck3._thread.join()


def test_supervisor_fatal_vs_retryable():
    def fatal_fn(injector):
        raise ValueError("misconfigured")

    rep = run_with_restarts(fatal_fn, max_restarts=3)
    assert not rep.completed and rep.restarts == 0
    assert rep.fatal_error and "misconfigured" in rep.fatal_error

    slept = []
    state = {"n": 0}

    def flaky_fn(injector):
        state["n"] += 1
        if state["n"] <= 2:
            raise RuntimeError("preempted")
        return 7

    rep = run_with_restarts(flaky_fn, max_restarts=3, base_delay=0.1,
                            max_delay=0.15, sleep=slept.append)
    assert rep.completed and rep.restarts == 2 and rep.final_step == 7
    assert slept == [0.1, 0.15]                   # capped backoff between


# ---------------------------------------------------------------------------
# sketched-state codec
# ---------------------------------------------------------------------------

_SK_CFG = SketchConfig(family="tt", k=128, rank=2, dims=(4, 8, 16),
                       bucket_elems=4 * 8 * 16, fresh_per_step=True)


def _ef_tree(npod=1, key=1):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    lead = (npod,) if npod > 1 else ()
    return {"w": jax.random.normal(k1, lead + (64, 32)),
            "b": jax.random.normal(k2, lead + (128,))}


def test_sketched_codec_roundtrip_deterministic(tmp_path):
    ef = _ef_tree()
    codec = SketchedTreeCodec(_SK_CFG, jax.eval_shape(lambda: ef))
    rec = codec.encode(ef, step=9)
    assert set(rec) == {"y", "seed", "step"}
    # decode is deterministic: same record -> bit-identical trees
    d1, d2 = codec.decode(rec), codec.decode(rec)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), d1, d2)
    # ... and survives a disk roundtrip through the checkpointer
    checkpointer.save(tmp_path, 9, rec)
    back, _ = checkpointer.restore(tmp_path, codec.record_shapes())
    d3 = codec.decode(back)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), d1, d3)
    # never the dense tensor on disk: the record is nb*k floats + scalars
    assert codec.sketch_bytes() < codec.dense_bytes()
    meta = codec.meta()
    codec2 = SketchedTreeCodec.from_meta(meta, jax.eval_shape(lambda: ef))
    d4 = codec2.decode(rec)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), d1, d4)


def test_sketched_codec_typed_errors():
    ef = _ef_tree()
    codec = SketchedTreeCodec(_SK_CFG, jax.eval_shape(lambda: ef))
    rec = codec.encode(ef, step=0)
    with pytest.raises(CheckpointError, match="base key"):
        SketchedTreeCodec(_SK_CFG, jax.eval_shape(lambda: ef),
                          base_key=0xBAD).decode(rec)
    bad = dict(rec)
    bad["y"] = rec["y"][:, : _SK_CFG.k // 2]
    with pytest.raises(CheckpointError, match="shape"):
        codec.decode(bad)


def test_train_loop_sketched_ef_crash_restart_bit_identical(tmp_path):
    """Two supervised runs (same crash schedule) through the sketched-EF
    checkpoint path produce bit-identical params AND ef: encode/decode is a
    pure function of (state, step, cfg, key), so crash-restart stays
    reproducible even though the EF roundtrip is an estimate."""
    data = SyntheticLM(DataConfig(vocab=31, seq_len=8, global_batch=2))

    def step_fn(state, batch):
        g = jnp.sum(batch["tokens"]) * 1e-3
        params = jax.tree.map(lambda p: p - 1e-2 * (p + g), state["params"])
        ef = jax.tree.map(lambda e, p: 0.9 * e + 0.1 * p, state["ef"],
                          params)
        loss = sum(jnp.sum(p ** 2) for p in jax.tree.leaves(params))
        return {"params": params, "ef": ef}, {"loss": loss}

    def init():
        return {"params": _ef_tree(key=2), "ef": _ef_tree(key=3)}

    def run_once(d):
        codec = SketchedTreeCodec(
            _SK_CFG, jax.eval_shape(lambda: init()["ef"]))
        inj = FaultInjector({9})
        holder = {}

        def attempt(injector):
            cfg = train_loop.LoopConfig(total_steps=14, ckpt_dir=str(d),
                                        ckpt_every=4, log_every=1000,
                                        async_ckpt=False)
            state, final = train_loop.run(step_fn, init(), data, cfg,
                                          injector=injector,
                                          log=lambda *_: None,
                                          ef_codec=codec)
            holder["state"] = state
            return final

        rep = run_with_restarts(attempt, max_restarts=2, injector=inj)
        assert rep.completed and rep.restarts == 1, rep
        return holder["state"]

    s1 = run_once(tmp_path / "a")
    s2 = run_once(tmp_path / "b")
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s1, s2)
    # the manifest carries the codec meta; the record on disk is the sketch
    step = checkpointer.latest_step(tmp_path / "a")
    man = checkpointer.read_manifest(tmp_path / "a", step)
    assert "sketched_ef" in man["extra"]
    shapes = [tuple(a["shape"]) for a in man["arrays"]]
    # params leaves appear ONCE each; the ef copies of the same shapes are
    # replaced by one (nb, k) sketch + two scalars — never on disk densely
    assert shapes.count((64, 32)) == 1 and shapes.count((128,)) == 1, shapes
    assert shapes.count((5, 128)) == 1, shapes    # the (nb, k) sketch


# ---------------------------------------------------------------------------
# elastic resume: pod respec + operator regeneration from the saved seed
# ---------------------------------------------------------------------------

def test_respec_pod_ef_divisible_is_bit_exact():
    ef = _ef_tree(npod=4)
    out = respec_pod_ef(ef, 4, 2)
    for k in ef:
        got = np.asarray(out[k])
        want = np.asarray(ef[k][0] + ef[k][1]), np.asarray(ef[k][2] + ef[k][3])
        np.testing.assert_array_equal(got[0], want[0])   # bit-exact sums
        np.testing.assert_array_equal(got[1], want[1])
    down = respec_pod_ef(ef, 4, 1)                       # full collapse
    for k in ef:
        np.testing.assert_array_equal(
            np.asarray(down[k]),
            np.asarray(ef[k][0] + ef[k][1] + ef[k][2] + ef[k][3]))


def test_respec_pod_ef_total_preserving_and_errors():
    ef = _ef_tree(npod=2)
    up = respec_pod_ef(ef, 2, 3)                  # non-dividing: total kept
    for k in ef:
        np.testing.assert_allclose(np.asarray(jnp.sum(up[k], axis=0)),
                                   np.asarray(jnp.sum(ef[k], axis=0)),
                                   rtol=1e-6)
        assert up[k].shape == (3,) + ef[k].shape[1:]
    one = _ef_tree(npod=1)
    grown = respec_pod_ef(one, 1, 4)              # 1 -> N splits evenly
    for k in one:
        assert grown[k].shape == (4,) + one[k].shape
        np.testing.assert_allclose(np.asarray(jnp.sum(grown[k], axis=0)),
                                   np.asarray(one[k]), rtol=1e-6)
    with pytest.raises(CheckpointError, match="leading dim"):
        respec_pod_ef(_ef_tree(npod=2), 3, 2)
    with pytest.raises(CheckpointError, match=">= 1"):
        respec_pod_ef(ef, 0, 2)


def test_resume_elastic_sketched_onto_fewer_pods(tmp_path):
    """Checkpoint written on 4 pods with a sketched EF record resumes onto
    2 pods: codec rebuilt from manifest meta (operator regenerated from the
    SAVED seed — no operator bytes on disk), pod rows re-bucketed exactly."""
    npod_old, npod_new = 4, 2
    state = {"params": _ef_tree(key=2), "ef": _ef_tree(npod=npod_old, key=3)}
    codec = SketchedTreeCodec(_SK_CFG, jax.eval_shape(lambda: state["ef"]))
    to_save = dict(state)
    to_save["ef"] = codec.encode(state["ef"], step=8)
    checkpointer.save(tmp_path, 8, to_save,
                      extra={"npod": npod_old, "sketched_ef": codec.meta()})

    new_ef_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((npod_new,) + l.shape[1:], l.dtype),
        jax.eval_shape(lambda: state["ef"]))
    example = {"params": jax.eval_shape(lambda: state["params"]),
               "ef": new_ef_shapes}
    resumed, step = resume_elastic(tmp_path, example, npod_new=npod_new)
    assert step == 8
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state["params"], resumed["params"])
    # reference: decode the same record with a fresh codec, then respec
    want = respec_pod_ef(codec.decode(to_save["ef"]), npod_old, npod_new)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), want, resumed["ef"])
    # corruption still falls back inside resume_elastic's step selection
    flip_byte(tmp_path / "step_0000000008" / "arr_0.npy")
    with pytest.raises(CorruptionError):
        resume_elastic(tmp_path, example, npod_new=npod_new)


def test_resume_elastic_dense_ef_and_no_ef(tmp_path):
    state = {"params": _ef_tree(key=2), "ef": _ef_tree(npod=2, key=3)}
    checkpointer.save(tmp_path / "d", 4, state, extra={"npod": 2})
    example = {"params": jax.eval_shape(lambda: state["params"]),
               "ef": jax.tree.map(
                   lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                   jax.eval_shape(lambda: state["ef"]))}
    resumed, step = resume_elastic(tmp_path / "d", example, npod_new=1)
    for k in state["ef"]:
        np.testing.assert_array_equal(
            np.asarray(resumed["ef"][k]),
            np.asarray(state["ef"][k][0] + state["ef"][k][1]))
    plain = {"params": _ef_tree(key=5)}
    checkpointer.save(tmp_path / "p", 2, plain)
    got, step = resume_elastic(tmp_path / "p",
                               jax.eval_shape(lambda: plain), npod_new=8)
    assert step == 2


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    d = SyntheticLM(cfg)
    b1 = d.batch(5)
    b2 = d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # union of shards == global batch
    s0 = d.batch(5, shard=0, num_shards=2)
    s1 = d.batch(5, shard=1, num_shards=2)
    assert s0["tokens"].shape[0] == 4 and s1["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
