"""Tests for the unified repro.rp projector API.

Covers: registry round-trip for all four families, structure-dispatch
equivalence (flat / dense / TT / CP inputs agree), backend equivalence
(pallas interpret-mode vs xla), provable auto->pallas routing, typed format
errors, SketchConfig family passthrough (gaussian end-to-end roundtrip),
and a JL-property smoke test per family (the non-hypothesis counterpart of
tests/test_property.py::test_jl_pairwise_distances).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rp
from repro.core import random_cp, random_tt
from repro.core.sketch import PytreeSketcher, SketchConfig

FAMILIES = ("tt", "cp", "gaussian", "sparse")
DIMS = (4, 5, 6)
KEY = jax.random.PRNGKey(0)


def _op(family, k=64, dims=DIMS, rank=2, key=KEY):
    return rp.make_projector(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank), key)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_builtin_families():
    assert set(FAMILIES) <= set(rp.list_families())


@pytest.mark.parametrize("family", FAMILIES)
def test_registry_roundtrip(family):
    op = _op(family)
    assert isinstance(op, rp.RPOperator)
    assert op.k == 64
    assert op.num_params() > 0
    y = rp.project(op, jax.random.normal(KEY, DIMS))
    assert y.shape == (64,)
    a = op.as_dense_matrix()
    assert a.shape == (64, 4 * 5 * 6)


def test_registry_aliases_resolve_but_are_not_listed():
    assert rp.get_family("dense") is rp.get_family("gaussian")
    assert rp.get_family("verysparse") is rp.get_family("sparse")
    assert "dense" not in rp.list_families()


def test_unknown_family_raises_with_known_list():
    with pytest.raises(KeyError, match="unknown RP family"):
        rp.make_projector(rp.ProjectorSpec(family="nope", k=8, dims=(4,)), KEY)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        rp.register_family("tt")(lambda spec, key: None)


def test_register_new_family_plugs_into_call_sites():
    name = "unit-test-scaled-tt"
    try:
        @rp.register_family(name)
        def _make(spec, key):
            return _op("tt", k=spec.k, dims=spec.dims, rank=spec.rank, key=key)

        op = rp.make_projector(
            rp.ProjectorSpec(family=name, k=32, dims=DIMS, rank=2), KEY)
        assert rp.project(op, jax.random.normal(KEY, DIMS)).shape == (32,)
        cfg = SketchConfig(family=name, k=32, rank=2, bucket_elems=120,
                           dims=DIMS)
        assert cfg.operator_params() == op.num_params()
    finally:
        from repro.rp import registry as _reg
        _reg._FAMILIES.pop(name, None)


# ---------------------------------------------------------------------------
# structure dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_dispatch_paths_agree(family):
    """flat == dense == TT == CP routing on exactly-representable inputs."""
    t = random_tt(jax.random.PRNGKey(1), DIMS, 3)
    c = random_cp(jax.random.PRNGKey(2), DIMS, 2)
    op = _op(family, k=128)
    for x in (t, c):
        xd = x.full()
        y_dense = rp.project(op, xd)
        y_flat = rp.project(op, xd.reshape(-1))
        y_struct = rp.project(op, x)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_flat),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_struct),
                                   rtol=1e-4, atol=1e-5)


def test_ttrp_project_cp_boundary_contraction():
    """Regression for the dead conditional in TTRP.project_cp: the carry is
    always (k, 1, R~); cross-format equality must hold exactly-representably."""
    c = random_cp(jax.random.PRNGKey(3), DIMS, 4)
    t = c.to_tt()
    op = _op("tt", k=96, rank=3)
    y_dense = rp.project(op, c.full())
    np.testing.assert_allclose(np.asarray(rp.project(op, c)),
                               np.asarray(y_dense), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rp.project(op, t)),
                               np.asarray(y_dense), rtol=1e-4, atol=1e-5)


def test_flat_vector_zero_padding():
    """Short flat inputs are zero-padded — projection of the embedded vector."""
    op = _op("tt")
    x = jax.random.normal(KEY, (100,))  # prod(DIMS) = 120
    y = rp.project(op, x)
    xp = jnp.concatenate([x, jnp.zeros((20,))]).reshape(DIMS)
    np.testing.assert_allclose(np.asarray(y), np.asarray(op.project(xp)),
                               rtol=1e-5, atol=1e-5)


def test_batched_flat_vector_zero_padding():
    """Regression: BATCHED short flat vectors `(*batch, D < prod(dims))`
    zero-pad the trailing axis exactly like the 1-D case (the old coercion
    only padded unbatched vectors and raised on batches of ragged tail
    buckets)."""
    op = _op("tt")
    xb = jax.random.normal(KEY, (4, 100))   # prod(DIMS) = 120
    yb = rp.project(op, xb)
    assert yb.shape == (4, 64)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(yb[i]),
                                   np.asarray(rp.project(op, xb[i])),
                                   rtol=1e-5, atol=1e-5)
    # multi-axis batches pad the same way
    y2 = rp.project(op, xb.reshape(2, 2, 100))
    np.testing.assert_allclose(np.asarray(y2.reshape(4, -1)), np.asarray(yb),
                               rtol=1e-6, atol=1e-6)
    # flat families too
    g = _op("gaussian")
    yg = rp.project(g, xb)
    np.testing.assert_allclose(np.asarray(yg[2]),
                               np.asarray(rp.project(g, xb[2])),
                               rtol=1e-5, atol=1e-5)


def test_batched_inputs():
    op = _op("tt")
    xb = jax.random.normal(KEY, (7,) + DIMS)
    yb = rp.project(op, xb)
    assert yb.shape == (7, 64)
    np.testing.assert_allclose(np.asarray(yb[3]),
                               np.asarray(rp.project(op, xb[3])),
                               rtol=1e-5, atol=1e-5)
    # batched flat for a flat family
    g = _op("gaussian")
    yf = rp.project(g, xb.reshape(7, -1))
    np.testing.assert_allclose(np.asarray(yf[2]),
                               np.asarray(rp.project(g, xb[2])),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("family", FAMILIES)
def test_batched_reconstruct_dispatch(family):
    """(B, k) sketches -> (B, *in_dims) estimates matching per-sketch calls."""
    op = _op(family, k=64)
    yb = jax.random.normal(jax.random.PRNGKey(13), (5, 64))
    xb = rp.reconstruct(op, yb)
    assert xb.shape == (5,) + tuple(op.in_dims)
    np.testing.assert_allclose(np.asarray(xb[2]),
                               np.asarray(rp.reconstruct(op, yb[2])),
                               rtol=1e-5, atol=1e-5)
    # multi-axis batch
    x2 = rp.reconstruct(op, yb.reshape(5, 1, 64))
    np.testing.assert_allclose(np.asarray(x2[:, 0]), np.asarray(xb),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("family", ("tt", "cp"))
def test_batched_project_reconstruct_backend_equivalence(family):
    """Batched pallas (interpret) == batched xla for project AND reconstruct."""
    dims = (16, 32, 24)
    op = _op(family, k=128, dims=dims)
    xb = jax.random.normal(jax.random.PRNGKey(14), (6,) + dims)
    y_xla = rp.project(op, xb, backend="xla")
    y_pal = rp.project(op, xb, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-4)
    r_xla = rp.reconstruct(op, y_xla, backend="xla")
    r_pal = rp.reconstruct(op, y_xla, backend="pallas")
    assert r_xla.shape == (6,) + dims
    np.testing.assert_allclose(np.asarray(r_xla), np.asarray(r_pal),
                               rtol=2e-4, atol=2e-4)


def test_batched_input_is_one_kernel_dispatch():
    """A whole batch routes through ONE kernel dispatch (no vmap-of-launches):
    the launch-count reduction the batched sketcher relies on."""
    dims = (8, 128, 64)
    op = _op("tt", k=128, dims=dims)
    xb = jax.random.normal(jax.random.PRNGKey(15), (16,) + dims)
    before = rp.kernel_call_count()
    with rp.force_pallas():
        yb = rp.project(op, xb, backend="auto")
        rp.reconstruct(op, yb, backend="auto")
    assert rp.kernel_call_count() == before + 2  # one per direction, B=16
    assert yb.shape == (16, 128)


def test_format_mismatch_typed_errors():
    op = _op("tt")
    # a trailing axis LONGER than prod(dims) cannot be padded or reshaped
    with pytest.raises(rp.FormatMismatchError):
        rp.project(op, jnp.zeros((121,)))
    with pytest.raises(rp.FormatMismatchError):
        rp.project(op, jnp.zeros((4, 121)))
    with pytest.raises(rp.FormatMismatchError):
        rp.project(op, random_tt(KEY, (2, 2, 2), 2))
    with pytest.raises(rp.FormatMismatchError):
        rp.reconstruct(op, jnp.zeros((65,)))
    with pytest.raises(ValueError, match="unknown backend"):
        rp.project(op, jnp.zeros(DIMS), backend="cuda")


def test_short_batch_treated_as_batch_of_flat_vectors():
    """`(B, D < prod(dims))` is a batch of short flat vectors (each padded),
    NOT collapsed into a single tensor of B*D elements — the output keeps
    the batch axis."""
    op = _op("tt")
    y = rp.project(op, jnp.ones((4, 30)))   # 4 * 30 == prod(DIMS) == 120
    assert y.shape == (4, 64)
    np.testing.assert_allclose(
        np.asarray(y[0]),
        np.asarray(rp.project(op, jnp.ones((30,)))), rtol=1e-5, atol=1e-5)


def test_near_miss_dense_tensor_is_rejected_not_padded():
    """A tensor matching in_dims on every mode but the last (a truncated /
    over-long bucket, the classic off-by-one slice bug) must raise, not be
    silently reinterpreted as a batch of short flat vectors."""
    op = _op("tt")                              # DIMS = (4, 5, 6)
    with pytest.raises(rp.FormatMismatchError, match="near-miss"):
        rp.project(op, jnp.zeros((4, 5, 5)))    # truncated last mode
    with pytest.raises(rp.FormatMismatchError, match="near-miss"):
        rp.project(op, jnp.zeros((2, 4, 5, 5)))  # batched truncation
    # but a SHORT trailing axis under different leading modes still pads
    assert rp.project(op, jnp.zeros((3, 5, 5))).shape == (3, 5, 64)


@pytest.mark.parametrize("family", FAMILIES)
def test_reconstruct_adjoint(family):
    op = _op(family, k=128)
    x = jax.random.normal(jax.random.PRNGKey(4), DIMS)
    y = rp.project(op, x)
    a = op.as_dense_matrix()
    want = np.asarray(a).T @ np.asarray(y)
    np.testing.assert_allclose(
        np.asarray(rp.reconstruct(op, y)).reshape(-1), want,
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# backend routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("tt", "cp"))
def test_backend_equivalence_pallas_vs_xla(family):
    dims = (16, 32, 24)
    op = _op(family, k=128, dims=dims)
    x = jax.random.normal(jax.random.PRNGKey(5), dims)
    y_xla = rp.project(op, x, backend="xla")
    y_pal = rp.project(op, x, backend="pallas")  # interpret mode on CPU
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-4)


def test_auto_routes_through_pallas_kernel_when_aligned():
    """Acceptance: MXU-aligned dense input + backend='auto' provably takes
    the Pallas kernel (interpret-mode instrumentation via force_pallas)."""
    dims = (8, 128, 64)  # aligned: every mode % 8 == 0, k % 128 == 0
    op = _op("tt", k=128, dims=dims)
    x = jax.random.normal(jax.random.PRNGKey(6), dims)
    before = rp.kernel_call_count()
    y_plain = rp.project(op, x, backend="auto")
    assert rp.kernel_call_count() == before  # off-TPU auto stays on XLA
    with rp.force_pallas():
        y_kern = rp.project(op, x, backend="auto")
    assert rp.kernel_call_count() == before + 1
    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_kern),
                               rtol=2e-4, atol=2e-4)


def test_reconstruct_chunk_is_planned_not_warned():
    """`chunk=` bounds the einsum path's intermediate; the plan RECORDS how
    each route handles it — the kernel route tiles k internally so chunk is
    FOLDED into the tiling (plan.chunk_policy='folded'), the einsum route
    honors it ('honored') — and no route warns: chunk handling is part of
    the plan, not a dispatch-time surprise."""
    dims = (8, 128, 64)
    op = _op("tt", k=128, dims=dims)
    y = jax.random.normal(jax.random.PRNGKey(30), (128,))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r_kern = rp.reconstruct(op, y, chunk=32, backend="pallas")
        r_xla = rp.reconstruct(op, y, chunk=32, backend="xla")
        rp.reconstruct(op, y, backend="pallas")
    assert not any(issubclass(x.category, UserWarning) for x in w)
    np.testing.assert_allclose(np.asarray(r_kern), np.asarray(r_xla),
                               rtol=2e-4, atol=2e-4)
    # the plan records the chunk disposition per route
    pk = rp.explain(op, y, kind="reconstruct", backend="pallas", chunk=32)
    assert (pk.route, pk.chunk, pk.chunk_policy) == ("pallas", 32, "folded")
    px = rp.explain(op, y, kind="reconstruct", backend="xla", chunk=32)
    assert (px.route, px.chunk, px.chunk_policy) == ("xla", 32, "honored")


def test_auto_skips_kernel_when_unaligned():
    op = _op("tt", k=60, dims=(3, 5, 7))
    x = jax.random.normal(KEY, (3, 5, 7))
    before = rp.kernel_call_count()
    with rp.force_pallas():
        rp.project(op, x, backend="auto")
    assert rp.kernel_call_count() == before


# ---------------------------------------------------------------------------
# order-N routing (acceptance: orders 4 and 5 take the mode-sweep kernels)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("tt", "cp"))
@pytest.mark.parametrize("dims", [(16, 16), (8, 8, 8, 8), (8, 8, 8, 8, 8)])
def test_order_n_kernel_routing_and_equality(family, dims):
    """MXU-aligned dense inputs of orders 2/4/5 provably route through the
    mode-sweep Pallas kernel under force_pallas (kernel_call_count, one
    dispatch per batched direction) and match the einsum reference."""
    op = _op(family, k=128, dims=dims)
    xb = jax.random.normal(jax.random.PRNGKey(21), (3,) + dims)
    with rp.dispatch_stats() as stats:
        with rp.force_pallas():
            y_kern = rp.project(op, xb, backend="auto")
            assert stats.kernel_calls == 1
            r_kern = rp.reconstruct(op, y_kern, backend="auto")
            assert stats.kernel_calls == 2
    y_xla = rp.project(op, xb, backend="xla")
    r_xla = rp.reconstruct(op, y_xla, backend="xla")
    assert y_kern.shape == (3, 128) and r_kern.shape == (3,) + dims
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(r_kern), np.asarray(r_xla),
                               rtol=1e-4, atol=1e-4)


def test_out_of_range_orders_stay_on_einsum():
    """Operators outside the kernel-supported order range — order-1 (no
    mode to sweep) and order > kernels.MAX_ORDER — take the einsum path
    even under backend='pallas', without counting a kernel dispatch."""
    from repro.core import sample_tt_rp
    from repro.kernels import MAX_ORDER
    for dims in ((64,), (2,) * (MAX_ORDER + 1)):
        op = sample_tt_rp(jax.random.PRNGKey(22), dims, 128, 1)
        x = jax.random.normal(jax.random.PRNGKey(23), dims)
        with rp.dispatch_stats() as stats:
            y = rp.project(op, x, backend="pallas")
            r = rp.reconstruct(op, y, backend="pallas")
            assert stats.kernel_calls == 0
        np.testing.assert_allclose(np.asarray(y), np.asarray(op.project(x)),
                                   rtol=1e-5, atol=1e-5)
        assert r.shape == dims


# ---------------------------------------------------------------------------
# context-local dispatch instrumentation
# ---------------------------------------------------------------------------

def test_dispatch_stats_scopes_are_isolated():
    """Counts inside a dispatch_stats() scope never leak to the enclosing
    context (the old module-global counter did)."""
    dims = (8, 128, 64)
    op = _op("tt", k=128, dims=dims)
    x = jax.random.normal(jax.random.PRNGKey(24), dims)
    outer_before = rp.kernel_call_count()
    outer_breakdown = rp.dispatch_breakdown()
    with rp.dispatch_stats() as inner:
        rp.project(op, x, backend="pallas")
        assert inner.kernel_calls == 1
        assert inner.breakdown == {("tt", "dense", "pallas", 3): 1}
        assert rp.dispatch_breakdown() == inner.breakdown
        with rp.dispatch_stats() as innermost:
            rp.project(op, x, backend="pallas")
            assert innermost.kernel_calls == 1
            # the breakdown is scoped exactly like kernel_calls
            assert innermost.breakdown == {("tt", "dense", "pallas", 3): 1}
        assert inner.kernel_calls == 1      # inner scope didn't see it
        assert inner.breakdown[("tt", "dense", "pallas", 3)] == 1
    assert rp.kernel_call_count() == outer_before
    assert rp.current_stats() is not inner
    assert rp.dispatch_breakdown() == outer_breakdown   # nothing leaked


def test_dispatch_breakdown_routes_and_invariant():
    """Every dispatch lands one (family, structure, route, order) cell;
    kernel_calls stays bit-compatible as the sum of the pallas cells."""
    dims = (8, 128, 64)
    op_tt = _op("tt", k=128, dims=dims)
    op_g = _op("gaussian", k=128, dims=dims)
    x = jax.random.normal(jax.random.PRNGKey(25), dims)
    with rp.dispatch_stats() as st:
        y = rp.project(op_tt, x, backend="pallas")      # pallas dense
        rp.project(op_tt, x, backend="xla")             # xla dense
        rp.project(op_g, x, backend="xla")              # gaussian dense
        rp.reconstruct(op_tt, y, backend="xla")         # sketch route
        bd = st.breakdown
        assert bd == {
            ("tt", "dense", "pallas", 3): 1,
            ("tt", "dense", "xla", 3): 1,
            # gaussian is an order-1 (flat dense) operator by construction
            ("gaussian", "dense", "xla", 1): 1,
            ("tt", "sketch", "xla", 3): 1,
        }
        pallas_total = sum(n for (_, _, route, _), n in bd.items()
                           if route == "pallas")
        assert st.kernel_calls == pallas_total == 1
        table = st.breakdown_table()
        assert {r["family"] for r in table} == {"tt", "gaussian"}
        assert sum(r["calls"] for r in table) == 4


def test_dispatch_breakdown_struct_routes():
    """TT/CP structured payloads land under their own structure tag."""
    from repro.core.formats import random_tt
    dims = (8, 16, 16)
    op = _op("tt", k=128, dims=dims)
    xtt = random_tt(jax.random.PRNGKey(26), dims, 2)
    with rp.dispatch_stats() as st:
        rp.project(op, xtt, backend="xla")
        assert list(st.breakdown) == [("tt", "tt", "xla", 3)]


def test_force_pallas_nests_and_restores():
    """force_pallas is depth-counted on the context-local stats: nested
    scopes compose and the flag drops only when the LAST scope exits."""
    with rp.dispatch_stats() as stats:
        assert not stats.force_pallas
        with rp.force_pallas():
            with rp.force_pallas():
                assert stats.force_depth == 2 and stats.force_pallas
            assert stats.force_pallas       # still forced after inner exit
        assert not stats.force_pallas


# ---------------------------------------------------------------------------
# SketchConfig family passthrough
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jax.random.normal(jax.random.PRNGKey(7), (24, 24)),
            "b": jax.random.normal(jax.random.PRNGKey(8), (17,))}


@pytest.mark.parametrize("family", FAMILIES)
def test_sketcher_roundtrip_every_family(family):
    cfg = SketchConfig(family=family, k=256, rank=2, bucket_elems=128,
                       dims=(4, 4, 8), backend="xla")
    tree = _tree()
    sk = PytreeSketcher(cfg, tree)
    recon, y = sk.roundtrip(tree, jax.random.PRNGKey(9))
    assert y.shape == (sk.n_buckets, cfg.k)
    assert jax.tree_util.tree_structure(recon) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(recon),
                    jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert bool(jnp.all(jnp.isfinite(a)))
    # roundtrip is a (noisy) estimator, not garbage: positive correlation
    flat_r = jnp.concatenate([a.reshape(-1) for a in
                              jax.tree_util.tree_leaves(recon)])
    flat_t = jnp.concatenate([a.reshape(-1) for a in
                              jax.tree_util.tree_leaves(tree)])
    corr = jnp.vdot(flat_r, flat_t) / (
        jnp.linalg.norm(flat_r) * jnp.linalg.norm(flat_t))
    assert float(corr) > 0.2, float(corr)


def test_sketchconfig_fmt_alias_deprecated():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = SketchConfig(fmt="cp", k=64, bucket_elems=120, dims=DIMS)
    assert cfg.family == "cp" and cfg.fmt == "cp"
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_sketchconfig_rejects_unknown_family():
    with pytest.raises(KeyError, match="unknown RP family"):
        SketchConfig(family="nope", bucket_elems=120, dims=DIMS)


def test_shrinkage_defined_for_all_families():
    for family in FAMILIES:
        cfg = SketchConfig(family=family, k=64, bucket_elems=120, dims=DIMS)
        assert 0.0 < cfg.shrinkage() < 1.0
        assert cfg.operator_params() > 0


# ---------------------------------------------------------------------------
# JL smoke per family (non-hypothesis port of test_property.py machinery)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_jl_pairwise_distance_smoke(family):
    dims, k, m = (4, 4, 4), 256, 6
    op = _op(family, k=k, dims=dims, rank=4, key=jax.random.PRNGKey(11))
    pts = jax.random.normal(jax.random.PRNGKey(12), (m,) + dims)
    proj = jax.vmap(lambda t: rp.project(op, t))(pts)
    ratios = []
    for i in range(m):
        for j in range(i + 1, m):
            du = float(jnp.sum((pts[i] - pts[j]) ** 2))
            dv = float(jnp.sum((proj[i] - proj[j]) ** 2))
            ratios.append(dv / du)
    assert 0.5 < float(np.median(ratios)) < 1.6, np.median(ratios)


def test_spec_for_flat_auto_tensorizes():
    spec = rp.ProjectorSpec.for_flat("tt", 100_000, k=64)
    assert spec.input_size >= 100_000
    op = rp.make_projector(spec, KEY)
    y = rp.project(op, jax.random.normal(KEY, (100_000,)))
    assert y.shape == (64,)
