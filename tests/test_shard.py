"""Sharded sketching engine: shard_map bucket-axis sharding
(`rp.project_sharded` / `rp.sketch_tree_sharded`), the
`compress_collective` cross-pod compressed all-reduce (numeric equivalence
with the vmap simulation + HLO wire-bytes accounting), and `bucket_pspec`
divisibility. Multi-device cases run in subprocesses with fake XLA devices;
the main process keeps its single CPU device."""
import jax
import jax.numpy as jnp
import pytest

from repro import rp


def test_bucket_pspec_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    assert rp.bucket_pspec(mesh, 16)[0] == ("data",)
    assert rp.bucket_pspec(mesh, 16, exclude=("data",))[0] is None


def test_project_sharded_falls_back_without_shardable_axes():
    """A spec that shards over nothing routes through the plain dispatch."""
    mesh = jax.make_mesh((1,), ("data",))
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2),
        jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16, 16))
    y = rp.project_sharded(op, x, mesh=mesh)
    assert y.shape == (4, 128)
    import numpy as np
    np.testing.assert_allclose(np.asarray(y), np.asarray(rp.project(op, x)),
                               rtol=1e-5, atol=1e-5)


def test_bucket_pspec_divisibility(subproc):
    out = subproc("""
import jax
from repro import rp
mesh = jax.make_mesh((2, 4), ("pod", "data"))
assert rp.bucket_pspec(mesh, 8)[0] == ("pod", "data")
assert rp.bucket_pspec(mesh, 2)[0] == ("pod",)          # largest valid prefix
assert rp.bucket_pspec(mesh, 3)[0] is None              # nothing divides
assert rp.bucket_pspec(mesh, 8, exclude=("pod",))[0] == ("data",)
assert rp.bucket_pspec(mesh, 8, axes=("data",))[0] == ("data",)
print("PSPEC_OK")
""", devices=8)
    assert "PSPEC_OK" in out


def test_project_sharded_matches_and_single_dispatch(subproc):
    """Sharded == unsharded projection/adjoint; ONE kernel dispatch per
    trace (the shard_map body traces once, each shard replays it)."""
    out = subproc("""
import jax, numpy as np
from repro import rp
mesh = jax.make_mesh((8,), ("data",))
op = rp.make_projector(
    rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2),
    jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 16, 16))
with rp.dispatch_stats() as st, rp.force_pallas():
    y = rp.project_sharded(op, x, mesh=mesh)
assert st.kernel_calls == 1, st.kernel_calls
y_ref = rp.project(op, x, backend="xla")
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
with rp.dispatch_stats() as st, rp.force_pallas():
    xh = rp.reconstruct_sharded(op, y, mesh=mesh)
assert st.kernel_calls == 1, st.kernel_calls
xh_ref = rp.reconstruct(op, y, backend="xla")
np.testing.assert_allclose(np.asarray(xh), np.asarray(xh_ref),
                           rtol=2e-4, atol=2e-4)
# indivisible bucket count is a typed error, not silent replication
try:
    rp.project_sharded(op, x[:6], mesh=mesh,
                       spec=jax.sharding.PartitionSpec(("data",)))
except ValueError as e:
    assert "divisible" in str(e)
else:
    raise AssertionError("expected ValueError")
print("PROJECT_SHARDED_OK")
""", devices=8)
    assert "PROJECT_SHARDED_OK" in out


def test_sketch_tree_sharded_matches_sketcher(subproc):
    """sketch_tree_sharded == PytreeSketcher.sketch under the same key; one
    kernel dispatch per leaf per trace; ragged leaves fall back unsharded
    but stay bit-identical."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro import rp
from repro.core.sketch import PytreeSketcher, SketchConfig
mesh = jax.make_mesh((8,), ("data",))
cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=8 * 16 * 16,
                   dims=(8, 16, 16))
tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (16, 2048)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (3000,))}  # ragged: 2 buckets
key = jax.random.PRNGKey(42)
with rp.dispatch_stats() as st, rp.force_pallas():
    y = rp.sketch_tree_sharded(cfg, tree, key, mesh=mesh)
assert st.kernel_calls == 2, st.kernel_calls   # exactly one per leaf
sk = PytreeSketcher(cfg, tree)
y_ref = sk.sketch(tree, key)
assert y.shape == y_ref.shape == (sk.n_buckets, cfg.k)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("SKETCH_TREE_OK", sk.n_buckets)
""", devices=8)
    assert "SKETCH_TREE_OK" in out


def test_compress_collective_equals_per_pod(subproc):
    """The shard_map collective == the vmap(spmd_axis_name) simulation to
    fp32 tolerance, both sync modes, on an 8-pod host mesh."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.sketch import SketchConfig
from repro.optim.compress import SketchCompressor

CFG = SketchConfig(family="tt", k=512, rank=4, bucket_elems=4 * 8 * 16,
                   dims=(4, 8, 16))
npod = 8
mesh = jax.make_mesh((npod,), ("pod",))
g = {"w": jax.random.normal(jax.random.PRNGKey(2), (npod, 500)),
     "b": jax.random.normal(jax.random.PRNGKey(3), (npod, 33))}
state = {"residual": jax.tree.map(lambda x: 0.1 * x, g)}
from repro.models import settings as model_settings
for sync in ("sketch-mean", "local-mean"):
    ref = SketchCompressor(CFG, sync=sync).compress_per_pod(g, state, step=0)
    comp = SketchCompressor(CFG, sync=sync, pod_axis="pod")
    # trace with the AMBIENT settings mesh set: the in-body plain sketcher
    # must not emit the legacy global-hint constraint inside the manual
    # region (which would abort XLA), regardless of ambient state
    with model_settings.override(mesh=mesh):
        out = jax.jit(lambda gg, ss, step: comp.compress_collective(
            gg, ss, step=step, mesh=mesh))(g, state, 0)
    for a, b in zip(jax.tree.leaves(ref[:2]), jax.tree.leaves(out[:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)
    # wire_bytes metric reports the ACTIVE formulation
    assert float(out[2]["wire_bytes"]) == (
        out[2]["sketch_bytes"] if sync == "sketch-mean"
        else out[2]["dense_bytes"])
# a leading dim that is a LARGER multiple of npod would shard_map cleanly
# but drop every other pod's row — must be a typed error, not silence
half = jax.make_mesh((npod // 2,), ("pod",),
                     devices=jax.devices()[:npod // 2])
try:
    comp.compress_collective(g, state, step=0, mesh=half)
except ValueError as e:
    assert "one row per pod" in str(e), e
else:
    raise AssertionError("expected ValueError for npod mismatch")
print("COLLECTIVE_EQ_OK")
""", devices=8)
    assert "COLLECTIVE_EQ_OK" in out


def test_compress_collective_wire_bytes(subproc):
    """HLO inspection (the acceptance criterion): under sync='sketch-mean'
    the ONLY cross-pod collective is one all-reduce of n_buckets * k floats;
    'local-mean' moves the dense bytes instead. Metrics are dropped from the
    jitted outputs so their telemetry reductions DCE away."""
    out = subproc("""
import jax, numpy as np
from repro.core.sketch import PytreeSketcher, SketchConfig
from repro.launch.roofline import parse_collectives
from repro.optim.compress import SketchCompressor

CFG = SketchConfig(family="tt", k=512, rank=4, bucket_elems=4 * 8 * 16,
                   dims=(4, 8, 16))
npod = 8
mesh = jax.make_mesh((npod,), ("pod",))
g = {"w": jax.random.normal(jax.random.PRNGKey(2), (npod, 1000)),
     "b": jax.random.normal(jax.random.PRNGKey(3), (npod, 33))}
state = {"residual": jax.tree.map(lambda x: 0.1 * x, g)}
sk = PytreeSketcher(CFG, jax.tree.map(lambda x: x[0], g))
for sync in ("sketch-mean", "local-mean"):
    comp = SketchCompressor(CFG, sync=sync, pod_axis="pod")
    f = jax.jit(lambda gg, ss, step: comp.compress_collective(
        gg, ss, step=step, mesh=mesh)[:2])
    txt = f.lower(g, state, 0).compile().as_text()
    coll = parse_collectives(txt)
    kinds = sorted(coll["per_type"])
    assert kinds == ["all-reduce"], kinds   # pmean is the ONLY collective
    ar = coll["per_type"]["all-reduce"]
    if sync == "sketch-mean":
        assert ar["count"] == 1, ar
        assert ar["bytes"] == sk.n_buckets * CFG.k * 4, (
            ar["bytes"], sk.n_buckets, CFG.k)
    else:
        assert ar["bytes"] == sk.dense_bytes(), (ar, sk.dense_bytes())
    print(sync, "bytes", int(ar["bytes"]))
print("WIRE_BYTES_OK")
""", devices=8)
    assert "WIRE_BYTES_OK" in out


def test_train_step_lowers_collective_on_pod_mesh(subproc):
    """build_train_step wires compress_collective: the compiled step on a
    2x2x2 mesh contains a sketch-sized all-reduce when sync='sketch-mean'
    (the model's own collectives live on other channels; we only assert the
    step lowers and runs — numerics are covered by the convergence test)."""
    out = subproc("""
import functools, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.launch import steps
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.compress import SketchCompressor
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced(get_config("llama3.2-3b"))
model = build_model(cfg)
shape = ShapeSpec("t", 32, 8, "train")
scfg = SketchConfig(family="tt", k=1024, rank=8, bucket_elems=4 * 8 * 16,
                    dims=(4, 8, 16))
comp = SketchCompressor(scfg, sync="sketch-mean")
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
with mesh:
    b = steps.build_train_step(model, mesh, shape, compressor=comp,
        lr_fn=functools.partial(schedule.constant, peak_lr=3e-3))
    compiled = b.fn.lower(*b.args).compile()
    state = steps.init_train_state(model, jax.random.PRNGKey(0),
                                   compressor=comp, npod=2)
    state, m = b.fn(state, jax.tree.map(jnp.asarray, data.batch(0)))
assert float(m["loss"]) > 0 and float(m["wire_bytes"]) > 0
print("TRAIN_COLLECTIVE_OK", int(m["wire_bytes"]))
""", devices=8, timeout=1200)
    assert "TRAIN_COLLECTIVE_OK" in out


def test_sketcher_explicit_mesh_constrains_buckets():
    """PytreeSketcher(mesh=, bucket_spec=) pins the bucket layout without
    consulting the global settings hint; indivisible leaves fall back."""
    from jax.sharding import PartitionSpec as P
    from repro.core.sketch import PytreeSketcher, SketchConfig
    mesh = jax.make_mesh((1,), ("data",))
    cfg = SketchConfig(family="tt", k=64, rank=2, bucket_elems=4 * 8 * 16,
                       dims=(4, 8, 16))
    tree = {"w": jnp.zeros((4, 512))}
    sk = PytreeSketcher(cfg, tree, mesh=mesh, bucket_spec=P(("data",)))
    y = sk.sketch(tree, jax.random.PRNGKey(0))
    assert y.shape == (4, 64)
    rec = sk.unsketch(y, jax.random.PRNGKey(0))
    assert rec["w"].shape == (4, 512)


@pytest.mark.parametrize("bad_model", [3, 0, -1])
def test_make_host_mesh_rejects_bad_model(bad_model):
    from repro.launch.mesh import make_host_mesh
    if bad_model == 3 and len(jax.devices()) % 3 == 0:
        pytest.skip("3 divides the device count here")
    with pytest.raises(ValueError, match="divisor"):
        make_host_mesh(model=bad_model)
