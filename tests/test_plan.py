"""The plan/compile layer: `rp.plan_execution` and its LRU cache.

Pins the PR's behavior bar from four sides:

* cache identity — the same (spec, structure-sig, backend, pipeline)
  resolves to exactly ONE built plan across eager calls, jit retraces, and
  the project / project_many / serve-group paths; rank or dims drift is a
  MISS that re-validates (a new plan, not a stale hit).
* routing parity — `rp.explain` returns the plan the dispatch actually
  runs: same route/ledger under force_pallas, rejected alternatives named
  with reasons, chunk disposition recorded per route.
* layering — `repro.rp.dispatch` no longer imports the kernels packages;
  every kernel decision lives behind `plan_execution`/`execute_plan`.
* `-O` safety — the centralized backend/pipeline/kind validation raises
  typed ValueErrors (not asserts), so misuse still fails under `python -O`.
"""
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import rp
from repro.core import theory

KEY = jax.random.PRNGKey(0)
DIMS = (8, 16, 16)


def _op(family="tt", k=128, dims=DIMS, rank=2, seed=0):
    return rp.make_projector(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
        jax.random.fold_in(KEY, seed))


# ---------------------------------------------------------------------------
# cache identity
# ---------------------------------------------------------------------------

def test_one_build_across_eager_jit_and_retrace():
    op = _op()
    xb = jax.random.normal(jax.random.fold_in(KEY, 1), (8,) + DIMS)
    rp.clear_plan_cache()
    stats = rp.plan_cache_stats()
    rp.project(op, xb)                              # eager: the one build
    assert stats.builds == 1 and stats.hits == 0
    jax.jit(lambda a: rp.project(op, a))(xb)        # first trace
    jax.jit(lambda a: rp.project(op, a))(xb)        # fresh jit: RE-trace
    rp.project(op, xb)
    assert stats.builds == 1, "a jit retrace rebuilt an identical plan"
    assert stats.hits >= 3


def test_one_build_across_project_many_and_serve_group():
    """The serve path (`group_signature` + `plan_execution`, what
    `OperatorCache.plan_for` runs) and the `project_many` bucketed dispatch
    key on the SAME padded signature — one build serves both."""
    op = _op(seed=2)
    xs = [jax.random.normal(jax.random.fold_in(KEY, 10 + i), DIMS)
          for i in range(4)]
    rp.clear_plan_cache()
    stats = rp.plan_cache_stats()
    eplan = rp.plan_execution(op, rp.group_signature(op, xs))
    assert stats.builds == 1
    rp.project_many(op, xs)
    assert stats.builds == 1, (
        "project_many rebuilt the plan the serve group already resolved")
    assert stats.hits >= 1
    # and the many-path really did run THAT plan's shape: pow2-bucketed
    assert eplan.batch == 8     # 4 payloads pad to the batch floor


def test_rank_and_dims_drift_miss_and_revalidate():
    spec = rp.ProjectorSpec(family="tt", k=128, dims=DIMS, rank=2)
    sig = rp.StructureSig(batch=8)
    rp.clear_plan_cache()
    stats = rp.plan_cache_stats()
    p0 = rp.plan_execution(spec, sig)
    assert stats.builds == 1
    p_rank = rp.plan_execution(
        rp.ProjectorSpec(family="tt", k=128, dims=DIMS, rank=4), sig)
    assert stats.builds == 2 and p_rank.plan_id != p0.plan_id
    p_dims = rp.plan_execution(
        rp.ProjectorSpec(family="tt", k=128, dims=(16, 16, 16), rank=2), sig)
    assert stats.builds == 3 and p_dims.plan_id != p0.plan_id
    # the original key still hits — drift added entries, it did not evict
    assert rp.plan_execution(spec, sig) is p0
    assert stats.hits == 1


def test_routing_environment_is_part_of_the_key():
    """force_pallas() flips the auto route, so it must flip the cache key —
    a plan cached under one routing environment never leaks into another."""
    op = _op(k=128, dims=(8, 128, 64), seed=3)     # MXU-aligned
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (8, 128, 64))
    rp.clear_plan_cache()
    plain = rp.explain(op, x)
    with rp.force_pallas():
        forced = rp.explain(op, x)
    assert (plain.route, forced.route) == ("xla", "pallas")
    assert plain.plan_id != forced.plan_id
    assert rp.plan_cache_stats().builds == 2


# ---------------------------------------------------------------------------
# routing parity + ledger
# ---------------------------------------------------------------------------

def test_explain_matches_dispatch_and_names_rejections():
    op = _op(k=128, dims=(8, 128, 64), seed=5)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (8, 128, 64))
    ep = rp.explain(op, x)                          # auto, off-TPU -> xla
    assert ep.route == "xla" and ep.kernel == "einsum"
    assert any(route == "pallas" and "force_pallas" in reason
               for route, reason in ep.rejected)
    before = rp.kernel_call_count()
    with rp.force_pallas():
        ep_k = rp.explain(op, x)
        rp.project(op, x)                           # the dispatch itself
    assert ep_k.route == "pallas" and ep_k.tiles is not None
    assert rp.kernel_call_count() == before + 1     # explain ran nothing
    text = ep.describe()
    assert ep.plan_id in text and "rejected alternatives:" in text


def test_cost_ledger_is_the_theory_module():
    """plan.cost reads `repro.core.theory` — bit-identical, so benchmark
    ratios built from plan costs equal the paper formulas exactly."""
    b = 8
    ep = rp.plan_execution(
        rp.ProjectorSpec(family="tt", k=128, dims=DIMS, rank=2),
        rp.StructureSig(batch=b))
    assert ep.cost.flops == b * theory.flops_project_dense_tt(128, DIMS, 2)
    assert ep.cost.params == theory.params_tt_rp(128, DIMS, 2)
    assert ep.cost.var_factor == theory.variance_factor_tt(len(DIMS), 2)
    es = rp.plan_execution(
        rp.ProjectorSpec(family="tt", k=128, dims=DIMS, rank=2),
        rp.StructureSig(structure="cp", batch=b, in_rank=3))
    assert es.cost.flops == b * theory.flops_project_struct(
        "tt", "cp", 128, DIMS, 2, 3)
    assert es.carry_bytes == theory.mem_carry_struct(128, 2, 3, batch=b)


def test_struct_plan_requires_tn_operator():
    spec = rp.ProjectorSpec(family="gaussian", k=64, dims=DIMS)
    with pytest.raises(ValueError, match="tt/cp operators only"):
        rp.plan_execution(spec, rp.StructureSig(structure="tt", batch=2,
                                                in_rank=2))


def test_reconstruct_chunk_policy_per_route():
    op = _op(seed=7)
    y = jax.random.normal(jax.random.fold_in(KEY, 8), (128,))
    pk = rp.explain(op, y, kind="reconstruct", backend="pallas", chunk=16)
    px = rp.explain(op, y, kind="reconstruct", backend="xla", chunk=16)
    assert (pk.chunk_policy, px.chunk_policy) == ("folded", "honored")
    assert pk.chunk == px.chunk == 16
    # project plans carry no chunk disposition
    assert rp.explain(op, jax.random.normal(KEY, DIMS)).chunk_policy == "n/a"


def test_obs_report_explain_cli():
    from repro.launch.obs_report import explain_plan, main
    text = explain_plan("family=tt,k=128,dims=8x16x16,rank=2,batch=8")
    assert "rejected alternatives:" in text and "route" in text
    assert main(["--explain",
                 "family=cp,k=128,dims=8x16x16,rank=2,batch=8,"
                 "backend=pallas,pipeline=double"]) == 0
    with pytest.raises(ValueError, match="missing required key"):
        explain_plan("family=tt,k=128")
    with pytest.raises(ValueError, match="key=value"):
        explain_plan("family")


# ---------------------------------------------------------------------------
# layering
# ---------------------------------------------------------------------------

def test_dispatch_no_longer_imports_kernels():
    """The PR's layering bar: every kernels.* decision is behind the plan
    layer — `repro.rp.dispatch` contains NO import of the kernels
    packages (`kernels.ops`, `kernels.struct`, or `repro.kernels`)."""
    import repro.rp.dispatch as dispatch
    src = pathlib.Path(dispatch.__file__.replace(".pyc", ".py")).read_text()
    offending = [
        line for line in src.splitlines()
        if line.lstrip().startswith(("import ", "from "))
        and "kernels" in line.split("#")[0]
    ]
    assert not offending, f"dispatch imports kernels again: {offending}"


def test_project_numerics_unchanged_across_routes():
    """The refactor moved the route decision, not the math: both routes
    still agree (the old dispatch acceptance bar, re-pinned on the plan
    path)."""
    op = _op(seed=9)
    xb = jax.random.normal(jax.random.fold_in(KEY, 11), (4,) + DIMS)
    y_x = rp.project(op, xb, backend="xla")
    y_p = rp.project(op, xb, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# -O safety of the centralized validation
# ---------------------------------------------------------------------------

def test_plan_validation_survives_python_O():
    code = """
import jax, jax.numpy as jnp
from repro import rp
for bad, msg in (("cuda", "unknown backend"),):
    try:
        rp.validate_backend(bad)
    except ValueError as e:
        assert msg in str(e), e
    else:
        raise SystemExit("validate_backend not caught under -O")
try:
    rp.validate_pipeline("triple")
except ValueError as e:
    assert "unknown pipeline" in str(e), e
else:
    raise SystemExit("validate_pipeline not caught under -O")
op = rp.make_projector(
    rp.ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=2),
    jax.random.PRNGKey(0))
x = jnp.ones((4, 8))
try:
    rp.project(op, x, pipeline="doble")
except ValueError as e:
    assert "unknown pipeline" in str(e), e
else:
    raise SystemExit("project pipeline typo not caught under -O")
try:
    rp.plan_execution(op, kind="estimate")
except ValueError as e:
    assert "unknown kind" in str(e), e
else:
    raise SystemExit("plan kind typo not caught under -O")
try:
    rp.plan_execution(op, rp.StructureSig(structure="dense"),
                      kind="reconstruct")
except ValueError as e:
    assert "structure='sketch'" in str(e), e
else:
    raise SystemExit("reconstruct sig mismatch not caught under -O")
print("O_SAFE_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "O_SAFE_OK" in res.stdout, (
        res.stdout, res.stderr)
