"""Compressed-domain engine (repro.kernels.struct): carry-sweep Pallas
kernels vs the batched einsum oracles vs the dense path, for all four
(operator, input) structured pairings at orders 2-5, batched containers,
the carry planner, and the rp.project dispatch wiring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rp
from repro.core import (BatchedCPTensor, BatchedTTTensor, CPTensor, TTTensor,
                        random_cp, random_tt, sample_cp_rp, sample_tt_rp)
from repro.kernels import MAX_ORDER, plan_carry_sweep, struct, struct_project
from repro.kernels.struct import ref as sref
from repro.kernels.struct.ops import _in_operands
from repro.kernels.struct.plan import _carry_program, struct_hbm_bytes

KEY = jax.random.PRNGKey(0)
PAIRINGS = [("tt", "tt"), ("tt", "cp"), ("cp", "tt"), ("cp", "cp")]
# one ragged shape per order 2-5 (each order exercises the carry program's
# interior-mode loop differently: zero, one, two, three interior modes)
ORDER_SHAPES = [(16, 24), (16, 32, 24), (8, 6, 4, 10), (4, 6, 4, 8, 4)]


def _make_op(family, dims, k, rank, fold=1):
    sampler = sample_tt_rp if family == "tt" else sample_cp_rp
    return sampler(jax.random.fold_in(KEY, fold), dims, k, rank)


def _make_input(family, dims, rank, fold=2):
    mk = random_tt if family == "tt" else random_cp
    return mk(jax.random.fold_in(KEY, fold), dims, rank)


def _make_batch(family, dims, rank, b, fold=3):
    items = [_make_input(family, dims, rank, fold=fold + i) for i in range(b)]
    stack = BatchedTTTensor.stack if family == "tt" else BatchedCPTensor.stack
    return stack(items)


# ---------------------------------------------------------------------------
# batched containers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("tt", "cp"))
def test_batched_container_stack_unstack_full(family):
    dims, b = (4, 6, 5), 3
    xb = _make_batch(family, dims, 2, b)
    assert xb.batch == b and xb.dims == dims and xb.order == 3
    items = xb.unstack()
    assert len(items) == b
    full = xb.full()
    assert full.shape == (b,) + dims
    for i in range(b):
        np.testing.assert_allclose(np.asarray(full[i]),
                                   np.asarray(items[i].full()),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(xb[i].full()),
                                   np.asarray(items[i].full()),
                                   rtol=1e-6, atol=1e-6)


def test_batched_container_rejects_mismatched_structure():
    with pytest.raises(ValueError, match="mismatched structure"):
        BatchedTTTensor.stack([random_tt(KEY, (4, 6, 5), 2),
                               random_tt(KEY, (4, 6, 5), 3)])
    with pytest.raises(ValueError, match="mismatched structure"):
        BatchedCPTensor.stack([random_cp(KEY, (4, 6), 2),
                               random_cp(KEY, (6, 4), 2)])
    with pytest.raises(ValueError, match="mixing weighted"):
        BatchedCPTensor.stack([
            random_cp(KEY, (4, 6), 2),
            CPTensor(random_cp(KEY, (4, 6), 2).factors, jnp.ones((2,)))])


def test_batched_cp_weights_roundtrip():
    ws = [jnp.arange(1.0, 4.0), jnp.arange(2.0, 5.0)]
    items = [CPTensor(random_cp(jax.random.fold_in(KEY, i), (4, 6, 5), 3).factors,
                      ws[i]) for i in range(2)]
    xb = BatchedCPTensor.stack(items)
    assert xb.weights is not None and xb.weights.shape == (2, 3)
    np.testing.assert_allclose(np.asarray(xb.full()[1]),
                               np.asarray(items[1].full()),
                               rtol=1e-6, atol=1e-6)
    back = xb.unstack()
    np.testing.assert_allclose(np.asarray(back[0].weights), np.asarray(ws[0]))


def test_batched_containers_are_pytrees():
    xb = _make_batch("tt", (4, 6), 2, 2)
    mapped = jax.tree_util.tree_map(lambda a: 2.0 * a, xb)
    assert isinstance(mapped, BatchedTTTensor)
    cb = _make_batch("cp", (4, 6), 2, 2)
    assert isinstance(jax.jit(lambda t: t)(cb), BatchedCPTensor)


# ---------------------------------------------------------------------------
# carry planner
# ---------------------------------------------------------------------------

def test_carry_program_order3_ttxtt():
    """The emitted program at order 3 is exactly the documented carry
    schedule: create the (R, R~) carry at mode 1, one (op, input) update
    pair per interior mode, collapse both bonds at mode N."""
    prog = _carry_program("tt", "tt", 3)
    assert prog == (("c", "kdu,bde->bkue", "g0", "x0"),
                    ("t", "bkue,kudv->bkedv", "c", "g1"),
                    ("c", "bkedv,bedf->bkvf", "t", "x1"),
                    ("t", "bkue,kud->bked", "c", "g2"),
                    ("c", "bked,bed->bk", "t", "x2"))
    # cp x cp is the Hadamard form
    prog_cc = _carry_program("cp", "cp", 3)
    assert prog_cc[1] == ("t", "kdr,bdp->bkrp", "g1", "x1")
    assert prog_cc[-1] == ("c", "bkrp,bkrp->bk", "c", "t")


@pytest.mark.parametrize("op_family,in_family", PAIRINGS)
@pytest.mark.parametrize("order", [2, 5, MAX_ORDER])
def test_carry_program_every_step_is_two_operand(op_family, in_family, order):
    prog = _carry_program(op_family, in_family, order)
    assert prog[-1][0] == "c" and prog[-1][1].endswith("->bk")
    for dst, spec, a, b in prog:
        assert dst in ("c", "t")
        assert spec.count(",") == 1
        for src in (a, b):
            assert src in ("c", "t") or src[0] in "gx"


def test_plan_carry_sweep_tiles_and_grid():
    plan = plan_carry_sweep("tt", "tt", 256, 4, (8, 128, 64), 2, 10)
    assert plan.tk == 128 and plan.grid == (2, 1)
    assert plan.carry_bytes == 4 * 4 * 256 * 2 * 10
    assert plan.vmem_bytes <= 8 * 1024 * 1024
    # huge ranks force the batch tile down before the k tile
    fat = plan_carry_sweep("tt", "tt", 1024, 16, (128, 128, 128), 64, 64)
    assert fat.tb < 8
    assert struct_hbm_bytes(plan) > 0


def test_plan_carry_sweep_rejects_bad_requests():
    with pytest.raises(ValueError, match="2 <= order"):
        plan_carry_sweep("tt", "tt", 64, 1, (64,), 2, 2)
    with pytest.raises(ValueError, match="2 <= order"):
        plan_carry_sweep("tt", "tt", 64, 1, (2,) * (MAX_ORDER + 1), 2, 2)
    with pytest.raises(ValueError, match="operator family"):
        plan_carry_sweep("tucker", "tt", 64, 1, (8, 8), 2, 2)
    with pytest.raises(ValueError, match="input family"):
        plan_carry_sweep("tt", "tucker", 64, 1, (8, 8), 2, 2)


# ---------------------------------------------------------------------------
# kernels vs refs vs dense (the acceptance sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_family,in_family", PAIRINGS)
@pytest.mark.parametrize("dims", ORDER_SHAPES)
@pytest.mark.parametrize("k", [96, 200])
def test_carry_sweep_all_orders_vs_ref_and_dense(op_family, in_family,
                                                 dims, k):
    """Orders 2-5, all four pairings, ragged batch: the Pallas carry sweep
    (interpret mode) == the batched einsum oracle == the dense path on the
    materialized batch (non-power-of-two k covers the k-padding path)."""
    b = 3
    op = _make_op(op_family, dims, k, 2)
    xb = _make_batch(in_family, dims, 3, b)
    got = struct_project(op, xb, interpret=True)
    assert got.shape == (b, k)
    want_ref = struct_project(op, xb, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=2e-4, atol=2e-4)
    want_dense = op.project(xb.full())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_dense),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("op_family,in_family", PAIRINGS)
def test_carry_sweep_unbatched_matches_batch_row(op_family, in_family):
    dims, k = (16, 32, 24), 128
    op = _make_op(op_family, dims, k, 3)
    xb = _make_batch(in_family, dims, 2, 4)
    yb = struct_project(op, xb)
    y1 = struct_project(op, xb[1])
    assert y1.shape == (k,)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yb[1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", [1, 3, 5, 16])
def test_carry_sweep_ragged_batches(b):
    """Ragged batch sizes exercise the batch-tile padding (zero input cores
    are inert and sliced away)."""
    dims, k = (8, 16, 16), 128
    op = _make_op("tt", dims, k, 2)
    xb = _make_batch("tt", dims, 2, b)
    got = struct_project(op, xb)
    assert got.shape == (b, k)
    want = struct_project(op, xb, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_carry_sweep_cp_weights_fold():
    """CP input weights fold into factor 0 (exact by multilinearity) on
    both the kernel and the einsum routes."""
    dims, k = (4, 6, 5), 64
    op = _make_op("tt", dims, k, 2)
    base = random_cp(KEY, dims, 3)
    w = jnp.arange(1.0, 4.0)
    xw = CPTensor(base.factors, w)
    for use_kernel in (True, False):
        got = struct_project(op, xw, use_kernel=use_kernel)
        want = op.project(xw.full())
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_struct_refs_match_operator_methods():
    """The batched oracles agree with the (deprecated but kept) per-format
    operator methods — the pre-subsystem einsum paths."""
    dims, k = (4, 6, 5), 96
    tt_op = _make_op("tt", dims, k, 3)
    cp_op = _make_op("cp", dims, k, 3)
    t = _make_input("tt", dims, 2)
    c = _make_input("cp", dims, 2)
    from repro.kernels import tt_cores_squeezed
    scale = 1.0 / np.sqrt(float(k))
    tb = BatchedTTTensor(tuple(x[None] for x in t.cores))
    cb = BatchedCPTensor(tuple(f[None] for f in c.factors))
    cases = [
        (sref.tt_tt_ref(tt_cores_squeezed(tt_op), _in_operands("tt", tb)),
         tt_op.project_tt(t)),
        (sref.tt_cp_ref(tt_cores_squeezed(tt_op), _in_operands("cp", cb)),
         tt_op.project_cp(c)),
        (sref.cp_tt_ref(cp_op.factors, _in_operands("tt", tb)),
         cp_op.project_tt(t)),
        (sref.cp_cp_ref(cp_op.factors, _in_operands("cp", cb)),
         cp_op.project_cp(c)),
    ]
    for raw, want in cases:
        np.testing.assert_allclose(np.asarray(raw[0] * scale),
                                   np.asarray(want), rtol=2e-4, atol=2e-4)


def test_struct_project_order1_falls_back_dense():
    op = _make_op("tt", (64,), 32, 1)
    x = TTTensor((jax.random.normal(KEY, (1, 64, 1)),))
    got = struct_project(op, x)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(op.project(x.full())),
                               rtol=1e-5, atol=1e-5)


def test_struct_project_typed_errors():
    op = _make_op("tt", (4, 6, 5), 64, 2)
    with pytest.raises(ValueError, match="input dims"):
        struct_project(op, _make_input("tt", (5, 6, 4), 2))
    with pytest.raises(TypeError, match="structured input"):
        struct_project(op, jnp.zeros((4, 6, 5)))
    from repro.core import GaussianRP
    g = GaussianRP(key=KEY, k=8, dim=120)
    with pytest.raises(TypeError, match="TT/CP operator"):
        struct_project(g, _make_input("tt", (4, 6, 5), 2))


# ---------------------------------------------------------------------------
# dispatch wiring (rp.project routes batched structured inputs in ONE launch)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_family,in_family", PAIRINGS)
@pytest.mark.parametrize("dims", [(16, 16), (8, 8, 8, 8), (8, 8, 8, 8, 8)])
def test_dispatch_struct_one_kernel_call_all_orders(op_family, in_family,
                                                    dims):
    """Acceptance: all four pairings at orders 2/4/5 route through the
    carry-sweep kernel under force_pallas, ONE dispatch per batched call
    (no vmap), matching the XLA einsum route."""
    op = rp.make_projector(
        rp.ProjectorSpec(family=op_family, k=128, dims=dims, rank=2), KEY)
    xb = _make_batch(in_family, dims, 2, 3)
    with rp.dispatch_stats() as stats:
        with rp.force_pallas():
            y_kern = rp.project(op, xb, backend="auto")
        assert stats.kernel_calls == 1
        y_xla = rp.project(op, xb, backend="xla")
        assert stats.kernel_calls == 1      # einsum path never dispatches
    assert y_kern.shape == (3, 128)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_xla),
                               rtol=2e-4, atol=2e-4)


def test_dispatch_single_struct_input_kernel_route():
    """Single (unbatched) structured inputs also take the kernel under
    backend='pallas' — including the order-3 TT x TT case the deleted
    tt_dot kernel used to own (no regression)."""
    dims = (16, 32, 24)
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=128, dims=dims, rank=2), KEY)
    x = _make_input("tt", dims, 4)
    with rp.dispatch_stats() as stats:
        y = rp.project(op, x, backend="pallas")
        assert stats.kernel_calls == 1
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(op.project_tt(x)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(op.project(x.full())),
                               rtol=2e-4, atol=2e-4)


def test_dispatch_struct_to_flat_families_densifies():
    dims = (4, 6, 5)
    xb = _make_batch("cp", dims, 2, 3)
    for family in ("gaussian", "sparse"):
        op = rp.make_projector(
            rp.ProjectorSpec(family=family, k=32, dims=dims), KEY)
        y = rp.project(op, xb)
        assert y.shape == (3, 32)
        np.testing.assert_allclose(
            np.asarray(y[1]), np.asarray(rp.project(op, xb[1])),
            rtol=1e-5, atol=1e-5)


def test_dispatch_struct_dim_mismatch_is_typed():
    op = rp.make_projector(
        rp.ProjectorSpec(family="cp", k=32, dims=(4, 6, 5), rank=2), KEY)
    with pytest.raises(rp.FormatMismatchError):
        rp.project(op, _make_batch("tt", (5, 6, 4), 2, 2))


def test_dispatch_out_of_range_struct_order_stays_on_einsum():
    dims = (2,) * (MAX_ORDER + 1)
    op = _make_op("tt", dims, 32, 2)
    x = _make_input("tt", dims, 2)
    with rp.dispatch_stats() as stats:
        y = rp.project(op, x, backend="pallas")
        assert stats.kernel_calls == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(op.project_tt(x)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# sketcher integration (structured leaves, compressed-domain sketching)
# ---------------------------------------------------------------------------

def test_sketcher_structured_leaves_match_dense_path():
    """A tree with TT/CP/batched leaves sketches leaf-for-leaf equal to the
    same tree densified — and unsketch returns dense unbiased estimates of
    the right shapes."""
    from repro.core import PytreeSketcher, SketchConfig
    dims = (4, 4, 8)
    cfg = SketchConfig(family="tt", k=64, rank=2, bucket_elems=128,
                       dims=dims, backend="xla")
    tree = {"w": jax.random.normal(KEY, (16, 8)),
            "t": _make_input("tt", dims, 3),
            "tb": _make_batch("cp", dims, 2, 3)}
    sk = PytreeSketcher(cfg, tree)
    assert sk.n_buckets == 1 + 1 + 3
    y = sk.sketch(tree, jax.random.PRNGKey(1))
    assert y.shape == (5, 64)
    dense_tree = {"w": tree["w"], "t": tree["t"].full(),
                  "tb": tree["tb"].full().reshape(3, -1)}
    y_dense = PytreeSketcher(cfg, dense_tree).sketch(dense_tree,
                                                     jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    recon = sk.unsketch(y, jax.random.PRNGKey(1))
    assert recon["t"].shape == dims
    assert recon["tb"].shape == (3,) + dims
    assert recon["w"].shape == (16, 8)


def test_sketcher_structured_leaf_rejects_wrong_dims():
    from repro.core import PytreeSketcher, SketchConfig
    cfg = SketchConfig(family="tt", k=64, rank=2, bucket_elems=128,
                       dims=(4, 4, 8))
    with pytest.raises(ValueError, match="structured leaf dims"):
        PytreeSketcher(cfg, {"t": _make_input("tt", (8, 4, 4), 2)})


def test_struct_module_exports():
    assert set(struct.__all__) >= {"struct_project", "plan_carry_sweep",
                                   "CarryPlan"}
