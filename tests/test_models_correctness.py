"""Deep correctness: decode==forward equivalence per family, SSD chunked vs
sequential reference, RG-LRU scan vs step, ring-buffer SWA cache, MoE
dispatch vs dense expert computation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.models import layers as nn
from repro.models import mamba2, rglru, transformer
from repro.models.config import MoESpec
from repro.models.moe import moe_ffn


def _decode_all(model, cfg, params, tokens, max_seq, **kw):
    B, S = tokens.shape
    cache = model.init_cache(B, max_seq, dtype=jnp.float32)
    outs = []
    for t in range(S):
        kws = dict(kw)
        if cfg.mrope_sections:
            p = jnp.full((3, B, 1), t, jnp.int32)
            kws["positions3"] = p
        logits, cache = model.decode_step(
            params, cache, tokens[:, t], jnp.full((B,), t, jnp.int32),
            compute_dtype=jnp.float32, **kws)
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # (B, S, V)


def _forward_logits(model, cfg, params, tokens, batch_extra=None):
    kw = dict(batch_extra or {})
    h = model.mod.forward_hidden(cfg, params, tokens,
                                 compute_dtype=jnp.float32, remat="none",
                                 **kw)
    unembed = (params["embed"].T if "unembed" not in params
               else params["unembed"])
    logits = h.astype(jnp.float32) @ unembed.astype(jnp.float32)
    return nn.soft_cap(logits, cfg.final_softcap)


@pytest.mark.parametrize("name", ["llama3.2-3b", "gemma2-9b", "mixtral-8x22b",
                                  "qwen1.5-110b"])
def test_decode_matches_forward_decoder(name):
    """Sequential decode through the KV cache reproduces the full forward
    logits at every position (incl. local/global windows & softcaps)."""
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = _forward_logits(model, cfg, params, tokens)
    dec = _decode_all(model, cfg, params, tokens, max_seq=S)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_mamba2():
    cfg = reduced(ARCHS["mamba2-1.3b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = _forward_logits(model, cfg, params, tokens)
    dec = _decode_all(model, cfg, params, tokens, max_seq=S)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_rglru():
    cfg = reduced(ARCHS["recurrentgemma-2b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = _forward_logits(model, cfg, params, tokens)
    dec = _decode_all(model, cfg, params, tokens, max_seq=S)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_whisper():
    cfg = reduced(ARCHS["whisper-medium"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.encoder_seq, cfg.d_model)) * 0.3
    from repro.models import whisper as wh
    enc = wh.encode(cfg, params, frames, compute_dtype=jnp.float32,
                    remat="none")
    h = wh.decode_hidden(cfg, params, tokens, enc,
                         compute_dtype=jnp.float32, remat="none")
    full = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    cache = wh.build_cross_cache(cfg, params, enc, cache,
                                 compute_dtype=jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache, tokens[:, t],
                                          jnp.full((B,), t, jnp.int32),
                                          compute_dtype=jnp.float32)
        outs.append(logits)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_sequential():
    """The chunked dual form == step-by-step recurrence."""
    B, S, H, P, G, N = 2, 32, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N))
    cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N))
    for chunk in (4, 8, 32):
        y, h_last = mamba2.ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
        # sequential reference
        h = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            yt, h = mamba2.ssd_step(x[:, t], dt[:, t], a, bm[:, t], cm[:, t], h)
            ys.append(yt)
        y_ref = jnp.stack(ys, 1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                                   rtol=1e-4, atol=1e-4)


def test_rglru_scan_matches_step():
    B, S, dr = 2, 16, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, dr))
    r = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 1), (B, S, dr)))
    i = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 2), (B, S, dr)))
    lam = jax.random.normal(jax.random.fold_in(key, 3), (dr,))
    y, h_last = rglru.rglru_scan(x, r, i, lam)
    h = jnp.zeros((B, dr))
    ys = []
    for t in range(S):
        yt, h = rglru.rglru_step(x[:, t], r[:, t], i[:, t], lam, h)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def test_swa_ring_buffer_beyond_window():
    """Decode past the window: the 8-slot ring cache must reproduce the
    full-cache result (mixtral-style SWA)."""
    cfg = reduced(ARCHS["mixtral-8x22b"])  # window 8 in reduced form
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 20  # > window 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = _forward_logits(model, cfg, params, tokens)
    # ring cache: cache_len == window == 8 < S
    assert transformer.cache_len(cfg, 1 << 20) == 8
    dec = _decode_all(model, cfg, params, tokens, max_seq=1 << 20)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_dispatch_matches_dense_reference():
    """With ample capacity, sorted-scatter dispatch == explicit per-token
    top-k expert evaluation."""
    spec = MoESpec(num_experts=4, top_k=2, d_ff_expert=16,
                   capacity_factor=8.0)
    T, D = 24, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, D))
    rw = jax.random.normal(jax.random.fold_in(key, 1), (D, 4))
    wg = jax.random.normal(jax.random.fold_in(key, 2), (4, D, 16)) * 0.2
    wu = jax.random.normal(jax.random.fold_in(key, 3), (4, D, 16)) * 0.2
    wd = jax.random.normal(jax.random.fold_in(key, 4), (4, 16, D)) * 0.2
    out = moe_ffn(x, rw, wg, wu, wd, spec)
    # reference
    logits = x @ rw
    top_vals, top_ids = jax.lax.top_k(logits, 2)
    gates = jax.nn.softmax(top_vals, -1)
    ref = jnp.zeros((T, D))
    for t in range(T):
        acc = jnp.zeros((D,))
        for j in range(2):
            e = int(top_ids[t, j])
            h = jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wu[e])
            acc = acc + gates[t, j] * (h @ wd[e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_groups_consistency():
    """groups=1 vs groups=4 agree when capacity is ample per group."""
    spec = MoESpec(num_experts=4, top_k=2, d_ff_expert=16,
                   capacity_factor=8.0)
    T, D = 32, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, D))
    ws = [jax.random.normal(jax.random.fold_in(key, i), s) * 0.2
          for i, s in enumerate([(D, 4), (4, D, 16), (4, D, 16), (4, 16, D)])]
    o1 = moe_ffn(x, *ws, spec, groups=1)
    o4 = moe_ffn(x, *ws, spec, groups=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens():
    """Over capacity, later tokens drop (outputs zero for the dropped)."""
    spec = MoESpec(num_experts=2, top_k=1, d_ff_expert=8,
                   capacity_factor=0.25)
    T, D = 16, 4
    x = jnp.ones((T, D))
    rw = jnp.zeros((D, 2)).at[:, 0].set(1.0)  # everyone routes to expert 0
    wg = jnp.ones((2, D, 8)) * 0.1
    wu = jnp.ones((2, D, 8)) * 0.1
    wd = jnp.ones((2, 8, D)) * 0.1
    out = moe_ffn(x, rw, wg, wu, wd, spec)
    nonzero_rows = int(jnp.sum(jnp.any(jnp.abs(out) > 1e-8, axis=-1)))
    from repro.models.moe import moe_capacity
    assert nonzero_rows == moe_capacity(spec, T)
