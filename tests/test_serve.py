"""The sketch-serving engine end to end: project_many fan-out, rank-ragged
coalescing, the dynamic batcher's flush policy, the LRU operator cache,
the JL similarity store, the trace replayer's acceptance criteria, and the
SlotServer batched-prefill equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rp
from repro.core.formats import (pad_cp_rank, pad_tt_rank, random_cp,
                                random_tt, stack_ragged_cp, stack_ragged_tt)
from repro.serve import (DynamicBatcher, OperatorCache, ServeConfig,
                         SketchRequest, SketchServer, SketchStore, replay,
                         synth_trace)

SPEC = rp.ProjectorSpec(family="tt", k=128, dims=(4, 8, 8), rank=2)
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# rank-ragged coalescing (core.formats)
# ---------------------------------------------------------------------------

def test_pad_tt_rank_is_exact():
    t = random_tt(KEY, (4, 6, 5), 2)
    padded = pad_tt_rank(t, (1, 5, 4, 1))
    assert padded.ranks == (1, 5, 4, 1)
    np.testing.assert_allclose(np.asarray(padded.full()),
                               np.asarray(t.full()), rtol=1e-6)


def test_pad_tt_rank_rejects_boundary_and_shrink():
    t = random_tt(KEY, (4, 6, 5), 3)
    with pytest.raises(ValueError, match="boundary"):
        pad_tt_rank(t, (2, 4, 4, 1))
    with pytest.raises(ValueError, match="below current"):
        pad_tt_rank(t, (1, 2, 4, 1))
    with pytest.raises(ValueError, match="length"):
        pad_tt_rank(t, (1, 4, 1))


def test_pad_cp_rank_is_exact():
    t = random_cp(KEY, (4, 6, 5), 2)
    padded = pad_cp_rank(t, 6)
    assert padded.rank == 6
    np.testing.assert_allclose(np.asarray(padded.full()),
                               np.asarray(t.full()), rtol=1e-6)
    with pytest.raises(ValueError, match="below current"):
        pad_cp_rank(t, 1)


def test_stack_ragged_tt_preserves_each_item():
    ts = [random_tt(jax.random.fold_in(KEY, i), (4, 6, 5), r)
          for i, r in enumerate((2, 4, 3))]
    xb = stack_ragged_tt(ts)
    assert xb.batch == 3 and xb.ranks == (1, 4, 4, 1)
    full = np.asarray(xb.full())
    for i, t in enumerate(ts):
        np.testing.assert_allclose(full[i], np.asarray(t.full()), rtol=1e-5)
    with pytest.raises(ValueError, match="mismatched"):
        stack_ragged_tt([ts[0], random_tt(KEY, (4, 6, 4), 2)])


def test_stack_ragged_cp_mixes_weighted_and_unweighted():
    a = random_cp(jax.random.fold_in(KEY, 0), (4, 6, 5), 2)
    w = jnp.asarray([2.0, 0.5, 1.5])
    b_t = random_cp(jax.random.fold_in(KEY, 1), (4, 6, 5), 3)
    b_t = type(b_t)(b_t.factors, w)
    xb = stack_ragged_cp([a, b_t])
    assert xb.batch == 2 and xb.rank == 3 and xb.weights is not None
    full = np.asarray(xb.full())
    np.testing.assert_allclose(full[0], np.asarray(a.full()), rtol=1e-5)
    np.testing.assert_allclose(full[1], np.asarray(b_t.full()), rtol=1e-5)


# ---------------------------------------------------------------------------
# project_many (rp fan-out entry)
# ---------------------------------------------------------------------------

def test_project_many_matches_per_item_project():
    op = rp.make_projector(SPEC, KEY)
    inputs = [
        jax.random.normal(jax.random.fold_in(KEY, 1), SPEC.dims),
        jax.random.normal(jax.random.fold_in(KEY, 2), (100,)),  # short flat
        random_tt(jax.random.fold_in(KEY, 3), SPEC.dims, 2),
        random_tt(jax.random.fold_in(KEY, 4), SPEC.dims, 4),    # ragged rank
        random_cp(jax.random.fold_in(KEY, 5), SPEC.dims, 3),
    ]
    ys = rp.project_many(op, inputs)
    assert ys.shape == (5, SPEC.k)
    for i, x in enumerate(inputs):
        ref = rp.project(op, x)
        np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(ref),
                                   rtol=2e-4, atol=1e-5)


def test_project_many_one_dispatch_per_structure_group():
    # MXU-aligned spec (k % 128 == 0, dims % 8 == 0): force_pallas only
    # routes aligned shapes to the kernels, and only kernel dispatches
    # are counted by dispatch_stats.
    spec = rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2)
    op = rp.make_projector(spec, KEY)
    tts = [random_tt(jax.random.fold_in(KEY, i), spec.dims, 2 + i % 2)
           for i in range(4)]
    with rp.dispatch_stats() as st, rp.force_pallas():
        rp.project_many(op, tts)                      # homogeneous lane
    assert st.kernel_calls == 1
    mixed = [tts[0], random_cp(KEY, spec.dims, 2),
             jax.random.normal(KEY, spec.dims)]
    with rp.dispatch_stats() as st, rp.force_pallas():
        rp.project_many(op, mixed)
    assert st.kernel_calls == 3                       # one per structure


def test_project_many_rejects_batched_and_oversize():
    op = rp.make_projector(SPEC, KEY)
    tts = [random_tt(KEY, SPEC.dims, 2)] * 2
    batched = stack_ragged_tt(tts)
    with pytest.raises(rp.FormatMismatchError, match="Batched"):
        rp.project_many(op, [batched])
    too_big = jax.random.normal(KEY, (2, SPEC.input_size))
    with pytest.raises(rp.FormatMismatchError, match="one payload"):
        rp.project_many(op, [too_big])
    assert rp.project_many(op, []).shape == (0, SPEC.k)


# ---------------------------------------------------------------------------
# ServeConfig / store typed errors (and python -O survival)
# ---------------------------------------------------------------------------

def test_serve_config_typed_errors():
    with pytest.raises(ValueError, match="flush window"):
        ServeConfig(flush_us=0.0)
    with pytest.raises(ValueError, match="flush window"):
        ServeConfig(flush_us=-5.0)
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="backend"):
        ServeConfig(backend="tpu")
    with pytest.raises(ValueError, match="cache_capacity"):
        ServeConfig(cache_capacity=0)
    with pytest.raises(ValueError, match="delta"):
        ServeConfig(delta=1.5)
    with pytest.raises(ValueError, match="stats_window"):
        ServeConfig(stats_window=0)


def test_store_typed_errors():
    store = SketchStore(SPEC)
    with pytest.raises(ValueError, match="empty store"):
        store.query(np.zeros(SPEC.k, np.float32), 1)
    store.add(np.zeros((4, SPEC.k), np.float32))
    with pytest.raises(ValueError, match="top_m"):
        store.query(np.zeros(SPEC.k, np.float32), 5)   # > store size
    with pytest.raises(ValueError, match="top_m"):
        store.query(np.zeros(SPEC.k, np.float32), 0)
    with pytest.raises(ValueError, match="mixed-dtype"):
        store.add(np.zeros((1, SPEC.k), np.float64))
    with pytest.raises(ValueError, match="out of range"):
        store.pairwise([0], [7])
    with pytest.raises(ValueError, match="k ="):
        store.query(np.zeros(SPEC.k + 1, np.float32), 1)


def test_serve_errors_survive_python_O():
    """The config/store misuse checks are typed ValueErrors, not asserts —
    they must still fire under python -O."""
    import os
    import subprocess
    import sys
    code = """
import numpy as np
from repro.serve import ServeConfig, SketchStore
from repro.rp import ProjectorSpec
try:
    ServeConfig(flush_us=0.0)
except ValueError as e:
    assert "flush window" in str(e), e
else:
    raise SystemExit("flush_us=0 not caught under -O")
spec = ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=2)
store = SketchStore(spec)
store.add(np.zeros((2, 64), np.float32))
try:
    store.query(np.zeros(64, np.float32), 3)
except ValueError as e:
    assert "top_m" in str(e), e
else:
    raise SystemExit("top_m overflow not caught under -O")
try:
    store.add(np.zeros((1, 64), np.float64))
except ValueError as e:
    assert "mixed-dtype" in str(e), e
else:
    raise SystemExit("mixed-dtype ingest not caught under -O")
print("O_SAFE_OK")
"""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    res = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "O_SAFE_OK" in res.stdout, (
        res.stdout, res.stderr)


# ---------------------------------------------------------------------------
# operator cache
# ---------------------------------------------------------------------------

def test_cache_hit_iff_every_spec_field_and_seed_match():
    cache = OperatorCache(capacity=32)
    base = dict(family="tt", k=128, dims=(4, 8, 8), rank=2)
    cache.get(rp.ProjectorSpec(**base), seed=0)
    assert cache.stats.misses == 1
    cache.get(rp.ProjectorSpec(**base), seed=0)
    assert cache.stats.hits == 1                      # identical spec: hit
    variants = [
        dict(base, family="cp"),
        dict(base, k=256),
        dict(base, dims=(8, 4, 8)),
        dict(base, rank=3),
        dict(base, dtype=jnp.bfloat16),
        dict(base, backend="xla"),
    ]
    for i, kw in enumerate(variants):
        cache.get(rp.ProjectorSpec(**kw), seed=0)
        assert cache.stats.misses == 2 + i, kw        # every field keys
    cache.get(rp.ProjectorSpec(**base), seed=7)       # seed keys too
    assert cache.stats.misses == 2 + len(variants)
    assert cache.stats.hits == 1


def test_cache_lru_eviction_order():
    cache = OperatorCache(capacity=2)
    a = rp.ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=2)
    b = rp.ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=3)
    c = rp.ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=4)
    cache.get(a)
    cache.get(b)
    cache.get(a)                   # refresh a: b is now least-recent
    cache.get(c)                   # evicts b
    assert cache.stats.evictions == 1
    assert (a, 0) in cache and (c, 0) in cache and (b, 0) not in cache
    assert [k[0] for k in cache.keys()] == [a, c]     # LRU-first ordering


def test_cache_regenerates_bitwise_identical_after_eviction():
    cache = OperatorCache(capacity=1)
    a = rp.ProjectorSpec(family="tt", k=64, dims=(4, 8), rank=2)
    b = rp.ProjectorSpec(family="cp", k=64, dims=(4, 8), rank=2)
    x = jax.random.normal(KEY, (4, 8))
    y_first = np.asarray(rp.project(cache.get(a, seed=3), x))
    cache.get(b)                                      # evicts a
    assert (a, 3) not in cache
    y_again = np.asarray(rp.project(cache.get(a, seed=3), x))
    assert cache.stats.evictions >= 1
    np.testing.assert_array_equal(y_first, y_again)   # bitwise


# ---------------------------------------------------------------------------
# dynamic batcher flush policy
# ---------------------------------------------------------------------------

def _req(rid, payload, t, spec=SPEC, seed=0):
    return SketchRequest(rid=rid, payload=payload, spec=spec, seed=seed,
                         t_submit=t)


def test_batcher_max_batch_flush():
    cfg = ServeConfig(max_batch=3, flush_us=1e9)
    bat = DynamicBatcher(cfg)
    x = np.zeros(SPEC.dims, np.float32)
    for i in range(3):
        bat.submit(_req(i, x, t=float(i)))
    assert bat.ready(now=2.0)                         # full, age irrelevant
    key, batch = bat.next_batch(now=2.0)
    assert [r.rid for r in batch] == [0, 1, 2]
    assert bat.pending() == 0 and bat.lanes() == 0


def test_batcher_latency_flush_at_exact_deadline():
    """Regression: readiness must use the SAME float expression as
    next_deadline (t_submit + flush_us); computing `now - t_submit >=
    flush_us` can round the other way and spin the replay loop forever."""
    cfg = ServeConfig(max_batch=64, flush_us=1000.0)
    bat = DynamicBatcher(cfg)
    x = np.zeros(SPEC.dims, np.float32)
    t0 = 3337.3333333333335                           # adversarial float
    bat.submit(_req(0, x, t=t0))
    deadline = bat.next_deadline()
    assert deadline == t0 + 1000.0
    assert not bat.ready(now=deadline - 1e-6)
    assert bat.ready(now=deadline)                    # ready AT deadline
    got = bat.next_batch(now=deadline)
    assert got is not None and len(got[1]) == 1


def test_batcher_lanes_split_by_structure_and_seed():
    cfg = ServeConfig(max_batch=8, flush_us=1000.0)
    bat = DynamicBatcher(cfg)
    bat.submit(_req(0, np.zeros(SPEC.dims, np.float32), t=0.0))
    bat.submit(_req(1, random_tt(KEY, SPEC.dims, 2), t=0.0))
    bat.submit(_req(2, random_cp(KEY, SPEC.dims, 2), t=0.0))
    bat.submit(_req(3, np.zeros(SPEC.dims, np.float32), t=0.0, seed=1))
    assert bat.lanes() == 4
    # FIFO across lanes; fullness breaks the four-way t_submit tie
    bat.submit(_req(4, np.zeros(SPEC.dims, np.float32), t=1.0))
    key, batch = bat.next_batch(now=1e6, force=True)
    assert key.structure == "dense" and len(batch) == 2


def test_batcher_force_flush_and_empty():
    cfg = ServeConfig(max_batch=8, flush_us=1e9)
    bat = DynamicBatcher(cfg)
    assert bat.next_batch(now=0.0, force=True) is None
    assert bat.next_deadline() is None
    bat.submit(_req(0, np.zeros(SPEC.dims, np.float32), t=0.0))
    assert not bat.ready(now=10.0)
    assert bat.next_batch(now=10.0) is None           # not ready, no force
    got = bat.next_batch(now=10.0, force=True)        # drain path
    assert got is not None and len(got[1]) == 1


# ---------------------------------------------------------------------------
# the serving engine (acceptance criteria)
# ---------------------------------------------------------------------------

def test_mixed_trace_one_dispatch_per_tick():
    """>= 64 mixed dense/TT/CP requests complete, with exactly ONE
    rp.project dispatch per batcher tick (rp.dispatch_stats-asserted)."""
    spec = rp.ProjectorSpec(family="tt", k=128, dims=(8, 16, 16), rank=2)
    server = SketchServer(ServeConfig(max_batch=8, flush_us=1000.0),
                          SketchStore(spec))
    trace = synth_trace(64, [(spec, 0)], seed=3)
    with rp.dispatch_stats() as st, rp.force_pallas():
        rep = replay(server, trace)
    assert rep["requests_done"] == 64 and rep["pending"] == 0
    assert st.kernel_calls == rep["ticks"] > 0
    assert all(r.done and r.sketch.shape == (spec.k,) for r in server.done)
    assert all(r.payload is None for r in server.done)  # originals dropped
    assert rep["store_size"] == 64                      # everything ingested
    # flush policy bounds every queueing latency by the flush window
    assert 0.0 < rep["p50_us"] <= rep["p99_us"] <= 1000.0 + 1e-6
    assert 0.0 < rep["occupancy_mean"] <= 1.0


def test_repeated_spec_trace_cache_hit_rate():
    """Acceptance: >= 90% operator-cache hit rate on a repeated-spec
    trace."""
    server = SketchServer(ServeConfig(max_batch=4, flush_us=500.0))
    trace = synth_trace(96, [(SPEC, 0)], mix=(1.0, 0.0, 0.0), seed=5)
    rep = replay(server, trace)
    assert rep["requests_done"] == 96
    assert rep["cache"]["misses"] == 1
    assert rep["cache"]["hit_rate"] >= 0.9


def test_stats_percentiles_are_windowed():
    """Two-phase trace: a long fast prefix then a slow tail. All-time
    percentiles mask the tail entirely — 4 slow requests after 400 fast
    ones sit above the all-time p99 rank, so it still reads 'fast' — while
    the windowed p50/p99 (last `stats_window` requests) must surface it.
    This is the regression the window exists to catch."""
    cfg = ServeConfig(max_batch=1, flush_us=100.0, backend="xla",
                      ingest=False, stats_window=32)
    srv = SketchServer(cfg)
    x = jax.random.normal(KEY, SPEC.dims)
    t = 0.0
    for _ in range(400):                  # healthy prefix: 100us latency
        srv.submit(x, SPEC, now=t)
        srv.tick(t + 100.0)
        t += 200.0
    for _ in range(4):                    # regressed tail: 50ms latency
        srv.submit(x, SPEC, now=t)
        srv.tick(t + 50_000.0)
        t += 60_000.0
    st = srv.stats()
    all_time = np.percentile([r.latency_us for r in srv.done], 99)
    assert all_time <= 150.0              # the masking, demonstrated
    assert st["stats_window"] == 32 and st["stats_window_n"] == 32
    assert st["p99_us"] >= 10_000.0       # the window sees the slow phase
    assert st["p50_us"] <= 150.0          # but is not all-slow either
    assert st["requests_done"] == 404


def test_engine_submit_validates_structured_dims():
    server = SketchServer(ServeConfig())
    bad = random_tt(KEY, (4, 8, 4), 2)                # != SPEC.dims
    with pytest.raises(rp.FormatMismatchError, match="dims"):
        server.submit(bad, SPEC)
    with pytest.raises(ValueError, match="no sketch store"):
        server.query(np.zeros(SPEC.k, np.float32), 1)
    with pytest.raises(ValueError, match="no sketch store"):
        server.pairwise([0], [0])


def test_store_spec_gates_ingestion():
    other = rp.ProjectorSpec(family="tt", k=128, dims=(4, 8, 8), rank=3)
    server = SketchServer(ServeConfig(max_batch=2, flush_us=10.0),
                          SketchStore(SPEC))
    x = np.ones(SPEC.dims, np.float32)
    server.submit(x, SPEC, now=0.0)
    server.submit(x, other, now=0.0)
    server.drain(0.0)
    assert len(server.store) == 1                     # only SPEC ingested
    matching = [r for r in server.done if r.spec == SPEC]
    assert matching[0].store_id == 0
    assert [r.store_id for r in server.done if r.spec == other] == [None]


# ---------------------------------------------------------------------------
# JL similarity retrieval vs exact dense distances
# ---------------------------------------------------------------------------

def test_query_top_m_within_thm1_bound_of_exact_distances():
    """Seeded acceptance: the similarity endpoint's top-m answers agree
    with exact dense distances to within the Thm-1 distortion bound."""
    spec = rp.ProjectorSpec(family="tt", k=512, dims=(4, 8, 8), rank=2)
    op = rp.make_projector(spec, jax.random.PRNGKey(1))
    n, m = 40, 5
    xs = [jax.random.normal(jax.random.fold_in(KEY, i), spec.dims)
          for i in range(n)]
    store = SketchStore(spec, query_tile=7)           # force multi-tile
    ys = rp.project_many(op, xs)
    store.add(np.asarray(ys))
    dense = np.stack([np.asarray(x).ravel() for x in xs])
    res = store.query(np.asarray(ys[:3]), m, delta=0.05)
    assert res.ids.shape == res.dist2.shape == (3, m)
    assert res.eps == pytest.approx(store.eps_bound(0.05))
    sk = np.asarray(store.get(np.arange(n)), np.float64)
    for qi in range(3):
        # endpoint == brute force over the same sketches, in order
        d2_all = ((sk - sk[qi]) ** 2).sum(1)
        np.testing.assert_array_equal(
            np.sort(res.ids[qi]), np.sort(np.argsort(d2_all,
                                                     kind="stable")[:m]))
        np.testing.assert_allclose(res.dist2[qi], np.sort(d2_all)[:m],
                                   rtol=1e-4, atol=1e-3)
        # each reported distance estimates the TRUE dense distance within
        # the Thm-1 relative-error bound (self-match excluded: d2 = 0)
        for j in range(m):
            sid = int(res.ids[qi][j])
            if sid == qi:
                assert res.dist2[qi][j] < 1e-3
                continue
            true_d2 = float(((dense[qi] - dense[sid]) ** 2).sum())
            assert abs(res.dist2[qi][j] - true_d2) <= res.eps * true_d2
            assert res.dist2_lo[qi][j] <= true_d2
            if np.isfinite(res.dist2_hi[qi][j]):
                assert true_d2 <= res.dist2_hi[qi][j]


def test_query_tiling_is_transparent():
    store_a = SketchStore(SPEC, query_tile=3)
    store_b = SketchStore(SPEC, query_tile=4096)
    rng = np.random.default_rng(0)
    sk = rng.standard_normal((33, SPEC.k)).astype(np.float32)
    store_a.add(sk)
    store_b.add(sk)
    q = sk[:2]
    ra, rb = store_a.query(q, 7), store_b.query(q, 7)
    np.testing.assert_array_equal(ra.ids, rb.ids)
    np.testing.assert_allclose(ra.dist2, rb.dist2, rtol=1e-5, atol=1e-4)


def test_pairwise_matches_stored_sketch_distances():
    store = SketchStore(SPEC)
    rng = np.random.default_rng(1)
    sk = rng.standard_normal((8, SPEC.k)).astype(np.float32)
    store.add(sk)
    res = store.pairwise([0, 1, 2], [3, 4, 5], delta=0.1)
    want = ((sk[[0, 1, 2]] - sk[[3, 4, 5]]) ** 2).sum(1)
    np.testing.assert_allclose(res.dist2, want, rtol=1e-5)
    assert res.eps == pytest.approx(store.eps_bound(0.1))
    assert (res.dist2_lo <= res.dist2 + 1e-9).all()


# ---------------------------------------------------------------------------
# SlotServer batched prefill (launch.serve satellite)
# ---------------------------------------------------------------------------

def test_slot_server_batched_prefill_matches_token_loop():
    """The batched whole-prompt prefill must reproduce the old
    token-by-token decode-path prefill: same greedy tokens, same
    positions, for every request. Both paths run the same jitted step
    executable, so this is bitwise identity, not tolerance-based."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import Request, SlotServer
    from repro.models import build_model

    cfg = reduced(get_config("llama3.2-3b"))
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=(6 + i % 3,))
               for i in range(4)]

    def run(feed_loop):
        srv = SlotServer(model, slots=2, max_seq=32, eos=None, max_gen=5)
        if feed_loop:
            def _loop_feed(slot, req):
                logits = None
                for t in req.prompt:
                    tok = srv.cur_tok.copy()
                    tok[slot] = t
                    logits, srv.cache = srv._step(
                        srv.params, srv.cache, jnp.asarray(tok),
                        jnp.asarray(srv.pos))
                    srv.pos[slot] += 1
                srv.cur_tok[slot] = int(jnp.argmax(logits[slot]))
            srv._feed_prompt = _loop_feed
        done = srv.run([Request(i, p) for i, p in enumerate(prompts)])
        return {r.rid: r.generated for r in done}

    fast = run(feed_loop=False)
    ref = run(feed_loop=True)
    assert fast == ref                               # bit-identical greedy
    assert all(len(v) == 5 for v in fast.values())


def test_slot_server_rejects_empty_prompt():
    from repro.configs import get_config, reduced
    from repro.launch.serve import Request, SlotServer
    from repro.models import build_model
    model = build_model(reduced(get_config("llama3.2-3b")))
    srv = SlotServer(model, slots=1, max_seq=16, eos=None, max_gen=2)
    with pytest.raises(ValueError, match="empty prompt"):
        srv.submit(Request(0, np.zeros((0,), np.int64)))


# ---------------------------------------------------------------------------
# cache manifest / prewarm (restart warm-up from a spec registry)
# ---------------------------------------------------------------------------

def test_projector_spec_dict_roundtrip():
    spec = rp.ProjectorSpec(family="cp", k=64, dims=(4, 8), rank=3,
                            dtype=jnp.bfloat16, backend="xla")
    back = rp.ProjectorSpec.from_dict(spec.to_dict())
    assert back == spec and hash(back) == hash(spec)  # cache-key identical
    import json
    json.dumps(spec.to_dict())                        # JSON-able as claimed
    with pytest.raises(ValueError, match="dtype"):
        rp.ProjectorSpec.from_dict({**spec.to_dict(), "dtype": "no_such"})


def test_cache_manifest_prewarm_bitwise_and_stats():
    a = rp.ProjectorSpec(family="tt", k=64, dims=(4, 8, 8), rank=2)
    b = rp.ProjectorSpec(family="cp", k=32, dims=(8, 8), rank=2)
    cache = OperatorCache(capacity=4)
    cache.get(a, seed=3)
    cache.get(b, seed=9)
    man = cache.manifest()
    assert [e["seed"] for e in man] == [3, 9]         # LRU-first order

    warm = OperatorCache(capacity=4)
    assert warm.prewarm(man) == 2
    st = warm.stats
    assert st.prewarmed == 2 and st.misses == 0 and st.hits == 0
    assert "prewarmed" in st.as_dict()
    x = np.arange(4 * 8 * 8, dtype=np.float32)
    y_orig = np.asarray(rp.project(cache.get(a, seed=3), x))
    y_warm = np.asarray(rp.project(warm.get(a, seed=3), x))
    np.testing.assert_array_equal(y_orig, y_warm)     # bitwise regeneration
    assert warm.stats.hits == 1 and warm.stats.misses == 0
    # idempotent: prewarming again samples nothing, only refreshes recency
    assert warm.prewarm(man) == 0 and warm.stats.prewarmed == 2
    # capacity still enforced during prewarm
    tiny = OperatorCache(capacity=1)
    assert tiny.prewarm(man) == 2
    assert tiny.stats.evictions == 1 and len(tiny) == 1


def test_server_save_manifest_prewarm_file(tmp_path):
    srv = SketchServer(ServeConfig())
    x = np.zeros((4 * 8 * 8,), np.float32)
    srv.submit(x, SPEC, seed=1, now=0.0)
    srv.tick(1.0, force=True)
    path = tmp_path / "ops.json"
    assert srv.save_manifest(path) == 1
    assert b"cores" not in path.read_bytes()          # specs only, no weights

    srv2 = SketchServer(ServeConfig())
    assert srv2.prewarm(path) == 1
    srv2.submit(x, SPEC, seed=1, now=0.0)
    srv2.tick(1.0, force=True)
    assert srv2.cache.stats.hits == 1 and srv2.cache.stats.misses == 0
    with pytest.raises(ValueError, match="entries"):
        bad = tmp_path / "bad.json"
        bad.write_text('{"version": 1}')
        srv2.prewarm(bad)
