"""Gradient compression: shrinkage contraction, unbiasedness, EF boundedness,
multi-pod sketched all-reduce (subprocess, 2x2x2 mesh) with convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import PytreeSketcher, SketchConfig
from repro.optim.compress import SketchCompressor, parse_compress_flag


CFG = SketchConfig(family="tt", k=512, rank=4, bucket_elems=4 * 8 * 16,
                   dims=(4, 8, 16))


def test_parse_flag():
    c = parse_compress_flag("tt:k=2048,rank=3,dims=32x16x8")
    assert c.fmt == "tt" and c.k == 2048 and c.rank == 3
    assert c.dims == (32, 16, 8) and c.bucket_elems == 32 * 16 * 8


def test_parse_flag_order_field():
    """order=N tensorizes the default bucket into N balanced pow2 modes
    (the order-N kernel path); with dims= it only cross-checks."""
    c = parse_compress_flag("tt:k=1024,rank=2,order=4")
    assert c.dims == (32, 32, 32, 32) and c.bucket_elems == 128 * 128 * 64
    c5 = parse_compress_flag("cp:order=5")
    assert c5.dims == (16, 16, 16, 16, 16)
    assert c5.bucket_elems == 128 * 128 * 64
    # order=3 over the default bucket reproduces the classic tensorization
    assert parse_compress_flag("tt:order=3").dims == (128, 128, 64)
    # consistent/contradictory explicit dims
    ok = parse_compress_flag("tt:dims=8x8x8x8,order=4")
    assert ok.dims == (8, 8, 8, 8) and ok.bucket_elems == 8 ** 4
    with pytest.raises(ValueError, match="contradicts"):
        parse_compress_flag("tt:dims=32x16x8,order=4")
    # nonsense orders get a clear error, not a ZeroDivision/shift traceback
    for bad in ("order=0", "order=-2"):
        with pytest.raises(ValueError, match="positive integer"):
            parse_compress_flag(f"tt:{bad}")


def test_parse_flag_rejects_unknown_keys():
    """A misspelled key must not silently ship a default config."""
    with pytest.raises(ValueError, match="rnak"):
        parse_compress_flag("tt:rnak=4")
    with pytest.raises(ValueError, match="accepted keys"):
        parse_compress_flag("tt:k=128,dim=4x8x16")
    # a bare key with no '=' is malformed, not a silent no-op
    with pytest.raises(ValueError, match="key=value"):
        parse_compress_flag("tt:k=128,rank")
    with pytest.raises(ValueError, match="key=value"):
        parse_compress_flag("tt:k=128,")
    # good flags still parse
    assert parse_compress_flag("tt:k=128,rank=3").rank == 3


def test_validation_survives_python_O():
    """SketchConfig dims/bucket_elems and make_host_mesh divisibility raise
    typed ValueErrors, not asserts — they must still fire under python -O."""
    import os
    import subprocess
    import sys
    code = """
import jax
from repro.core.sketch import SketchConfig
try:
    SketchConfig(dims=(4, 8), bucket_elems=999)
except ValueError as e:
    assert "bucket_elems" in str(e), e
else:
    raise SystemExit("SketchConfig mismatch not caught under -O")
from repro.launch.mesh import make_host_mesh
for bad in (0, len(jax.devices()) + 1):
    try:
        make_host_mesh(model=bad)
    except ValueError as e:
        assert "divisor" in str(e), e
    else:
        raise SystemExit(f"make_host_mesh(model={bad}) not caught under -O")
print("O_SAFE_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "O_SAFE_OK" in res.stdout, (
        res.stdout, res.stderr)


def test_sketcher_memo_structured_leaves():
    """The memo key flattens with the sketcher's own struct-leaf predicate:
    structured leaves key on the container contract (type/dims/buckets/
    dtype), so a rank change HITS the memo (the sketcher bookkeeping is
    rank-independent) while a dims change MISSES and re-validates."""
    from repro.core import random_tt
    cfg = SketchConfig(family="tt", k=64, rank=2, bucket_elems=4 * 8 * 16,
                       dims=(4, 8, 16))
    comp = SketchCompressor(cfg)
    d = jnp.zeros((100,))
    t_r2 = {"s": random_tt(jax.random.PRNGKey(0), (4, 8, 16), 2), "d": d}
    t_r5 = {"s": random_tt(jax.random.PRNGKey(1), (4, 8, 16), 5), "d": d}
    sk1 = comp._sketcher(t_r2)
    assert comp._sketcher(t_r5) is sk1          # rank change: memo HIT
    assert comp._sketcher(t_r2) is sk1
    # dims change: memo MISS -> PytreeSketcher re-validates and rejects
    t_bad = {"s": random_tt(jax.random.PRNGKey(2), (8, 8, 8), 2), "d": d}
    with pytest.raises(ValueError, match="structured leaf dims"):
        comp._sketcher(t_bad)
    # dense-shape change also misses (fresh sketcher, not the cached one)
    t_dense = {"s": jnp.zeros((4, 8, 16)), "d": d}
    assert comp._sketcher(t_dense) is not sk1


def test_sketch_config_dims_mismatch_is_typed():
    with pytest.raises(ValueError, match="bucket_elems"):
        SketchConfig(family="tt", dims=(4, 8, 16), bucket_elems=12345)


def test_parse_flag_order_shrinks_operator():
    """Same bucket, higher order => strictly smaller TT/CP operator (core
    params scale with the SUM of the modes) — the memory axis the order-N
    kernel layer unlocks."""
    params = [parse_compress_flag(f"tt:k=1024,rank=2,order={n}"
                                  ).operator_params() for n in (2, 3, 4, 5)]
    assert all(b < a for a, b in zip(params, params[1:])), params


def test_shrunk_roundtrip_is_contractive():
    """||x - alpha*A^T A x|| < ||x|| on average (the EF requirement); the
    UNSHRUNK roundtrip is an expansion at this D/k — the paper's Thm-1
    variance factor sets alpha."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (500,))}
    sk = PytreeSketcher(CFG, tree)
    alpha = CFG.shrinkage()
    norms_shrunk, norms_raw = [], []
    x = tree["w"]
    for i in range(30):
        key = jax.random.PRNGKey(100 + i)
        rec = sk.unsketch(sk.sketch(tree, key), key)["w"]
        norms_raw.append(float(jnp.linalg.norm(x - rec)))
        norms_shrunk.append(float(jnp.linalg.norm(x - alpha * rec)))
    nx = float(jnp.linalg.norm(x))
    assert np.mean(norms_shrunk) < nx, (np.mean(norms_shrunk), nx)
    assert np.mean(norms_raw) > nx  # why shrinkage is necessary


def test_single_worker_ef_residual_bounded():
    """With a constant gradient the EF recursion e' = (I - alpha*A^T A)(g+e)
    plateaus at ~(1/alpha - 1)*||g|| — bounded at the theory-predicted level,
    not divergent."""
    comp = SketchCompressor(CFG)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (500,))}
    state = comp.init_state(g)
    norms = []
    for step in range(40):
        ghat, state, met = comp.compress(g, state, step=step)
        norms.append(float(met["residual_norm"]))
    gn = float(jnp.linalg.norm(g["w"]))
    plateau = (1.0 / CFG.shrinkage() - 1.0) * gn
    assert norms[-1] < 1.5 * plateau, (norms[-1], plateau)
    # stabilized: the last step is no longer growing materially
    assert norms[-1] <= max(norms) * 1.05, (norms[-1], max(norms))


def test_ef_transmits_full_signal_over_time():
    """With a CONSTANT gradient, cumulative reconstructions converge to it:
    sum of EF-compressed updates -> T*g (information is not lost)."""
    comp = SketchCompressor(CFG)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (500,))}
    state = comp.init_state(g)
    acc = jnp.zeros((500,))
    T = 60
    for step in range(T):
        ghat, state, _ = comp.compress(g, state, step=step)
        acc = acc + ghat["w"]
    rel = float(jnp.linalg.norm(acc / T - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.35, rel


def test_per_pod_sync_modes_agree():
    """'local-mean' (one adjoint pass) == 'sketch-mean' (sketch-sized comm)
    by linearity of the adjoint; both yield identical synced grads/residuals."""
    npod = 3
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (npod, 500)),
         "b": jax.random.normal(jax.random.PRNGKey(3), (npod, 33))}
    outs = {}
    for sync in ("local-mean", "sketch-mean"):
        comp = SketchCompressor(CFG, sync=sync)
        state = comp.init_state(jax.tree.map(lambda x: x[0], g))
        state = {"residual": jax.tree.map(
            lambda r: jnp.broadcast_to(r, (npod,) + r.shape), state["residual"])}
        outs[sync] = comp.compress_per_pod(g, state, step=0)
    for a, b in zip(jax.tree.leaves(outs["local-mean"][:2]),
                    jax.tree.leaves(outs["sketch-mean"][:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError, match="unknown sync mode"):
        SketchCompressor(CFG, sync="nope").compress_per_pod(
            g, {"residual": jax.tree.map(jnp.zeros_like, g)}, step=0)


def test_multi_pod_compressed_training(subproc):
    """2x2x2 mesh: per-pod grads via vmap(spmd_axis_name), sketch-only
    cross-pod sync, loss must decrease."""
    out = subproc("""
import functools, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.launch import steps
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.compress import SketchCompressor
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced(get_config("llama3.2-3b"))
model = build_model(cfg)
shape = ShapeSpec("t", 32, 8, "train")
scfg = SketchConfig(family="tt", k=1024, rank=8, bucket_elems=4*8*16, dims=(4,8,16))
comp = SketchCompressor(scfg)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
with mesh:
    b = steps.build_train_step(model, mesh, shape, compressor=comp,
        lr_fn=functools.partial(schedule.constant, peak_lr=3e-3))
    state = steps.init_train_state(model, jax.random.PRNGKey(0),
                                   compressor=comp, npod=2)
    losses = []
    for i in range(50):
        state, m = b.fn(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
print("COMPRESS_OK first=%.3f last=%.3f" % (losses[0], losses[-1]))
""", devices=8, timeout=1200)
    assert "COMPRESS_OK" in out


# ---------------------------------------------------------------------------
# int8 sketches on the wire (compress_collective wire='int8')
# ---------------------------------------------------------------------------

def _collective_setup():
    key = jax.random.PRNGKey(29)
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.fold_in(key, 0), (1, 4096)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (1, 100))}
    state = {"residual": jax.tree.map(jnp.zeros_like, g)}
    cfg = SketchConfig(family="tt", k=128, rank=2, bucket_elems=4 * 8 * 16,
                       dims=(4, 8, 16))
    return cfg, mesh, g, state


@pytest.mark.parametrize("sync", ["sketch-mean", "local-mean"])
def test_int8_wire_matches_fp32(sync):
    """wire='int8' stays within the quantization variance budget of the
    fp32 reference on BOTH sync modes, and the residual state stays equally
    close — whatever the quantizer rounds off is bounded by the shared
    per-row scale (absmax/qmax), a budget far inside Thm-1's own sketch
    variance at these shapes."""
    cfg, mesh, g, state = _collective_setup()
    out = {}
    for wire in ("fp32", "int8"):
        comp = SketchCompressor(cfg, sync=sync, pod_axis="pod", wire=wire)
        g_hat, new_state, _ = comp.compress_collective(g, state, step=0,
                                                       mesh=mesh)
        out[wire] = (g_hat, new_state["residual"])
    for a, b in zip(jax.tree.leaves(out["fp32"]), jax.tree.leaves(out["int8"])):
        a, b = np.asarray(a), np.asarray(b)
        rel = np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-12)
        assert rel < 0.12, f"int8-vs-fp32 rel err {rel:.3f} past budget"


def test_int8_wire_deterministic():
    """Shared pmax scale + half-to-even round + integer psum: the
    dequantized sketch is bitwise reproducible across fresh traces."""
    cfg, mesh, g, state = _collective_setup()
    outs = []
    for _ in range(2):  # two separately-constructed compressors + traces
        comp = SketchCompressor(cfg, sync="sketch-mean", pod_axis="pod",
                                wire="int8")

        def once(gg, ss, comp=comp):
            return comp.compress_collective(gg, ss, step=3, mesh=mesh)[0]

        outs.append(jax.jit(once)(g, state))
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), *outs)


@pytest.mark.parametrize("sync", ["sketch-mean", "local-mean"])
def test_int8_wire_bytes_hlo(sync):
    """The analytic `wire_bytes` ledger IS the measured HLO all-reduce
    traffic, and int8 cuts it > 3x vs fp32 (int8 payload + fp32 scales;
    exactly 4x only as n_buckets*k grows past the scale overhead)."""
    from repro.launch.roofline import parse_collectives
    cfg, mesh, g, state = _collective_setup()
    sk = PytreeSketcher(cfg, jax.tree.map(lambda x: x[0], g))
    hlo = {}
    for wire in ("fp32", "int8"):
        comp = SketchCompressor(cfg, sync=sync, pod_axis="pod", wire=wire)

        def run(gg, ss, comp=comp):
            return comp.compress_collective(gg, ss, step=0, mesh=mesh)[:2]

        txt = jax.jit(run).lower(g, state).compile().as_text()
        ar = parse_collectives(txt)["per_type"].get(
            "all-reduce", {"bytes": 0.0})
        hlo[wire] = int(ar["bytes"])
        assert hlo[wire] == comp.wire_bytes(sk), (wire, hlo, comp.wire_bytes(sk))
    assert hlo["fp32"] / hlo["int8"] > 3.0


def test_wire_validation():
    cfg, mesh, g, state = _collective_setup()
    with pytest.raises(ValueError, match="unknown wire"):
        SketchCompressor(cfg, wire="fp16")
    with pytest.raises(ValueError, match="compress_collective feature"):
        SketchCompressor(cfg, wire="int8").compress_per_pod(g, state, step=0)
    from repro import rp
    with pytest.raises(ValueError, match="at most 127 pods"):
        rp.quantize_for_psum(jnp.ones((2, 4)), "pod", 128)
