"""Double-buffered DMA pipelining vs the serial schedule.

The pipelined kernels (`sweep_project_pipelined`, dense mode sweep;
`carry_sweep_project_pipelined`, structured carry sweep) prefetch the next
grid step's input/core tiles into a second VMEM slot while the current tile
contracts — SAME tiles, SAME order, SAME math, different overlap. These
tests pin (a) numerical equivalence to the serial schedule across orders
2-5 and both families (including the no-overlap na==1 / nb==1 edges where
the pipeline degenerates to serial), (b) the planner's two-slot accounting
and its typed errors, and (c) the `pipeline=` plumbing through
`rp.project`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rp
from repro.core import BatchedCPTensor, BatchedTTTensor, random_cp, random_tt
from repro.kernels import (PIPELINES, cp_project, plan_carry_sweep,
                           plan_contraction, struct_hbm_bytes, sweep_hbm_bytes,
                           tt_project)
from repro.kernels.struct.plan import CarryPlan

ORDER_SHAPES = [(16, 24), (16, 32, 24), (8, 6, 4, 10), (4, 6, 4, 8, 4)]


# ---------------------------------------------------------------------------
# dense sweep: pipelined == serial
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", ORDER_SHAPES)
@pytest.mark.parametrize("family", ["tt", "cp"])
def test_sweep_pipelined_matches_serial(dims, family):
    k, rank, b = 96, 2, 4
    op = rp.make_projector(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(0))
    xb = jax.random.normal(jax.random.PRNGKey(1), (b,) + dims)
    kern = tt_project if family == "tt" else cp_project
    got = kern(op, xb, pipeline="double")
    want = kern(op, xb, pipeline="serial")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("family", ["tt", "cp"])
def test_sweep_pipelined_na1_edge(family):
    """d1 <= ba: a single grid step — nothing to prefetch, the pipeline
    must still produce the serial result (its steady state never runs)."""
    dims, k, rank = (8, 16, 16), 128, 2
    op = rp.make_projector(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(2))
    plan = plan_contraction(family, "project", k, 2, dims, rank,
                            pipeline="double")
    assert -(-dims[0] // plan.ba) == 1
    xb = jax.random.normal(jax.random.PRNGKey(3), (2,) + dims)
    kern = tt_project if family == "tt" else cp_project
    np.testing.assert_allclose(
        np.asarray(kern(op, xb, pipeline="double")),
        np.asarray(kern(op, xb, pipeline="serial")), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# carry sweep: pipelined == serial, all four structured pairings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op_family", ["tt", "cp"])
@pytest.mark.parametrize("in_family", ["tt", "cp"])
def test_carry_pipelined_matches_serial(op_family, in_family):
    dims, k, r_op, r_in, b = (8, 6, 10), 96, 2, 3, 16
    op = rp.make_projector(
        rp.ProjectorSpec(family=op_family, k=k, dims=dims, rank=r_op),
        jax.random.PRNGKey(4))
    mk = random_tt if in_family == "tt" else random_cp
    items = [mk(jax.random.PRNGKey(10 + i), dims, r_in) for i in range(b)]
    stack = (BatchedTTTensor.stack if in_family == "tt"
             else BatchedCPTensor.stack)
    xb = stack(items)
    got = rp.project(op, xb, backend="pallas", pipeline="double")
    want = rp.project(op, xb, backend="pallas", pipeline="serial")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# planner: two-slot accounting + typed errors
# ---------------------------------------------------------------------------

def test_plan_double_buffer_accounting():
    """The double-buffered plan must account the second slot: its VMEM
    footprint strictly exceeds the serial plan's for the same problem, and
    stays within the budget it was given."""
    from repro.kernels.ops import VMEM_BUDGET_BYTES
    for family in ("tt", "cp"):
        serial = plan_contraction(family, "project", 128, 8, (256, 16, 16), 2)
        double = plan_contraction(family, "project", 128, 8, (256, 16, 16), 2,
                                  pipeline="double")
        assert double.pipeline == "double" and serial.pipeline == "serial"
        assert double.vmem_bytes > serial.vmem_bytes
        assert double.vmem_bytes <= VMEM_BUDGET_BYTES
        # pipelining overlaps transfers, it does not change traffic
        assert sweep_hbm_bytes(double) == sweep_hbm_bytes(serial)


def test_plan_carry_double_buffer_accounting():
    serial = plan_carry_sweep("tt", "tt", 128, 64, (16, 16, 16), 2, 4)
    double = plan_carry_sweep("tt", "tt", 128, 64, (16, 16, 16), 2, 4,
                              pipeline="double")
    assert isinstance(double, CarryPlan) and double.pipeline == "double"
    assert double.vmem_bytes > serial.vmem_bytes
    assert struct_hbm_bytes(double) == struct_hbm_bytes(serial)
    # pipelined grid drops the batch axis (manually swept inside the body)
    assert len(double.grid) == len(serial.grid) - 1


def test_unknown_pipeline_raises():
    with pytest.raises(ValueError, match="unknown pipeline 'triple'"):
        plan_contraction("tt", "project", 64, 2, (8, 8), 2,
                         pipeline="triple")
    with pytest.raises(ValueError, match="unknown pipeline 'triple'"):
        plan_carry_sweep("tt", "tt", 64, 2, (8, 8), 2, 2, pipeline="triple")
    assert PIPELINES == ("serial", "double")


def test_reconstruct_double_raises():
    with pytest.raises(ValueError, match="kind='project' only"):
        plan_contraction("tt", "reconstruct", 64, 2, (8, 8), 2,
                         pipeline="double")


# ---------------------------------------------------------------------------
# rp.project plumbing
# ---------------------------------------------------------------------------

def test_project_pipeline_kwarg_dense_and_validation():
    dims = (8, 16, 16)
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=64, dims=dims, rank=2),
        jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (4,) + dims)
    got = rp.project(op, x, backend="pallas", pipeline="double")
    want = rp.project(op, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # a typo'd pipeline must never silently run serial — even on routes
    # that ignore the kwarg (einsum backend)
    with pytest.raises(ValueError, match="unknown pipeline 'doble'"):
        rp.project(op, x, backend="xla", pipeline="doble")


def test_project_pipeline_ignored_on_einsum_route():
    """backend='xla' has no manual DMA schedule; pipeline='double' must
    still validate and return the same sketch."""
    dims = (8, 16, 16)
    op = rp.make_projector(
        rp.ProjectorSpec(family="cp", k=64, dims=dims, rank=2),
        jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), dims)
    np.testing.assert_allclose(
        np.asarray(rp.project(op, x, backend="xla", pipeline="double")),
        np.asarray(rp.project(op, x, backend="xla")), rtol=1e-6, atol=1e-6)
