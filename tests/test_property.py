"""Hypothesis property tests on the sketching invariants.

Skipped wholesale when `hypothesis` is absent (it is not baked into the CI
container); tests/test_rp_api.py carries non-hypothesis JL smoke coverage so
the invariants stay exercised either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (BatchedCPTensor, BatchedTTTensor, auto_dims,
                        pad_to_tensorizable, random_cp, random_tt,
                        sample_cp_rp, sample_tt_rp)

dims_strategy = st.lists(st.integers(2, 6), min_size=1, max_size=4)


@settings(max_examples=20, deadline=None)
@given(dims=dims_strategy, rank=st.integers(1, 4),
       k=st.sampled_from([8, 16, 33]), seed=st.integers(0, 2 ** 20),
       fmt=st.sampled_from(["tt", "cp"]))
def test_linearity(dims, rank, k, seed, fmt):
    """f(a*x + b*y) == a*f(x) + b*f(y) — the maps are linear operators."""
    dims = tuple(dims)
    sampler = sample_tt_rp if fmt == "tt" else sample_cp_rp
    op = sampler(jax.random.PRNGKey(seed), dims, k, rank)
    kx, ky = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(kx, dims)
    y = jax.random.normal(ky, dims)
    lhs = op.project(2.5 * x - 0.75 * y)
    rhs = 2.5 * op.project(x) - 0.75 * op.project(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(dims=dims_strategy, rank=st.integers(1, 3), seed=st.integers(0, 999))
def test_reconstruct_unbiased_over_operators(dims, rank, seed):
    """mean over operators of A^T A x approaches x (unbiased adjoint).
    Tolerance scales with the Thm-1 roundtrip std / sqrt(n_ops)."""
    from repro.core import theory
    dims = tuple(dims)
    n_ops, k = 200, 32
    x = jax.random.normal(jax.random.PRNGKey(seed), dims)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n_ops)

    def one(kk):
        op = sample_tt_rp(kk, dims, k, rank)
        return op.reconstruct(op.project(x))

    recs = jax.lax.map(one, keys)
    err = jnp.linalg.norm(recs.mean(0) - x) / jnp.linalg.norm(x)
    D = 1
    for d in dims:
        D *= d
    c = theory.variance_factor_tt(len(dims), rank)
    tol = 4.0 * (c * D / k / n_ops) ** 0.5 + 0.05
    assert float(err) < tol, (float(err), tol)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 10 ** 7))
def test_pad_to_tensorizable_invariants(n):
    vec = jnp.zeros((n,))
    padded, dims, orig = pad_to_tensorizable(vec)
    assert orig == n
    prod = 1
    for d in dims:
        prod *= d
    assert prod == padded.size >= n
    assert padded.size - n < 128


@settings(max_examples=20, deadline=None)
@given(dims=st.lists(st.integers(2, 6), min_size=3, max_size=3),
       rank=st.integers(1, 3), b=st.integers(1, 7),
       k=st.sampled_from([16, 33, 64]), seed=st.integers(0, 999),
       fmt=st.sampled_from(["tt", "cp"]), backend=st.sampled_from(["xla",
                                                                   "pallas"]))
def test_batched_dispatch_matches_stacked_unbatched(dims, rank, b, k, seed,
                                                    fmt, backend):
    """rp.project / rp.reconstruct on a (B, ...) batch equal the stack of
    per-item calls, on BOTH backends (pallas = interpret-mode kernels)."""
    from repro import rp
    dims = tuple(dims)
    op = rp.make_projector(
        rp.ProjectorSpec(family=fmt, k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(seed))
    xb = jax.random.normal(jax.random.PRNGKey(seed + 1), (b,) + dims)
    yb = rp.project(op, xb, backend=backend)
    want_y = jnp.stack([rp.project(op, xb[i], backend="xla")
                        for i in range(b)])
    np.testing.assert_allclose(np.asarray(yb), np.asarray(want_y),
                               rtol=2e-4, atol=2e-4)
    rb = rp.reconstruct(op, yb, backend=backend)
    want_r = jnp.stack([rp.reconstruct(op, want_y[i], backend="xla")
                        for i in range(b)])
    np.testing.assert_allclose(np.asarray(rb), np.asarray(want_r),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(dims=st.lists(st.integers(2, 6), min_size=2, max_size=5),
       rank=st.integers(1, 3), b=st.integers(1, 7),
       k=st.sampled_from([16, 33]), seed=st.integers(0, 999),
       fmt=st.sampled_from(["tt", "cp"]))
def test_order_n_routing_pallas_matches_einsum(dims, rank, b, k, seed, fmt):
    """Orders 2-5 x {tt, cp} x ragged batch sizes: the mode-sweep Pallas
    route (interpret mode) equals the einsum reference, and
    kernel_call_count increments exactly ONCE per batched dispatch (counted
    on an isolated context-local DispatchStats)."""
    from repro import rp
    dims = tuple(dims)
    op = rp.make_projector(
        rp.ProjectorSpec(family=fmt, k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(seed))
    xb = jax.random.normal(jax.random.PRNGKey(seed + 1), (b,) + dims)
    with rp.dispatch_stats() as stats:
        yb = rp.project(op, xb, backend="pallas")
        assert stats.kernel_calls == 1
        rb = rp.reconstruct(op, yb, backend="pallas")
        assert stats.kernel_calls == 2
    np.testing.assert_allclose(
        np.asarray(yb), np.asarray(rp.project(op, xb, backend="xla")),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(rb), np.asarray(rp.reconstruct(op, yb, backend="xla")),
        rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(dims=st.lists(st.integers(2, 6), min_size=2, max_size=5),
       r_op=st.integers(1, 3), r_in=st.integers(1, 3), b=st.integers(1, 7),
       k=st.sampled_from([16, 33]), seed=st.integers(0, 999),
       op_family=st.sampled_from(["tt", "cp"]),
       in_family=st.sampled_from(["tt", "cp"]))
def test_struct_pairings_pallas_einsum_dense_agree(dims, r_op, r_in, b, k,
                                                   seed, op_family,
                                                   in_family):
    """Orders 2-5 x all four structured pairings x ragged batches: the
    carry-sweep Pallas route (interpret mode) == the batched einsum refs ==
    the dense-path sketch of the materialized batch, and a batched
    structured project is exactly ONE kernel dispatch (isolated
    context-local DispatchStats)."""
    from repro import rp
    dims = tuple(dims)
    op = rp.make_projector(
        rp.ProjectorSpec(family=op_family, k=k, dims=dims, rank=r_op),
        jax.random.PRNGKey(seed))
    mk = random_tt if in_family == "tt" else random_cp
    items = [mk(jax.random.PRNGKey(seed + 1 + i), dims, r_in)
             for i in range(b)]
    stack = (BatchedTTTensor.stack if in_family == "tt"
             else BatchedCPTensor.stack)
    xb = stack(items)
    with rp.dispatch_stats() as stats:
        y_pal = rp.project(op, xb, backend="pallas")
        assert stats.kernel_calls == 1
        y_xla = rp.project(op, xb, backend="xla")
        assert stats.kernel_calls == 1
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla),
                               rtol=2e-4, atol=2e-4)
    y_dense = rp.project(op, xb.full(), backend="xla")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), fmt=st.sampled_from(["tt", "cp"]))
def test_jl_pairwise_distances(seed, fmt):
    """JL property: pairwise distances preserved in aggregate for modest k."""
    from repro.core import sample_cp_rp, sample_tt_rp
    dims, k, m = (4, 4, 4), 256, 6
    sampler = sample_tt_rp if fmt == "tt" else sample_cp_rp
    op = sampler(jax.random.PRNGKey(seed), dims, k, 4)
    pts = jax.random.normal(jax.random.PRNGKey(seed + 1), (m,) + dims)
    proj = jax.vmap(op.project)(pts)
    ratios = []
    for i in range(m):
        for j in range(i + 1, m):
            du = float(jnp.sum((pts[i] - pts[j]) ** 2))
            dv = float(jnp.sum((proj[i] - proj[j]) ** 2))
            ratios.append(dv / du)
    # median ratio near 1 (individual pairs can deviate)
    assert 0.5 < float(np.median(ratios)) < 1.6, np.median(ratios)
