"""Fused unsketch + error feedback + AdamW kernel vs the unfused chain.

`kernels.fused_update_buckets` runs ONE Pallas launch per leaf whose
epilogue applies EF and the AdamW moment/param math to every reconstructed
tile while it is still in VMEM; `optim.adamw.update_sketched` is its
optimizer-level entry. These tests pin (a) numerical equivalence to the
reconstruct -> EF -> AdamW reference across orders 2-5 and both families,
(b) the fixed-point planner's budget accounting and the analytic HBM
ledger (fused < unfused), (c) every typed misuse error, and (d) the
update_sketched == compress + update chain identity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rp
from repro.kernels import (fused_hbm_bytes, fused_update_buckets,
                           plan_fused_update, unfused_hbm_bytes)
from repro.kernels.ops import VMEM_BUDGET_BYTES

ORDER_SHAPES = [(16, 24), (16, 32, 24), (8, 6, 4, 10), (4, 6, 4, 8, 4)]
HP = dict(alpha=0.9, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)


def _reference(op, y, p, w, m, v, lr, c1, c2):
    g = HP["alpha"] * rp.reconstruct(op, y, backend="pallas")
    resid = p - g
    m32 = HP["b1"] * m + (1 - HP["b1"]) * g
    v32 = HP["b2"] * v + (1 - HP["b2"]) * g * g
    step = (m32 / c1) / (jnp.sqrt(v32 / c2) + HP["eps"])
    return resid, w - lr * (step + HP["weight_decay"] * w), m32, v32


@pytest.mark.parametrize("dims", ORDER_SHAPES)
@pytest.mark.parametrize("family", ["tt", "cp"])
def test_fused_matches_reference(dims, family):
    k, rank, nb = 96, 2, 3
    op = rp.make_projector(
        rp.ProjectorSpec(family=family, k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(jax.random.fold_in(key, 0), (nb, k))
    p, w, m, v = (jax.random.normal(jax.random.fold_in(key, i + 1),
                                    (nb,) + dims) for i in range(4))
    v = jnp.abs(v)  # second moment is nonnegative in real trajectories
    lr, c1, c2 = jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.05)
    got = fused_update_buckets(op, y, p, w, m, v, lr, c1, c2, **HP)
    want = _reference(op, y, p, w, m, v, lr, c1, c2)
    for g, r in zip(got, want):
        assert g.shape == (nb,) + dims and g.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=5e-5, atol=5e-5)


def test_plan_fused_update_budget():
    """The fixed point must charge the eight resident dense blocks to the
    sweep's budget: the fused plan fits, and never claims bigger tiles
    than the plain reconstruct plan it derives from."""
    from repro.kernels import plan_contraction
    for family in ("tt", "cp"):
        plan = plan_fused_update(family, 128, 8, (64, 16, 16), 2)
        assert plan.kind == "reconstruct" and plan.pipeline == "serial"
        base = plan_contraction(family, "reconstruct", 128, 8, (64, 16, 16), 2)
        assert plan.tb <= base.tb and plan.ba <= base.ba
        extra = 8 * 4 * plan.tb * plan.ba * 16 * 16
        assert plan.vmem_bytes + extra <= VMEM_BUDGET_BYTES


def test_fused_hbm_ledger():
    """Fused traffic strictly beats unfused (the dense write is replaced
    by 8 optimizer passes vs the chain's write + 9 passes) and both are
    monotone in problem size."""
    for family in ("tt", "cp"):
        plan = plan_fused_update(family, 128, 8, (64, 16, 16), 2)
        assert fused_hbm_bytes(plan) < unfused_hbm_bytes(plan)
        dense = 4 * plan.b * 64 * 16 * 16
        # exactly one dense-array round trip saved plus the write itself
        assert unfused_hbm_bytes(plan) - fused_hbm_bytes(plan) == 2 * dense


def test_fused_typed_errors():
    dims, k = (8, 16, 16), 64
    gop = rp.make_projector(
        rp.ProjectorSpec(family="gaussian", k=k, dims=dims), jax.random.PRNGKey(2))
    args = [jnp.zeros((2, k))] + [jnp.zeros((2,) + dims)] * 4
    scal = [jnp.float32(1e-3), jnp.float32(0.1), jnp.float32(0.05)]
    with pytest.raises(TypeError, match="TT/CP operator"):
        fused_update_buckets(gop, *args, *scal, **HP)
    from repro.kernels import MAX_ORDER
    big = (2,) * (MAX_ORDER + 1)
    top = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=k, dims=big, rank=2),
        jax.random.PRNGKey(3))
    args7 = [jnp.zeros((2, k))] + [jnp.zeros((2,) + big)] * 4
    with pytest.raises(ValueError, match="order"):
        fused_update_buckets(top, *args7, *scal, **HP)


# ---------------------------------------------------------------------------
# optimizer-level entry: update_sketched
# ---------------------------------------------------------------------------

def _setup_tree():
    from repro.core.sketch import SketchConfig
    from repro.optim import adamw
    from repro.optim.compress import SketchCompressor

    cfg = SketchConfig(family="tt", k=128, rank=2, dims=(16, 16, 8),
                       bucket_elems=2048)
    comp = SketchCompressor(cfg)
    acfg = adamw.AdamWConfig(clip_norm=None)
    key = jax.random.PRNGKey(5)
    params = {"w": jax.random.normal(jax.random.fold_in(key, 0), (3000,)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (100, 7))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 2), (3000,)),
             "b": jax.random.normal(jax.random.fold_in(key, 3), (100, 7))}
    ef = jax.tree.map(lambda e: e + 0.01, comp.init_state(params))
    opt = adamw.init_state(params, acfg)
    opt = {**opt, "count": jnp.asarray(4, jnp.int32),
           "m": jax.tree.map(lambda p: p * 0.05, params),
           "v": jax.tree.map(lambda p: jnp.abs(p) * 0.01, params)}
    return comp, acfg, params, grads, ef, opt


def test_update_sketched_matches_compress_then_update():
    """The fused optimizer step IS the compress -> update chain (f32
    params/grads, nonzero EF residual, mid-trajectory count) — same
    params, moments, residual, count, and metrics keys."""
    from repro.optim import adamw

    comp, acfg, params, grads, ef, opt = _setup_tree()
    lr = jnp.float32(1e-3)
    g_ref, ef_ref, _ = comp.compress(grads, ef, step=opt["count"])
    p_ref, opt_ref, _ = adamw.update(params, g_ref, opt, lr, acfg)
    p_f, opt_f, ef_f, met = adamw.update_sketched(
        params, grads, ef, opt, lr, acfg, compressor=comp)
    for ref_t, got_t in [(p_ref, p_f), (opt_ref["m"], opt_f["m"]),
                         (opt_ref["v"], opt_f["v"]),
                         (ef_ref["residual"], ef_f["residual"])]:
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5),
            ref_t, got_t)
    assert int(opt_f["count"]) == int(opt_ref["count"]) == 5
    assert {"sketch_bytes", "dense_bytes", "residual_norm"} <= set(met)


def test_update_sketched_chained_steps():
    """Two fused steps back to back stay glued to the unfused chain —
    the EF residual produced by step 1 feeds step 2 identically."""
    from repro.optim import adamw

    comp, acfg, params, grads, ef, opt = _setup_tree()
    lr = jnp.float32(1e-3)
    p_u, opt_u, ef_u = params, opt, ef
    p_f, opt_f, ef_f = params, opt, ef
    for step in range(2):
        g_hat, ef_u, _ = comp.compress(grads, ef_u, step=opt_u["count"])
        p_u, opt_u, _ = adamw.update(p_u, g_hat, opt_u, lr, acfg)
        p_f, opt_f, ef_f, _ = adamw.update_sketched(
            p_f, grads, ef_f, opt_f, lr, acfg, compressor=comp)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4), p_u, p_f)


def test_update_sketched_typed_errors():
    from repro.core import random_tt
    from repro.optim import adamw

    comp, acfg, params, grads, ef, opt = _setup_tree()
    lr = jnp.float32(1e-3)
    with pytest.raises(ValueError, match="clip_norm=None"):
        adamw.update_sketched(params, grads, ef, opt, lr,
                              adamw.AdamWConfig(), compressor=comp)
    struct_g = {"w": random_tt(jax.random.PRNGKey(6), (16, 16, 8), 2)}
    struct_p = {"w": jnp.zeros((2048,))}
    struct_ef = {"residual": {"w": jnp.zeros((2048,))}}
    struct_opt = adamw.init_state(struct_p, acfg)
    with pytest.raises(ValueError, match="dense gradient leaves only"):
        adamw.update_sketched(struct_p, struct_g, struct_ef, struct_opt,
                              lr, acfg, compressor=comp)


def test_build_train_step_fused_validations():
    """The three build-time misuse errors fire before any compile."""
    from repro.configs import get_config, reduced
    from repro.core.sketch import SketchConfig
    from repro.launch import steps
    from repro.models import build_model
    from repro.models.config import ShapeSpec
    from repro.optim.adamw import AdamWConfig
    from repro.optim.compress import SketchCompressor

    cfg = reduced(get_config("llama3.2-3b"))
    model = build_model(cfg)
    shape = ShapeSpec("t", 32, 4, "train")
    comp = SketchCompressor(SketchConfig(
        family="tt", k=1024, rank=8, bucket_elems=4 * 8 * 16,
        dims=(4, 8, 16)))
    opt = AdamWConfig(clip_norm=None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        with pytest.raises(ValueError, match="needs a compressor"):
            steps.build_train_step(model, mesh, shape, opt=opt,
                                   fused_update=True)
        with pytest.raises(ValueError, match="clip_norm=None"):
            steps.build_train_step(model, mesh, shape, compressor=comp,
                                   opt=AdamWConfig(), fused_update=True)
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with mesh3:
        with pytest.raises(ValueError, match="single-pod"):
            steps.build_train_step(model, mesh3, shape, compressor=comp,
                                   opt=opt, fused_update=True)


def test_build_train_step_fused_trains(subproc):
    """End to end: the fused branch compiles, steps, and learns on a tiny
    model (loss strictly decreases over a short run)."""
    out = subproc("""
import functools, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.launch import steps
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import SketchCompressor
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM

mesh = jax.make_mesh((1, 1), ("data", "model"))
cfg = reduced(get_config("llama3.2-3b"))
model = build_model(cfg)
shape = ShapeSpec("t", 32, 4, "train")
scfg = SketchConfig(family="tt", k=1024, rank=8, bucket_elems=4*8*16,
                    dims=(4, 8, 16))
comp = SketchCompressor(scfg)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
with mesh:
    b = steps.build_train_step(
        model, mesh, shape, compressor=comp, opt=AdamWConfig(clip_norm=None),
        lr_fn=functools.partial(schedule.constant, peak_lr=3e-3),
        fused_update=True)
    state = steps.init_train_state(model, jax.random.PRNGKey(0),
                                   compressor=comp)
    losses = []
    for i in range(8):
        state, m = b.fn(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("FUSED_OK first=%.3f last=%.3f" % (losses[0], losses[-1]))
""", timeout=1200)
    assert "FUSED_OK" in out
