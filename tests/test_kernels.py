"""Pallas kernels vs pure-jnp oracle (ref.py), interpret=True on CPU.

Sweeps shapes (aligned and ragged), k values (padding path) and ranks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TTTensor, random_tt, sample_cp_rp, sample_tt_rp
from repro.kernels import cp_project, ref, tt_dot, tt_project

SHAPES = [
    (16, 32, 24),      # ragged-ish
    (8, 128, 64),      # lane-aligned tail
    (32, 16, 16),
]
KS = [64, 128, 200]


@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("rank", [1, 3])
def test_tt_project_kernel(dims, k, rank):
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, rank)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got = tt_project(op, x)
    g1 = op.cores[0][:, 0, :, :]
    g2 = op.cores[1]
    g3 = op.cores[2][:, :, :, 0]
    want = ref.tt_project3_ref(x, g1, g2, g3) / jnp.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(op.project(x)),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("rank", [1, 4])
def test_cp_project_kernel(dims, k, rank):
    op = sample_cp_rp(jax.random.PRNGKey(0), dims, k, rank)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got = cp_project(op, x)
    want = ref.cp_project3_ref(x, *op.factors) / jnp.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", [64, 200])
@pytest.mark.parametrize("rx", [1, 4])
def test_tt_dot_kernel(dims, k, rx):
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, 2)
    x = random_tt(jax.random.PRNGKey(2), dims, rx)
    got = tt_dot(op, x)
    g1 = op.cores[0][:, 0, :, :]
    g2 = op.cores[1]
    g3 = op.cores[2][:, :, :, 0]
    want = ref.tt_dot3_ref(*x.cores, g1, g2, g3) / jnp.sqrt(float(k))
    # f32 accumulation-order differences reach ~1e-4 relative on the larger
    # (dims, rx) cells; 3e-5 was flaky on the seed.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(op.project_tt(x)),
                               rtol=2e-4, atol=2e-4)


def test_kernel_fallback_non_order3():
    """Orders != 3 fall back to the core einsum path."""
    dims = (4, 5, 6, 7)
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, 32, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    np.testing.assert_allclose(np.asarray(tt_project(op, x)),
                               np.asarray(op.project(x)), rtol=1e-5)


def test_kernel_bf16_inputs():
    dims = (8, 32, 16)
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, 128, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got16 = tt_project(op, x.astype(jnp.bfloat16))
    want = op.project(x)
    np.testing.assert_allclose(np.asarray(got16, dtype=np.float32),
                               np.asarray(want), rtol=0.05, atol=0.05)
