"""Mode-sweep Pallas kernels vs pure-jnp oracle (ref.py), interpret=True.

Sweeps orders 2-5, shapes (aligned and ragged), k values (padding path),
ranks, batch sizes (ragged B included), both directions, and the planner.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sample_cp_rp, sample_tt_rp
from repro.kernels import (cp_project, cp_reconstruct, pick_tiles,
                           plan_contraction, ref, tt_cores_squeezed,
                           tt_project, tt_reconstruct)

SHAPES = [
    (16, 32, 24),      # ragged-ish
    (8, 128, 64),      # lane-aligned tail
    (32, 16, 16),
]
KS = [64, 128, 200]

# one ragged shape per order 2-5 (every mode-count hits the sweep loop
# differently: no interior cores, one, two, three)
ORDER_SHAPES = [(16, 24), (16, 32, 24), (8, 6, 4, 10), (4, 6, 4, 8, 4)]


@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("rank", [1, 3])
def test_tt_project_kernel(dims, k, rank):
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, rank)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got = tt_project(op, x)
    want = ref.tt_project_ref(x, tt_cores_squeezed(op)) / jnp.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(op.project(x)),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("rank", [1, 4])
def test_cp_project_kernel(dims, k, rank):
    op = sample_cp_rp(jax.random.PRNGKey(0), dims, k, rank)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got = cp_project(op, x)
    want = ref.cp_project_ref(x, op.factors) / jnp.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# (the structured-input TT x TT kernel coverage that lived here moved to
# tests/test_struct.py with the carry-sweep subsystem, which replaced the
# order-3-only tt_dot kernel)

# ---------------------------------------------------------------------------
# order-N sweep: batched kernels vs vmap-of-reference (interpret mode)
# ---------------------------------------------------------------------------

BATCHES = [1, 3, 5, 16]   # ragged (3, 5) exercise batch-tile padding


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("dims", ORDER_SHAPES)
@pytest.mark.parametrize("k", [96, 200])
def test_tt_sweep_all_orders_vs_refs(b, dims, k):
    """Order 2-5 project AND reconstruct == references and the operator's
    own einsum paths (non-power-of-two k covers the k-padding path)."""
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, 2)
    cores = tt_cores_squeezed(op)
    xb = jax.random.normal(jax.random.PRNGKey(1), (b,) + dims)
    got = tt_project(op, xb)
    assert got.shape == (b, k)
    want = jax.vmap(lambda x: ref.tt_project_ref(x, cores))(xb)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want) / np.sqrt(float(k)),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(op.project(xb)),
                               rtol=2e-4, atol=2e-4)
    y = jax.random.normal(jax.random.PRNGKey(2), (b, k))
    gr = tt_reconstruct(op, y)
    assert gr.shape == (b,) + dims
    wr = ref.tt_reconstruct_ref(y, cores) / np.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(gr),
                               np.asarray(jax.vmap(op.reconstruct)(y)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("dims", ORDER_SHAPES)
@pytest.mark.parametrize("k", [96, 200])
def test_cp_sweep_all_orders_vs_refs(b, dims, k):
    op = sample_cp_rp(jax.random.PRNGKey(0), dims, k, 3)
    xb = jax.random.normal(jax.random.PRNGKey(1), (b,) + dims)
    got = cp_project(op, xb)
    assert got.shape == (b, k)
    want = jax.vmap(lambda x: ref.cp_project_ref(x, op.factors))(xb)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want) / np.sqrt(float(k)),
                               rtol=3e-5, atol=3e-5)
    y = jax.random.normal(jax.random.PRNGKey(2), (b, k))
    gr = cp_reconstruct(op, y)
    assert gr.shape == (b,) + dims
    wr = ref.cp_reconstruct_ref(y, op.factors) / np.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(gr),
                               np.asarray(jax.vmap(op.reconstruct)(y)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("dims,k", [((16, 32, 24), 200), ((8, 128, 64), 128)])
def test_tt_project_batched_vs_vmap_ref(b, dims, k):
    """Batched kernel == vmap of the unbatched reference, with the fused
    1/sqrt(k) scaling (ragged B exercises the batch-tile padding)."""
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, 2)
    cores = tt_cores_squeezed(op)
    xb = jax.random.normal(jax.random.PRNGKey(1), (b,) + dims)
    got = tt_project(op, xb)
    assert got.shape == (b, k)
    want = jax.vmap(lambda x: ref.tt_project_ref(x, cores))(xb)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want) / np.sqrt(float(k)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("dims,k", [((16, 32, 24), 200), ((8, 128, 64), 128)])
def test_cp_project_batched_vs_vmap_ref(b, dims, k):
    op = sample_cp_rp(jax.random.PRNGKey(0), dims, k, 3)
    xb = jax.random.normal(jax.random.PRNGKey(1), (b,) + dims)
    got = cp_project(op, xb)
    assert got.shape == (b, k)
    want = jax.vmap(lambda x: ref.cp_project_ref(x, op.factors))(xb)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want) / np.sqrt(float(k)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", [128, 200])
def test_tt_reconstruct_batched_vs_vmap_ref(b, dims, k):
    """Adjoint kernel == the reference einsum chain == vmap of
    op.reconstruct, ragged B and non-power-of-two k included."""
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, 2)
    y = jax.random.normal(jax.random.PRNGKey(1), (b, k))
    got = tt_reconstruct(op, y)
    assert got.shape == (b,) + dims
    want = ref.tt_reconstruct_ref(y, tt_cores_squeezed(op)) / np.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.vmap(op.reconstruct)(y)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("dims", SHAPES)
@pytest.mark.parametrize("k", [128, 200])
def test_cp_reconstruct_batched_vs_vmap_ref(b, dims, k):
    op = sample_cp_rp(jax.random.PRNGKey(0), dims, k, 3)
    y = jax.random.normal(jax.random.PRNGKey(1), (b, k))
    got = cp_reconstruct(op, y)
    assert got.shape == (b,) + dims
    want = ref.cp_reconstruct_ref(y, op.factors) / np.sqrt(float(k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.vmap(op.reconstruct)(y)),
                               rtol=1e-5, atol=1e-5)


def test_reconstruct_unbatched_matches_op():
    """(k,) in, in_dims-shaped out — the single-sketch contract survives."""
    dims, k = (16, 32, 24), 128
    for sampler, kern in ((sample_tt_rp, tt_reconstruct),
                          (sample_cp_rp, cp_reconstruct)):
        op = sampler(jax.random.PRNGKey(0), dims, k, 2)
        y = jax.random.normal(jax.random.PRNGKey(1), (k,))
        got = kern(op, y)
        assert got.shape == dims
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(op.reconstruct(y)),
                                   rtol=1e-5, atol=1e-5)


def test_fused_scaling_matches_explicit():
    """The epilogue-fused 1/sqrt(k) equals the raw contraction scaled after —
    scaling each k-tile partial sum commutes with the d1 accumulation."""
    from repro.kernels.tt_sweep import tt_sweep_project
    dims, k = (16, 32, 24), 128
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, k, 2)
    cores = tt_cores_squeezed(op)
    xb = jax.random.normal(jax.random.PRNGKey(1), (4,) + dims)
    steps = plan_contraction("tt", "project", k, 4, dims, 2).steps
    raw = tt_sweep_project(xb, *cores, steps=steps, tk=64, tb=4, ba=8)
    fused = tt_sweep_project(xb, *cores, steps=steps, tk=64, tb=4, ba=8,
                             scale=1.0 / float(np.sqrt(k)))
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(raw) / np.sqrt(float(k)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_pick_tiles_respects_vmem_budget():
    """The selector shrinks tiles until the accounted footprint fits, and
    prefers shrinking the batch tile for project / the k tile for the
    adjoint (whose fused transfer block is batch-independent)."""
    dims = (128, 128, 64)
    tk_p, tb_p, ba_p = pick_tiles(1024, 16, dims, 2, kind="project")
    assert tk_p == 128 and ba_p == 8 and 1 <= tb_p <= 8
    tk_r, tb_r, _ = pick_tiles(1024, 16, dims, 2, kind="reconstruct")
    assert tk_r < 128          # m = tk*R*d2*d3 floats forces a smaller tk
    assert tb_r >= tb_p        # batch tile survives on the adjoint
    # tiny problems keep full-size tiles, at every order
    assert pick_tiles(64, 2, (8, 8, 8), 2, kind="project") == (64, 2, 8)
    assert pick_tiles(64, 2, (8, 8, 8, 8), 2, kind="project") == (64, 2, 8)
    # order-4 adjoint with a big trailing product also sheds the k tile
    tk_r4, tb_r4, _ = pick_tiles(1024, 16, (32, 32, 32, 32), 2,
                                 kind="reconstruct")
    assert tk_r4 < 128 and tb_r4 >= 1
    with pytest.raises(ValueError, match="unknown kind"):
        pick_tiles(64, 2, (8, 8, 8), 2, kind="nope")


def test_plan_contraction_emits_order3_program():
    """The planner's einsum program at order 3 is exactly the retired
    hand-written order-3 kernel schedule."""
    plan = plan_contraction("tt", "project", 256, 4, (8, 128, 64), 2)
    assert plan.steps == ("nabc,kuc->knabu", "knabu,kvbu->knav",
                          "knav,kav->nk")
    assert plan.grid == (2, 1, 1) and plan.order == 3
    m_steps, h_spec, out_spec = plan_contraction(
        "tt", "reconstruct", 256, 4, (8, 128, 64), 2).steps
    assert m_steps == (None, "kvbu,kuc->kvbc")
    assert (h_spec, out_spec) == ("nk,kav->nakv", "nakv,kvbc->nabc")
    cp_plan = plan_contraction("cp", "reconstruct", 256, 4, (8, 128, 64), 2)
    assert cp_plan.steps[0][0] == "kcr->krc"   # CP layout transpose


def test_plan_contraction_rejects_bad_requests():
    with pytest.raises(ValueError, match="order >= 2"):
        plan_contraction("tt", "project", 64, 1, (64,), 2)
    with pytest.raises(ValueError, match="unknown family"):
        plan_contraction("tucker", "project", 64, 1, (8, 8), 2)
    with pytest.raises(ValueError, match="MAX_ORDER"):
        plan_contraction("tt", "project", 64, 1, (2,) * 9, 2)


def test_kernel_fallback_order1():
    """Order-1 operators (classical Gaussian RP as TT) fall back to the
    core einsum path — there is no mode to sweep."""
    op = sample_tt_rp(jax.random.PRNGKey(0), (64,), 32, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    np.testing.assert_allclose(np.asarray(tt_project(op, x)),
                               np.asarray(op.project(x)), rtol=1e-5)
    y = jax.random.normal(jax.random.PRNGKey(2), (32,))
    np.testing.assert_allclose(np.asarray(tt_reconstruct(op, y)),
                               np.asarray(op.reconstruct(y)), rtol=1e-5)


def test_kernel_bf16_inputs():
    dims = (8, 32, 16)
    op = sample_tt_rp(jax.random.PRNGKey(0), dims, 128, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), dims)
    got16 = tt_project(op, x.astype(jnp.bfloat16))
    want = op.project(x)
    np.testing.assert_allclose(np.asarray(got16, dtype=np.float32),
                               np.asarray(want), rtol=0.05, atol=0.05)
