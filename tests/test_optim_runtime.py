"""AdamW vs analytic reference, schedules, slot-server serving, and the
roofline depth-extrapolation arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw, schedule


def test_adamw_matches_reference():
    cfg = AdamWConfig(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      clip_norm=None)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw.init_state(p, cfg)
    lr = 0.1
    p1, st1, _ = adamw.update(p, g, st, lr, cfg)
    # analytic single step: m = (1-b1)g; v = (1-b2)g^2; bias-corrected step
    m_hat = np.asarray(g["w"]) * (1 - cfg.b1) / (1 - cfg.b1)
    v_hat = np.asarray(g["w"]) ** 2 * (1 - cfg.b2) / (1 - cfg.b2)
    want = (np.asarray(p["w"])
            - lr * (m_hat / (np.sqrt(v_hat) + cfg.eps)
                    + cfg.weight_decay * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)
    assert int(st1["count"]) == 1


def test_adamw_clipping():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 19


def test_cosine_schedule_shape():
    f = lambda s: float(schedule.cosine_with_warmup(
        jnp.asarray(s, jnp.float32), peak_lr=1.0, warmup_steps=10,
        total_steps=100))
    assert f(0) == 0.0
    assert abs(f(10) - 1.0) < 0.11
    assert f(55) < f(11)
    assert f(100) >= 0.1 - 1e-6  # final_frac floor


def test_slot_server_serves_all_requests():
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.launch.serve import Request, SlotServer
    from repro.models import build_model

    cfg = reduced(get_config("llama3.2-3b"))
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab, size=(4,)))
            for i in range(5)]
    srv = SlotServer(model, slots=2, max_seq=32, eos=None, max_gen=6)
    done = srv.run(reqs)
    assert len(done) == 5
    assert all(len(r.generated) == 6 for r in done)
    # slots were reused (5 requests through 2 slots)
    assert all(r.done for r in done)


def test_roofline_extrapolation_linear():
    """cost(n) = a + b*n recovered exactly from two probes."""
    from repro.launch import roofline as rl

    class Fake:
        def __init__(self, flops, byts, hlo):
            self._f, self._b, self._h = flops, byts, hlo

        def cost_analysis(self):
            return {"flops": self._f, "bytes accessed": self._b}

        def as_text(self):
            return self._h

    hlo1 = ('  %ar = f32[256]{0} all-reduce(%x), '
            'replica_groups=[16,16]<=[256], to_apply=%a\n')
    c1 = Fake(100.0, 1000.0, hlo1)          # n=1: a + b
    c2 = Fake(150.0, 1600.0, hlo1 * 2)      # n=2: a + 2b
    import dataclasses as dc
    from repro.models.config import ShapeSpec
    from repro.configs import get_config
    cfg = get_config("llama3.2-3b")
    shape = cfg.shape("train_4k")
    roof = rl.analyze_extrapolated(
        c1, c2, 1.0, 2.0, 10.0, arch="x", shape=shape, mesh_name="m",
        n_devices=256, cfg=cfg, memory={})
    assert abs(roof.hlo_flops_per_device - (50 + 50 * 10)) < 1e-6
    assert abs(roof.hlo_bytes_per_device - (400 + 600 * 10)) < 1e-6
    ar = roof.collective["per_type"]["all-reduce"]
    assert abs(ar["count"] - 10.0) < 1e-6


def test_collective_parser_group_sizes():
    from repro.launch.roofline import _group_size
    assert _group_size("replica_groups=[32,16]<=[512]") == 16
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("no groups here") == 1
