"""The CI bench-regression gate (benchmarks.check_regression) is pure
record-diffing — test it directly on synthetic BENCH_rp records."""
import copy

import pytest

from benchmarks.check_regression import check, main


def _record():
    return {
        "schema": "bench_rp/v9",
        "sections": {
            "timing": [
                {"name": "time/batched/tt/project/B=16", "us_per_call": 10.0,
                 "derived": {"launches_batched": 1,
                             "launches_per_bucket": 16}},
                {"name": "time/order/tt/N=4", "us_per_call": 5.0,
                 "derived": {"launches_project": 1,
                             "launches_reconstruct": 1}},
                {"name": "struct/ttxcp/N=3", "us_per_call": 4.0,
                 "derived": {"launches_project": 1, "carry_bytes": 16384}},
                {"name": "shard/collective/sync=sketch-mean",
                 "us_per_call": 7.0,
                 "derived": {"launches_project": 6, "wire_bytes": 1536}},
                {"name": "serve/trace/mixed/B=64", "us_per_call": 900.0,
                 "derived": {"launches_project": 28, "ticks": 28,
                             "hit_rate": 0.96}},
                {"name": "ckpt/sketched/n=65536", "us_per_call": 40000.0,
                 "derived": {"bytes_dense": 524288, "bytes_sketched": 32784,
                             "ratio": 15.99}},
                {"name": "perf/pipeline/sweep/tt", "us_per_call": 12000.0,
                 "derived": {"launches_project": 1, "speedup": 2.2,
                             "hbm_bytes": 2412544}},
                {"name": "perf/fused/update/tt", "us_per_call": 4000.0,
                 "derived": {"launches_project": 1, "speedup": 0.3,
                             "hbm_ratio": 0.82, "dense_kernels_fused": 0,
                             "dense_kernels_unfused": 4}},
                {"name": "perf/wire/sync=sketch-mean", "us_per_call": 1000.0,
                 "derived": {"launches_project": 6, "wire_ratio": 3.88,
                             "hlo_bytes_int8": 396}},
                {"name": "obs/overhead", "us_per_call": 1.0,
                 "derived": {"overhead_frac": 0.00003, "disabled_ns": 800,
                             "ref_us": 30000.0, "budget": 0.05}},
                {"name": "plan/cache", "us_per_call": 500.0,
                 "derived": {"plan_builds": 7, "plan_hits": 21,
                             "hit_rate": 0.75}},
                {"name": "plan/ledger/wire", "us_per_call": 0.0,
                 "derived": {"declared_wire_bytes": 8192,
                             "hlo_allreduce_bytes": 8192}},
            ],
            "smoke": [
                {"name": "smoke/tt", "us_per_call": 1.0, "derived": {"k": 64}},
            ],
        },
    }


def test_identical_records_pass():
    assert check(_record(), _record()) == []


def test_wall_clock_noise_is_not_gated():
    new = _record()
    new["sections"]["timing"][0]["us_per_call"] = 9999.0
    assert check(new, _record()) == []


def test_schema_drift_fails():
    new = _record()
    new["schema"] = "bench_rp/v10"
    assert any("schema drift" in e for e in check(new, _record()))


def test_required_row_prefixes_cover_struct_subsystem():
    """A timing record that stops emitting a whole gated row family — the
    order-N frontier, the compressed-domain struct/ rows, the
    sharded-engine shard/ rows, the serving-engine serve/ rows, or the
    checkpointing ckpt/ rows, the kernel perf-frontier perf/ rows, or the
    telemetry obs/ rows — fails even if the baseline ALSO lost them
    (row-by-row diffing alone can't see that)."""
    for prefix in ("struct/", "time/order/", "shard/", "serve/", "ckpt/",
                   "perf/", "obs/", "plan/"):
        new = _record()
        new["sections"]["timing"] = [
            r for r in new["sections"]["timing"]
            if not r["name"].startswith(prefix)]
        base = copy.deepcopy(new)          # baseline equally blind
        assert any("required prefix" in e and prefix in e
                   for e in check(new, base))
    # records without a timing section (e.g. --only smoke) are not gated
    smoke_only = {"schema": "bench_rp/v9",
                  "sections": {"smoke": _record()["sections"]["smoke"]}}
    assert not any("required prefix" in e
                   for e in check(smoke_only, copy.deepcopy(smoke_only)))


def test_missing_section_and_row_fail():
    new = _record()
    del new["sections"]["smoke"]
    errors = check(new, _record())
    assert any("sections missing" in e for e in errors)
    new2 = _record()
    new2["sections"]["timing"] = new2["sections"]["timing"][:1]
    assert any("rows missing" in e for e in check(new2, _record()))


def test_malformed_record_fails():
    new = _record()
    new["sections"]["timing"].append({"raw": "oops"})
    assert any("malformed" in e for e in check(new, _record()))


def test_vanished_launch_metric_fails():
    """A refactor that stops emitting a launch metric must not slip past
    the very gate that metric feeds."""
    new = _record()
    del new["sections"]["timing"][0]["derived"]["launches_batched"]
    errors = check(new, _record())
    assert any("launches_batched" in e and "missing" in e for e in errors)


def test_launch_count_regression_fails_only_past_2x():
    base = _record()
    doubled = copy.deepcopy(base)   # exactly 2x: allowed (threshold is >2x)
    doubled["sections"]["timing"][0]["derived"]["launches_batched"] = 2
    assert check(doubled, base) == []
    worse = copy.deepcopy(base)
    worse["sections"]["timing"][0]["derived"]["launches_batched"] = 3
    errors = check(worse, base)
    assert any("launches_batched regressed 1 -> 3" in e for e in errors)


def test_plan_builds_rides_the_launch_gate():
    """plan_builds is gated like a launch count: a plan signature going
    jit-unstable (every retrace re-planning) more than doubles builds and
    must fail the diff; its vanishing must not evade the gate either."""
    base = _record()
    worse = copy.deepcopy(base)
    worse["sections"]["timing"][10]["derived"]["plan_builds"] = 15
    assert any("plan_builds regressed 7 -> 15" in e
               for e in check(worse, base))
    vanished = copy.deepcopy(base)
    del vanished["sections"]["timing"][10]["derived"]["plan_builds"]
    assert any("plan_builds" in e and "missing" in e
               for e in check(vanished, base))


def test_perf_speedup_band():
    """perf/* `speedup` gates RELATIVE to baseline: a new value below
    0.5x baseline fails, anything above passes (absolute wall-clock is
    machine-dependent; the ratio of two timings from the same run is not).
    """
    base = _record()
    ok = copy.deepcopy(base)        # 0.6x baseline: inside the band
    ok["sections"]["timing"][6]["derived"]["speedup"] = 0.6 * 2.2
    assert check(ok, base) == []
    collapsed = copy.deepcopy(base)
    collapsed["sections"]["timing"][6]["derived"]["speedup"] = 0.4 * 2.2
    assert any("speedup regressed" in e for e in check(collapsed, base))


def test_perf_wire_ratio_band():
    base = _record()
    worse = copy.deepcopy(base)     # int8 path silently widening the wire
    worse["sections"]["timing"][8]["derived"]["wire_ratio"] = 1.0
    assert any("wire_ratio regressed" in e for e in check(worse, base))


def test_perf_hbm_ratio_gates_upward():
    """hbm_ratio (fused/unfused bytes) is better LOW: growth past
    baseline/0.8 means the fused kernel started re-streaming dense
    traffic it used to keep in VMEM."""
    base = _record()
    worse = copy.deepcopy(base)
    worse["sections"]["timing"][7]["derived"]["hbm_ratio"] = 1.1
    assert any("hbm_ratio regressed" in e for e in check(worse, base))
    ok = copy.deepcopy(base)        # small drift inside the band passes
    ok["sections"]["timing"][7]["derived"]["hbm_ratio"] = 0.9
    assert check(ok, base) == []


def test_vanished_perf_metric_fails():
    new = _record()
    del new["sections"]["timing"][6]["derived"]["speedup"]
    assert any("speedup" in e and "missing" in e for e in check(new, _record()))


def test_perf_bands_do_not_gate_non_perf_rows():
    """time/batched/* rows carry an 'x'-suffixed string speedup; even a
    numeric one outside perf/ must not be banded."""
    base = _record()
    base["sections"]["timing"][0]["derived"]["speedup"] = 2.0
    new = copy.deepcopy(base)
    new["sections"]["timing"][0]["derived"]["speedup"] = 0.1
    assert check(new, base) == []


def test_obs_overhead_absolute_cap():
    """obs/* overhead_frac is capped ABSOLUTELY at 0.05 — a ratio of two
    same-process timings, so unlike wall-clock an absolute budget holds
    across machines. The metric vanishing must not evade the cap."""
    base = _record()
    ok = copy.deepcopy(base)            # growth under the cap passes
    ok["sections"]["timing"][9]["derived"]["overhead_frac"] = 0.049
    assert check(ok, base) == []
    bloated = copy.deepcopy(base)
    bloated["sections"]["timing"][9]["derived"]["overhead_frac"] = 0.06
    assert any("overhead_frac" in e and "budget" in e
               for e in check(bloated, base))
    vanished = copy.deepcopy(base)
    del vanished["sections"]["timing"][9]["derived"]["overhead_frac"]
    assert any("overhead_frac" in e and "missing" in e
               for e in check(vanished, base))


def test_run_only_unknown_section_raises():
    from benchmarks.run import main as run_main
    with pytest.raises(ValueError, match=r"unknown --only section\(s\) "
                                         r"\['nope'\].*accepted"):
        run_main(["--only", "timing,nope"])


def test_main_exit_codes(tmp_path, capsys):
    import json
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_record()))
    main([str(good), str(good)])
    assert "bench-regression: OK" in capsys.readouterr().out
    bad = _record()
    bad["schema"] = "nope"
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(bad))
    with pytest.raises(SystemExit):
        main([str(bad_p), str(good)])
